"""Fleet controller: placement, health sweeps, pre-copy auto-migration.

Three shells form a fleet under a ``FleetController``.  The demo walks
the control plane's three verbs end to end:

1. **Placement** — ``place()`` scores members by free-page fraction
   minus a recent-fault penalty and picks the landing member for a new
   tenant (a member that cannot fit is excluded outright).
2. **Auto-migration** — tenant "gold" decodes on a deliberately small
   member; ``sweep()`` (the reconcile loop body, NOT a manual migrate
   call) flags the hotspot and pre-copy-migrates the tenant to the
   coldest member while it keeps serving: warm rounds ship KV pages,
   the freeze carries only the dirty delta.
3. **Stream re-homing** — both members run ``ServingGateway``s; the
   move re-routes the live ``TokenStream``s, so readers keep their
   stream objects and every stream finishes exactly once.

An undisturbed oracle engine proves token-for-token continuity; the
script exits non-zero on any lost/duplicated stream or divergence.

Run: PYTHONPATH=src python examples/fleet_autoscale.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Shell, ShellConfig
from repro.core.services import MMUConfig
from repro.fleet import FleetController
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.gateway import ServingGateway

PAGE = 16


def mk_shell(name: str, pool: int) -> Shell:
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=pool)},
        n_vfpgas=2), name=name)
    s.build()
    return s


def mk_engine(cfg, params, shell, *, rid_base=0) -> ServingEngine:
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=4, max_len=256, shell=shell, slot=0,
                         tenant="gold", rid_base=rid_base)


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

    small = mk_shell("edge-small", pool=32)     # 32 x 16 = 512 tokens
    big = mk_shell("pod-big", pool=256)
    oracle_shell = mk_shell("oracle", pool=256)
    eng_small = mk_engine(cfg, params, small, rid_base=0)
    eng_big = mk_engine(cfg, params, big, rid_base=1000)
    oracle = mk_engine(cfg, params, oracle_shell, rid_base=2000)
    gw_small = ServingGateway(eng_small, admission="fifo")
    gw_big = ServingGateway(eng_big, admission="fifo")

    # the ramp prompts share prefixes, so CoW dedup keeps the small
    # member near 9 unique pages (util ~0.28) — threshold just under
    fc = FleetController(precopy=True, hot_util=0.25, cold_util=0.60)
    fc.add_shell(small)
    fc.add_shell(big)
    fc.attach_gateway(small, gw_small)
    fc.attach_gateway(big, gw_big)

    # ---- hotspot forms on the small member ---------------------------------
    prompts = [list(range(3, 3 + n)) for n in (60, 90, 40)]
    streams = [gw_small.submit(p, max_new_tokens=24) for p in prompts]
    oracle_rids = [oracle.submit(p, max_new_tokens=24) for p in prompts]
    for _ in range(4):
        gw_small.step()
        oracle.step()
    load = fc.member_load(small)
    print(f"member {load['name']!r}: {load['pages_used']}/"
          f"{load['pages_total']} pages (util {load['util']:.2f}) -> hot")

    # ---- placement ---------------------------------------------------------
    pick = fc.place(pages_needed=8)
    print(f"placement: a NEW 8-page tenant would land on {pick.name!r} "
          f"(free-fraction scoring avoids the hot member)")
    assert pick is big
    assert fc.place(pages_needed=10**6) is None     # nobody can fit it

    # ---- the controller decides (sweep, not a manual migrate) --------------
    moved = [d for d in fc.sweep() if d.action == "migrate" and d.ok]
    assert moved, "sweep did not auto-migrate the hotspot"
    rep = moved[0].report
    print(f"\nsweep auto-migrated {moved[0].tenant!r}: "
          f"{moved[0].src} -> {moved[0].dst} ({moved[0].reason})")
    print(f"  pre-copy   {rep.precopy_rounds} warm rounds, "
          f"{rep.precopy_pages} pages shipped while serving")
    print(f"  freeze     {rep.delta_pages} dirty-delta pages, "
          f"downtime {rep.downtime_s * 1e3:.2f} ms")

    # ---- streams were re-homed; finish them on the big member --------------
    gw_big.drain()
    while oracle.pending():
        oracle.step()
    assert all(s.done and s.error is None for s in streams)
    assert not gw_small.streams and not gw_small.queue
    done = sorted(id(s) for s in gw_big.completed)
    assert done == sorted(id(s) for s in streams), \
        "streams lost or duplicated across the auto-migration"
    oracle_out = {r.rid: r.out_tokens for r in oracle.completed}
    for s, orid in zip(streams, oracle_rids):
        assert s.tokens == oracle_out[orid], \
            f"token divergence on stream {s.rid}"
    print(f"\nre-homed {len(streams)} live streams to {moved[0].dst!r}: "
          "all finished exactly once, token-for-token equal to the "
          "undisturbed oracle")
    assert small.services.get("mmu").utilization()["pages_used"] == 0
    print(f"{small.name!r} pages fully released; controller log: "
          f"{fc.status()['moves']} move(s), "
          f"{len(fc.decisions)} decision(s)")

    for s in (small, big, oracle_shell):
        s.close()
    print("OK")


if __name__ == "__main__":
    main()
