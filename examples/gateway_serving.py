"""Scenario: an always-on serving front door with SLOs.

Requests do not arrive in tidy batches: they show up on their own clock
(Poisson), in tiers (gold with tight deadlines, best-effort without),
and sometimes with deadlines that cannot possibly be met.  The
ServingGateway bridges that open-arrival world to the slot-granular
engine: continuous batching (finished rows backfilled every step),
chunked prefill (a long prompt streams in 32-token chunks instead of
stalling everyone), SLO admission (infeasible deadlines rejected typed,
queued deadlines expired, priorities aged as slack shrinks), and live
per-request token streams with TTFT/TPOT measured from arrival.

    PYTHONPATH=src python examples/gateway_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.faults import FaultKind
from repro.core.port import PortError
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.gateway import ServingGateway

cfg = get_config("smollm-135m").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
rng = np.random.RandomState(42)


def new_engine():
    mmu = MMU(MMUConfig(page_size=16, n_pages=256))
    return ServingEngine(cfg, params, mmu, max_batch=4, max_len=256,
                         seed=7, prefill_chunk=32)


def prompt(n):
    return rng.randint(0, cfg.vocab_size, size=n).tolist()


# --- 1. Poisson traffic in two SLO tiers, served continuously ------------
gw = ServingGateway(new_engine(), mode="continuous", admission="slo",
                    min_obs=1, aging_window_s=30.0)
# warm the engine's timing model (and the JIT cache) through the gateway
for _ in range(4):
    gw.submit(prompt(17), max_new_tokens=8)
gw.drain()
est = gw._service_estimate(17, 8)
print(f"timing model warm: single-request estimate ~{est * 1e3:.1f} ms")

t0 = time.perf_counter()
arrivals, streams = 0.0, []
for k in range(12):
    arrivals += float(rng.exponential(0.01))
    tier = "gold" if k % 3 else "best-effort"
    while time.perf_counter() - t0 < arrivals:
        gw.step()
    streams.append((tier, gw.submit(
        prompt(17), max_new_tokens=8,
        priority=1 if tier == "gold" else 0,
        deadline_s=20.0 if tier == "gold" else None)))
gw.drain()
st = gw.stats()
done = sum(1 for _, s in streams if s.done)
print(f"served {done}/12 mixed-tier requests: "
      f"goodput {st['goodput']:.1f}/s, TTFT p99 {st['ttft_p99_ms']:.1f} ms, "
      f"TPOT p50 {st['tpot_p50_ms']:.2f} ms")
assert all(s.done for _, s in streams)
# gold requests carry deadlines inside the aging window, so their
# effective priority was boosted while queued
aged = max(s.eff_priority - s.priority for t, s in streams if t == "gold")
print(f"deadline-driven aging boosted a gold request by +{aged}")
assert aged >= 1

# --- 2. live rejection: a deadline the engine cannot meet ----------------
try:
    gw.submit(prompt(64), max_new_tokens=64, deadline_s=0.2 * est)
    raise SystemExit("infeasible deadline was not rejected")
except PortError as e:
    assert e.kind == FaultKind.SLO_INFEASIBLE and not e.retryable
    print(f"infeasible deadline rejected at the door: {e.kind}")

# --- 3. expiry: a feasible deadline that dies in the queue ---------------
gw2 = ServingGateway(new_engine())          # cold model: door check off
doomed = gw2.submit(prompt(17), max_new_tokens=8, deadline_s=0.01)
time.sleep(0.02)
gw2.step()
assert doomed.rejected and doomed.error.kind == FaultKind.SLO_EXPIRED
assert doomed.rid is None                   # never wasted a prefill
print("queued request expired typed before burning page credits")

# --- 4. chunked prefill keeps shorts fast next to a long prompt ----------
gw3 = ServingGateway(new_engine(), admission="fifo")
long_s = gw3.submit(prompt(192), max_new_tokens=8)
shorts = [gw3.submit(prompt(15), max_new_tokens=8) for _ in range(3)]
gw3.drain()
ttfts = [s.ttft() * 1e3 for s in shorts]
print(f"shorts' TTFT next to a 192-token prompt (chunked prefill): "
      f"{max(ttfts):.1f} ms worst-case")
assert long_s.done and all(s.done for s in shorts)
print("gateway demo OK")
