"""Scenario: copy-on-write prefix sharing across templated requests.

Chatbots and agent fleets send many prompts that start with the same
system preamble.  The MMU content-keys full prompt pages (a chain hash
over token blocks), so ``alloc_seq`` maps the covered prefix onto
EXISTING physical pages with a refcount bump; the engine then prefills
only the uncovered suffix and admission charges page credits only for
private pages.  Writes to a shared page copy-on-write-fault onto a
fresh private page, so sharing is invisible to tenants — the demo ends
with a token-for-token parity check against a sharing-disabled engine.

    PYTHONPATH=src python examples/prefix_sharing.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

PAGE = 16
SYSTEM_PROMPT = list(range(3, 3 + 4 * PAGE))      # 4-page shared preamble

cfg = get_config("smollm-135m").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)


def serve(sharing: bool, n_pages: int = 96):
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=n_pages,
                        prefix_sharing=sharing))
    eng = ServingEngine(cfg, params, mmu, max_batch=4, max_len=256, seed=5)
    for uid in range(6):
        eng.submit(SYSTEM_PROMPT + [100 + uid, 200 + uid],
                   max_new_tokens=8, temperature=0.0 if uid % 2 else 0.6)
    eng.run()
    return eng, {tuple(r.prompt): list(r.out_tokens) for r in eng.completed}


# --- 1. templated traffic: shared prefill work is skipped ----------------
eng, outs = serve(sharing=True)
util = eng.mmu.utilization()
print(f"prefix hits: {util['prefix_hits']}, "
      f"prefill computed/skipped: {eng.prefill_computed}"
      f"/{eng.prefill_skipped}")
assert util["prefix_hits"] > 0, "templated prompts must hit the index"
assert eng.prefill_skipped > 0, "covered pages must skip prefill compute"

# --- 2. sharing is invisible: token-for-token parity ---------------------
_, outs_private = serve(sharing=False)
assert outs == outs_private, "sharing must not change any output token"
print(f"parity: {len(outs)} completions identical with sharing on/off")

# --- 3. admission: shared pages cost no page credits ---------------------
def admitted(sharing: bool) -> int:
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=12,
                        prefix_sharing=sharing))
    eng = ServingEngine(cfg, params, mmu, max_batch=8, max_len=256)
    for uid in range(8):
        eng.submit(SYSTEM_PROMPT + [100 + uid], max_new_tokens=8)
    eng.step()                                    # one admission pass
    return eng.active

base, shared = admitted(False), admitted(True)
print(f"concurrent sequences in a 12-page pool: "
      f"{base} private vs {shared} shared")
assert shared >= 2 * base, "sharing must at least double admissions"

# --- 4. copy-on-write: a write to a shared page stays private ------------
mmu = MMU(MMUConfig(page_size=PAGE, n_pages=16))
mmu.alloc_seq(1, len(SYSTEM_PROMPT), prompt_tokens=SYSTEM_PROMPT)
mmu.alloc_seq(2, len(SYSTEM_PROMPT), prompt_tokens=SYSTEM_PROMPT)
before = mmu.translate(2, 0)[0]
after = mmu.translate(2, 0, for_write=True)[0]    # CoW fault
assert after != before and mmu.translate(1, 0)[0] == before
assert mmu.utilization()["cow_faults"] == 1
print(f"CoW: writer remapped {before} -> {after}, sharer untouched")

print("OK: prefix sharing pays (skipped prefill, 2x admissions) and "
      "stays invisible (parity, CoW isolation)")
