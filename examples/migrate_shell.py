"""Quiesce-and-migrate: move a LIVE LM-serving tenant between shells.

Two shells serve the same reduced model.  Tenant "gold" decodes on shell
A; mid-decode we call ``migrate(A, B, "gold")`` — the slot quiesces, the
tenant's page tables AND actual KV pages are gathered into a versioned
snapshot container, restored onto shell B's MMU (fresh pages, rebuilt
device block table), and decode continues on B.  An unmigrated oracle
engine proves continuity: token-for-token identical output.  A bronze
tenant driving shell B's slot 1 throughout shows non-interference.

Run: PYTHONPATH=src python examples/migrate_shell.py
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import make_passthrough_artifact
from repro.configs import get_config
from repro.core import Invocation, Oper, SgEntry, Shell, ShellConfig, \
    migrate
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

PAGE, POOL = 16, 128


def mk_shell() -> Shell:
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
        n_vfpgas=2))
    s.build()
    return s


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    shell_a, shell_b = mk_shell(), mk_shell()
    eng_a = ServingEngine(cfg, params, shell_a.services.get("mmu"),
                          max_batch=3, max_len=128, shell=shell_a, slot=0,
                          tenant="gold")
    eng_b = ServingEngine(cfg, params, shell_b.services.get("mmu"),
                          max_batch=3, max_len=128, shell=shell_b, slot=0,
                          tenant="gold")
    oracle = ServingEngine(cfg, params, MMU(MMUConfig(page_size=PAGE,
                                                      n_pages=POOL)),
                           max_batch=3, max_len=128)

    # bronze tenant hammers shell B's OTHER slot for the whole demo
    shell_b.register_tenant("bronze", 1.0, slots=(1,))
    shell_b.load_app(1, make_passthrough_artifact())
    bronze_port = shell_b.attach(1)
    bronze_stop = threading.Event()
    bronze_lat = []

    def bronze_driver():
        while not bronze_stop.is_set():
            t0 = time.perf_counter()
            comp = bronze_port.submit(Invocation.from_sg(SgEntry(
                src=np.zeros(512, np.uint8), length=512,
                opcode=Oper.LOCAL_TRANSFER))).result(timeout=30.0)
            assert comp.ok
            bronze_lat.append(time.perf_counter() - t0)
    bronze = threading.Thread(target=bronze_driver)
    bronze.start()

    prompts = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
               (list(range(3, 12)), 1.3)]
    for p, temp in prompts:
        eng_a.submit(p, max_new_tokens=16, temperature=temp)
        oracle.submit(p, max_new_tokens=16, temperature=temp)
    for _ in range(5):                      # decode a few steps on A
        eng_a.step()
        oracle.step()
    mid = {r.rid: len(r.out_tokens) for r in eng_a.slots if r}
    print(f"tenant 'gold' live on shell A: {len(mid)} requests, "
          f"{sum(mid.values())} tokens decoded so far")

    # ---- the migration -----------------------------------------------------
    report = migrate(shell_a, shell_b, "gold")
    print(f"\nmigrated A -> B: {report.n_requests} in-flight requests, "
          f"{report.n_pages} KV pages, "
          f"{report.payload_bytes / 1e6:.2f} MB snapshot")
    print(f"  downtime      {report.downtime_s * 1e3:8.2f} ms   "
          f"(quiesce {report.quiesce_s * 1e3:.2f} / "
          f"snapshot {report.snapshot_s * 1e3:.2f} / "
          f"restore {report.restore_s * 1e3:.2f} / "
          f"replay {report.replay_s * 1e3:.2f})")

    # ---- continuity proof --------------------------------------------------
    while eng_b.pending():
        eng_b.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng_b.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want, "migrated decode diverged from the oracle"
    print(f"\ncontinuity: {len(got)} requests completed on shell B, "
          "token-for-token identical to the unmigrated oracle")
    for rid, toks in sorted(got.items()):
        print(f"  rid {rid}: ...{toks[-6:]}")
    assert shell_a.services.get("mmu").utilization()["pages_used"] == 0
    print("shell A pages fully released")

    bronze_stop.set()
    bronze.join()
    lat = np.asarray(bronze_lat) * 1e3
    stats = shell_b.scheduler.stats()["tenants"]["bronze"]
    assert stats["intake_stalls"] == 0
    print(f"bronze bystander on shell B: {len(lat)} requests, "
          f"p99 {np.percentile(lat, 99):.2f} ms, "
          f"{stats['intake_stalls']} stalls (undisturbed)")
    shell_a.drain()
    shell_b.drain()
    shell_a.close()
    shell_b.close()
    print("OK")


if __name__ == "__main__":
    main()
