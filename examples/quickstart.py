"""Quickstart: deploy a neural network through the shell in <10 lines.

The paper's Code 3 claim — GPU-like UX for FPGA-class infrastructure:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import CoyoteOverlay
from repro.core import Shell, ShellConfig
from repro.core.services import MMUConfig

# --- the <10 lines -----------------------------------------------------------
shell = Shell(ShellConfig.make(services={"mmu": MMUConfig()}))
shell.build()                                    # synthesize the shell once
overlay = CoyoteOverlay(shell, slot=0)           # the NN "overlay"
overlay.program_fpga()                           # partial reconfiguration
X = np.random.randn(1024, 593).astype(np.float32)
pred = overlay.predict(X, batch_size=256)        # streamed inference
# -----------------------------------------------------------------------------

print("predictions:", pred.shape, "| first 4:", pred[:4, 0].round(3))
print("slot status:", shell.vfpgas[0].status())
print("compile cache:", shell.static.compile_cache.stats())
