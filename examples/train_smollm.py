"""End-to-end driver: train a ~100M-parameter model for a few hundred steps.

smollm-135m at full width/depth (135M params — the deliverable's ~100M
model), shortened sequence for CPU wall-clock, with the production loop:
async checkpoints, an injected node failure + auto-restart, a straggler
host, and int8+EF gradient compression on the DP path.

    PYTHONPATH=src python examples/train_smollm.py [--steps 300]

(~2 s/step on this CPU container at seq 256/batch 8; trims to --steps 40
for a quick look.)
"""
import argparse
import json

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.services.compression import (CompressionConfig,
                                             GradCompression)
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m")          # full 135M-param config
    print(f"training {cfg.arch_id}: {cfg.n_params()/1e6:.0f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model}")
    shape = ShapeConfig("e2e", "train", args.seq_len, args.batch)
    tcfg = TrainConfig(
        steps=args.steps,
        log_every=max(args.steps // 10, 1),
        ckpt_every=max(args.steps // 4, 10),
        ckpt_dir="/tmp/coyote_e2e_smollm",
        fail_at_step=args.steps // 2,        # injected failure -> restart
        straggler_steps=(args.steps // 3,),  # one slow host batch
        straggler_delay_s=1.0,
        batch_timeout_s=0.5,
        compression=GradCompression(CompressionConfig(bits=8)),
        opt=AdamWConfig(lr=6e-4, warmup_steps=10, total_steps=args.steps),
        seed=0)
    trainer = Trainer(cfg, shape, tcfg)
    result = trainer.run()
    print(json.dumps(result, indent=1))
    print("loss curve:", [round(m["loss"], 3) for m in trainer.metrics_log])
    assert result["restarts"] == 1, "failure injection should trigger once"
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} ({'OK: decreasing' if last < first else 'WARN'})")


if __name__ == "__main__":
    main()
