"""Scenario: LLM serving with batched requests on the paged-KV MMU.

The paper's LLM-decode observation (Fig 1) end-to-end: requests from
multiple cThreads share one decode pipeline; the MMU pages the KV cache
(variable page size), pages fault/evict under pressure, and continuous
batching keeps the pipeline full.

    PYTHONPATH=src python examples/serve_paged.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

cfg = get_config("smollm-135m").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

# deliberately tight page pool: exercises fault/evict under pressure
mmu = MMU(MMUConfig(page_size=16, n_pages=96, tlb_entries=32, tlb_assoc=4))
engine = ServingEngine(cfg, params, mmu, max_batch=4, max_len=128)

rng = np.random.RandomState(0)
for i in range(10):
    plen = int(rng.randint(5, 40))
    engine.submit(rng.randint(3, cfg.vocab_size, plen).tolist(),
                  max_new_tokens=int(rng.randint(4, 16)),
                  temperature=0.0 if i % 2 else 0.8, tid=i)

stats = engine.run()
print("engine:", {k: (round(v, 2) if isinstance(v, float) else v)
                  for k, v in stats.items()})
print("mmu:", mmu.utilization())
for r in engine.completed[:3]:
    print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
assert stats["completed"] == 10
assert mmu.utilization()["pages_used"] == 0, "all pages must be freed"
print("OK: all requests served, pages reclaimed")
