"""Self-healing shell: watchdog detects a wedged slot, recovers it
KV-intact, and decoding resumes token-for-token.

One shell serves tenant "gold" (paged LM decode, greedy AND sampled
rows).  Mid-decode we arm a seeded fault plan — an IO error fails a
billed decode-IO future with a typed PortError, and a page-fault storm
churns KV pages through the evict-with-copy pager — then the slot goes
silent while it still has pending work.  ``Shell.check_health`` flags it
WEDGED (stale heartbeat + pending work) and recovers it in place:
quiesce, snapshot through the migration container, cold-reset the
device soft state, restore the KV pages, replay held invocations.  A
fault-free oracle proves continuity: token-for-token identical output.

Run: PYTHONPATH=src python examples/fault_recovery.py
Exits non-zero on any lost, duplicated, or diverged completion.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (FaultKind, FaultPlan, FaultSpec, Invocation,
                        Shell, ShellConfig)
from repro.core.port import PortError
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

PAGE, POOL = 16, 128


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    shell = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL,
                                   host_pool_pages=POOL)},
        n_vfpgas=2))
    shell.build()
    eng = ServingEngine(cfg, params, shell.services.get("mmu"),
                        max_batch=3, max_len=128, shell=shell, slot=0,
                        tenant="gold")
    oracle = ServingEngine(cfg, params, MMU(MMUConfig(page_size=PAGE,
                                                      n_pages=POOL)),
                           max_batch=3, max_len=128)
    reqs = [(list(range(3, 27)), 0.0), (list(range(3, 40)), 0.0),
            (list(range(3, 20)), 1.3)]
    for prompt, temp in reqs:
        eng.submit(prompt, max_new_tokens=24, temperature=temp)
        oracle.submit(prompt, max_new_tokens=24, temperature=temp)
    for _ in range(4):
        eng.step()
        oracle.step()
    print(f"[fault] mid-decode: {eng.active} live rows, "
          f"{shell.services.get('mmu').utilization()['pages_used']} KV "
          "pages")

    # -- a seeded storm: typed IO failure + page-fault churn ----------------
    plan = FaultPlan([FaultSpec(FaultKind.IO_ERROR, tenant="gold"),
                      FaultSpec(FaultKind.PAGE_FAULT_STORM, count=4)],
                     seed=7)
    shell.set_fault_plan(plan)
    try:
        eng.port.submit(Invocation.io(64, tenant="gold")).result(
            timeout=10.0)
        raise SystemExit("armed IO fault did not fire")
    except PortError as e:
        print(f"[fault] typed failure propagated: kind={e.kind} "
              f"slot={e.slot} tenant={e.tenant} retryable={e.retryable}")
    for _ in range(PAGE + 2):             # storm churns pages mid-decode:
        eng.step()                        # every row crosses a page
        oracle.step()                     # boundary, so the pager probes
    shell.set_fault_plan(None)
    print(f"[fault] plan fired {plan.stats()['fired_total']} fault(s); "
          f"mmu page_faults={shell.services.get('mmu').page_faults}")

    # -- the slot goes quiet with work pending: watchdog flags + heals ------
    shell.health.heartbeat_timeout_s = 0.05
    time.sleep(0.12)                      # heartbeat goes stale
    res = shell.check_health(auto_recover=True)
    if res["wedged"] != [0] or res["recovered"] != [0]:
        raise SystemExit(f"watchdog did not recover the slot: {res}")
    ev = [e for e in shell.health.status()["events"]
          if e["event"] == "recovery"][-1]
    print(f"[heal] slot 0 recovered in {ev['downtime_s'] * 1e3:.1f} ms "
          "(quiesce -> CYBS snapshot -> cold reset -> KV restore)")

    while eng.pending():
        eng.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    if got != want:
        raise SystemExit("DIVERGED: recovered tenant != fault-free oracle")
    st = shell.attach(0).stats()
    if st["submitted"] != st["completed"] + st["failed"]:
        raise SystemExit(f"lost/dup completions: {st}")
    h = shell.status()["health"]
    print(f"[ok] token-for-token parity across recovery "
          f"({sum(len(t) for t in got.values())} tokens, "
          f"{len(got)} requests); faults_total={h['faults_total']} "
          f"recoveries={h['recoveries']}")
    shell.close()


if __name__ == "__main__":
    main()
