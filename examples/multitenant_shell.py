"""Scenario: one shell, three tenants, live reconfiguration.

Walks the paper's headline features in one script:
  1. build a shell with MMU + AES + sniffer services;
  2. load three different apps into three vFPGA slots (AES-ECB tenant,
     HyperLogLog tenant, vector-add tenant);
  3. run cThread traffic through the credit-scheduled link while the
     sniffer captures packets;
  4. hot-swap ONE app (partial reconfiguration) while the others stay
     loaded;
  5. reconfigure the SHELL (drop the sniffer) without stranding any app;
  6. print the capture + fairness + status reports;
  7. weighted QoS: a gold tenant (weight 3) and a bronze tenant (weight 1)
     saturate the link through the shell scheduler — the contended byte
     split lands at ~3:1 and per-tenant Jain's indices come out of
     Shell.status().

    PYTHONPATH=src python examples/multitenant_shell.py
"""
import numpy as np

from repro.apps import (make_aes_artifact, make_hll_artifact,
                        make_passthrough_artifact, make_vector_add_artifact)
from repro.core import Alloc, Oper, SgEntry, Shell, ShellConfig
from repro.core.credits import jains_index, weighted_jains_index
from repro.core.services import (AESConfig, MMUConfig, SnifferConfig)
from repro.core.services.sniffer import CSR_SNIFFER_ENABLE

# 1. build
shell = Shell(ShellConfig.make(services={
    "mmu": MMUConfig(page_size=256, n_pages=512),
    "encryption": AESConfig(),
    "sniffer": SnifferConfig(headers_only=False),
}, n_vfpgas=3))
report = shell.build()
print(f"shell built in {report.total_s:.2f}s:",
      sorted(report.components))

# 2. three tenants
shell.load_app(0, make_aes_artifact("ecb"))
shell.load_app(1, make_hll_artifact())
shell.load_app(2, make_vector_add_artifact())
sniffer = shell.services.get("sniffer")
sniffer.csr.set_csr(1, CSR_SNIFFER_ENABLE)       # start capture via CSR

# 3. concurrent traffic
threads = [shell.attach_thread(i, pid=100 + i) for i in range(3)]
bufs = []
for ct in threads:
    src = ct.getMem((Alloc.HPF, 64 << 10))
    src[:] = np.random.RandomState(ct.tid).randint(0, 255, src.size,
                                                   dtype=np.uint8)
    bufs.append(src)
    ct.invoke(Oper.LOCAL_TRANSFER,
              SgEntry(src=ct.vaddr_of(src), length=src.size), wait=False)
shell.drain()
shares = shell.arbiter.fairness()
print(f"fair shares: { {k: round(v, 3) for k, v in shares.items()} } "
      f"jain={jains_index(shares):.4f}")

# 4. app hot-swap: replace the vector-add tenant, others untouched
stats = shell.reconfigure_app(2, make_passthrough_artifact())
print(f"app hot-swap: {stats['kernel_s']*1e3:.1f} ms "
      f"(cache_hit={bool(stats['compile_cache_hit'])}); "
      f"slot0 still: {shell.vfpgas[0].app.name}")

# 5. shell reconfig: drop the sniffer (scenario #3 of Table 3)
lat = shell.reconfigure_shell(ShellConfig.make(services={
    "mmu": MMUConfig(page_size=256, n_pages=512),
    "encryption": AESConfig(),
}, n_vfpgas=3))
print(f"shell reconfig (sniffer off): kernel {lat['kernel_s']*1e3:.1f} ms; "
      f"services now: {shell.services.names()}")

# 6. reports
records = sniffer.to_records()
print(f"sniffer captured {len(records)} packets; first 3:")
for r in records[:3]:
    print("  ", r)
print("final status:", {k: v for k, v in shell.status().items()
                        if k in ("fairness", "link_bytes")})

# 7. weighted QoS: gold tenant gets a 3x bandwidth share over bronze
qos = Shell(ShellConfig.make(services={}, n_vfpgas=2))
qos.build()
qos.register_tenant("gold", 3.0, slots=(0,))
qos.register_tenant("bronze", 1.0, slots=(1,))
events = []
qos.static.pcie.on_event(
    lambda ev: events.append((ev.t, ev.src.split("/", 1)[0], ev.nbytes)))
gold, bronze = qos.attach_thread(0, pid=200), qos.attach_thread(1, pid=201)
qos.scheduler.pause()                  # queue demand first -> saturation
for ct in (gold, bronze):
    for _ in range(24):
        buf = ct.getMem((Alloc.REG, 64 << 10))
        ct.invoke(Oper.LOCAL_TRANSFER,
                  SgEntry(src=ct.vaddr_of(buf), length=buf.size),
                  wait=False)
qos.scheduler.resume()
qos.drain()
finish = {}
for t, ten, _ in events:
    finish[ten] = t
t_star = min(finish.values())
moved = {"gold": 0, "bronze": 0}
for t, ten, nb in events:
    if t <= t_star:
        moved[ten] += nb
sched = qos.status()["scheduler"]
ctot = sum(moved.values())
contended_jain = weighted_jains_index(
    {k: v / ctot for k, v in moved.items()}, {"gold": 3.0, "bronze": 1.0})
print(f"weighted QoS (3:1): contended split "
      f"{moved['gold'] / max(moved['bronze'], 1):.2f}:1, "
      f"contended jain_weighted={contended_jain:.4f} "
      f"(drained-total jain_weighted={sched['jain_weighted']:.4f})")
for name, t in sorted(sched["tenants"].items()):
    print(f"  {name}: share={t['share']:.3f} weight={t['weight']:g} "
          f"mean_latency={t['mean_latency_s'] * 1e3:.2f}ms "
          f"batches={t['batches']}")
qos.close()
