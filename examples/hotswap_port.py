"""Drain-aware hot-swap through the unified Port API (Port API v2).

Two tenants drive two slots through ``port.submit`` while slot 0 is
hot-swapped from AES-ECB to HyperLogLog mid-traffic.  The demo prints the
swap timings, the hold-and-replay counts, and verifies the two invariants
the API guarantees:

  * zero lost / duplicated completions across the swap boundary;
  * the OTHER tenant's traffic never pauses and never stalls.

Run: PYTHONPATH=src python examples/hotswap_port.py
"""
import threading
import time

import numpy as np

from repro.apps import make_aes_artifact, make_hll_artifact
from repro.core import Invocation, Oper, SgEntry, Shell, ShellConfig
from repro.core.services import AESConfig, MMUConfig


def main() -> None:
    shell = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=64, n_pages=256),
                  "encryption": AESConfig()},
        n_vfpgas=2))
    shell.build()
    shell.register_tenant("gold", 2.0, slots=(0,))
    shell.register_tenant("bronze", 1.0, slots=(1,))
    shell.load_app(0, make_aes_artifact("ecb"))
    shell.load_app(1, make_aes_artifact("cbc"))

    gold, bronze = shell.attach(0), shell.attach(1)
    caps = gold.capabilities()
    print(f"slot0 capabilities: name={caps.name} streams={caps.streams} "
          f"csr_map={dict(caps.csr_map)} mem_model={caps.mem_model}")

    n = 150
    futs = {"gold": [], "bronze": []}

    def drive(port, key):
        for i in range(n):
            buf = (np.arange(256, dtype=np.uint32) + i).view(np.uint8)
            futs[key].append(port.submit(Invocation.from_sg(SgEntry(
                src=buf, length=buf.size, opcode=Oper.KERNEL))))

    threads = [threading.Thread(target=drive, args=(gold, "gold")),
               threading.Thread(target=drive, args=(bronze, "bronze"))]
    for t in threads:
        t.start()
    time.sleep(0.005)                      # let traffic get in flight

    # ---- the hot-swap: AES-ECB -> HLL, mid-traffic ----------------------
    stats = shell.reconfigure(0, make_hll_artifact())
    for t in threads:
        t.join()

    comps_g = [f.result(timeout=30.0) for f in futs["gold"]]
    comps_b = [f.result(timeout=30.0) for f in futs["bronze"]]
    assert len(comps_g) == n and all(c.ok for c in comps_g)
    assert len(comps_b) == n and all(c.ok for c in comps_b)
    ps = gold.stats()
    assert ps["submitted"] == ps["completed"] == n
    bs = shell.scheduler.stats()["tenants"]["bronze"]
    assert bs["completions"] == n and bs["intake_stalls"] == 0

    print(f"\nhot-swap aes_ecb -> hll on busy slot 0:")
    print(f"  drain_s={stats['drain_s']*1e3:.2f} ms  "
          f"load kernel_s={stats['kernel_s']*1e3:.2f} ms  "
          f"total_s={stats['total_s']*1e3:.2f} ms")
    print(f"  invocations held+replayed on new logic: "
          f"{int(stats['replayed'])}/{n}")
    print(f"  gold: {ps['submitted']} submitted -> "
          f"{ps['completed']} completed (zero lost/dup)")
    print(f"  bronze (untouched tenant): {bs['completions']}/{n} done, "
          f"{bs['intake_stalls']} stalls, "
          f"mean latency {bs['mean_latency_s']*1e3:.2f} ms")
    # the HLL results only exist for replayed invocations — the swap
    # boundary is visible in the completion payloads, not in their count
    hll_like = sum(1 for c in comps_g if np.isscalar(c.result)
                   or getattr(c.result, "ndim", 1) == 0)
    print(f"  completions executed by new logic (HLL estimates): "
          f"{hll_like}")
    shell.drain()
    shell.close()
    print("OK")


if __name__ == "__main__":
    main()
