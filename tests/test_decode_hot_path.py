"""Device-resident decode hot path: retrace guard, zero logits transfer,
Pallas-vs-ref engine parity across slot churn, incremental block tables,
drop-mode prefill scatter, fused sampling vs the host oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.paged_model import (TRACE_COUNTS, decode_step_paged,
                                     make_pools, write_prefill)
from repro.serve.sampler import sample_per_row


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _run_engine(cfg, params, *, use_pallas, prompts, new_tokens=4,
                max_batch=2, page=16):
    mmu = MMU(MMUConfig(page_size=page, n_pages=128))
    eng = ServingEngine(cfg, params, mmu, max_batch=max_batch, max_len=128,
                        use_pallas=use_pallas)
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    eng.run()
    return {r.rid: r.out_tokens for r in eng.completed}


# ------------------------------------------------------- retrace guard ----
def test_decode_compiles_exactly_once_across_occupancy_changes(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    # max_len 144 -> max_pages 9: a (batch, table) shape unique to this
    # test, so the process-global jit cache cannot have compiled it yet
    # and the single-trace assertion is order-independent.
    eng = ServingEngine(cfg, params, mmu, max_batch=3, max_len=144)
    # wave 1: partial occupancy
    eng.submit(list(range(3, 10)), max_new_tokens=4)
    eng.submit(list(range(3, 20)), max_new_tokens=6)
    before = TRACE_COUNTS.get("decode_step_paged", 0)
    for _ in range(3):
        eng.step()
    # wave 2: occupancy changes mid-run (slots refill, lens cross pages)
    eng.submit(list(range(3, 36)), max_new_tokens=5)
    eng.submit(list(range(3, 8)), max_new_tokens=3)
    eng.run()
    assert len(eng.completed) == 4
    assert TRACE_COUNTS["decode_step_paged"] - before == 1, \
        "decode_step_paged must compile exactly once per engine shape"


def test_prefill_is_batched_one_forward_per_admit_wave(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    # max_len 144 keeps this prefill bucket shape unique to this test
    # (see the retrace-guard note above)
    eng = ServingEngine(cfg, params, mmu, max_batch=4, max_len=144)
    for n in (5, 9, 12, 7):
        eng.submit(list(range(3, 3 + n)), max_new_tokens=2)
    before = TRACE_COUNTS.get("prefill_shared_paged", 0)
    eng.step()      # admits all 4 -> ONE batched prefill trace/call
    assert TRACE_COUNTS.get("prefill_shared_paged", 0) - before == 1
    assert all(len(r.out_tokens) >= 1 for r in eng.slots if r is not None)
    eng.run()
    assert len(eng.completed) == 4


def test_prompt_longer_than_max_len_completes_from_prefill(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=32)
    eng.submit(list(range(3, 3 + 40)), max_new_tokens=4)   # 40 > max_len
    eng.submit(list(range(3, 3 + 7)), max_new_tokens=3)
    stats = eng.run()
    assert stats["completed"] == 2
    long_req = next(r for r in eng.completed if len(r.prompt) == 40)
    assert len(long_req.out_tokens) == 1       # no decode budget left
    assert mmu.utilization()["pages_used"] == 0


# ----------------------------------------- only a (B,) vector crosses ----
def test_decode_step_outputs_no_logits(served):
    cfg, params = served
    b, maxp, n_pages, page = 4, 8, 64, 16
    pools = make_pools(cfg, n_pages, page)
    out = jax.eval_shape(
        lambda pr, po, t, l, lt, r, tp: decode_step_paged(
            pr, po, t, l, lt, r, tp, cfg=cfg, page_size=page),
        params, pools,
        jax.ShapeDtypeStruct((b, maxp), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.eval_shape(lambda: jax.random.PRNGKey(0)),
        jax.ShapeDtypeStruct((b,), jnp.float32))
    toks, new_pools, new_lens, _ = out
    assert toks.shape == (b,) and toks.dtype == jnp.int32
    assert new_lens.shape == (b,)
    # nothing vocab-shaped leaves the step: logits stay on device
    for leaf in jax.tree.leaves(out):
        assert cfg.vocab_size not in leaf.shape


# ------------------------------------- pallas == ref through the engine ----
def test_pallas_engine_matches_ref_engine_with_slot_churn(served):
    """Greedy decode through the Pallas kernel == jnp oracle, across
    continuous batching with slots freed and refilled mid-run and lens
    crossing page boundaries."""
    cfg, params = served
    # 5 requests through 2 slots -> churn; prompt 16 lands exactly on a
    # page boundary (page_size=16)
    prompts = [list(range(3, 3 + n)) for n in (16, 5, 12, 9, 17)]
    ref = _run_engine(cfg, params, use_pallas=False, prompts=prompts)
    pal = _run_engine(cfg, params, use_pallas=True, prompts=prompts)
    assert ref == pal


# ----------------------------------------------- incremental tables ----
def test_device_block_table_is_incremental():
    mmu = MMU(MMUConfig(page_size=4, n_pages=64))
    bt = mmu.block_table_device(n_slots=2, max_pages=8)
    mmu.alloc_seq(1, 6)                      # 2 pages
    bt.bind(0, 1)
    t0 = np.asarray(bt.device_view())
    np.testing.assert_array_equal(t0[0], mmu.block_table([1], 8)[0])
    assert t0[1][0] == -1
    up0 = bt.row_uploads
    # steady state within a page: repeated views are pure cache hits
    mmu.extend_seq(1, 1)                     # 7 tokens, still 2 pages
    for _ in range(3):
        bt.device_view()
    assert bt.row_uploads == up0
    assert bt.hits >= 3
    # page-boundary crossing dirties exactly one row
    mmu.extend_seq(1, 2)                     # 9 tokens -> 3rd page
    t1 = np.asarray(bt.device_view())
    assert bt.row_uploads == up0 + 1
    np.testing.assert_array_equal(t1[0], mmu.block_table([1], 8)[0])
    # free + unbind clears the row
    mmu.free_seq(1)
    bt.unbind(0)
    t2 = np.asarray(bt.device_view())
    assert (t2[0] == -1).all()


def test_device_block_table_tracks_eviction():
    mmu = MMU(MMUConfig(page_size=4, n_pages=4, host_pool_pages=16))
    bt = mmu.block_table_device(n_slots=2, max_pages=8)
    mmu.alloc_seq(1, 12)                     # 3 of 4 pages
    bt.bind(0, 1)
    bt.device_view()
    mmu.alloc_seq(2, 8)                      # forces eviction of seq 1 tail
    bt.bind(1, 2)
    t = np.asarray(bt.device_view())
    host = mmu.block_table([1, 2], 8)
    np.testing.assert_array_equal(t, host)
    assert (t[0] == -1).sum() >= 6           # evicted tail page shows as -1


# ------------------------------------------------ drop-mode scatter ----
def test_write_prefill_drops_invalid_writes(served):
    cfg, _ = served
    n_pages, page, b, s = 8, 4, 2, 10
    hd = cfg.resolved_head_dim
    kh = cfg.n_kv_heads
    L = cfg.n_layers
    sentinel = 7.5
    pools = {k: jnp.full((L * n_pages, page, kh, hd), sentinel)
             for k in ("k", "v")}
    ks = jax.random.normal(jax.random.PRNGKey(0), (L, b, s, kh, hd))
    vs = ks + 1.0
    tables = jnp.asarray([[2, 5, 1, -1], [6, -1, -1, -1]], jnp.int32)
    lens = jnp.asarray([10, 3], jnp.int32)
    out = write_prefill(pools, (ks, vs), tables, lens, page)
    # flat layout: layer l's page p lives at slot l*n_pages + p
    outk = np.asarray(out["k"]).reshape(L, n_pages, page, kh, hd)
    # mapped positions hold the prefill KV
    np.testing.assert_allclose(outk[:, 2], np.asarray(ks[:, 0, 0:4]))
    np.testing.assert_allclose(outk[:, 5], np.asarray(ks[:, 0, 4:8]))
    np.testing.assert_allclose(outk[:, 6, :3], np.asarray(ks[:, 1, 0:3]))
    # row 0 page 1 (vpage 2) holds tokens 8..9 only; offsets 2..3 untouched
    np.testing.assert_allclose(outk[:, 1, :2], np.asarray(ks[:, 0, 8:10]))
    assert (outk[:, 1, 2:] == sentinel).all()
    # rows' padding (beyond lens) and unmapped pages never get written:
    # every untouched pool page still holds the sentinel
    for pg in (0, 3, 4, 7):
        assert (outk[:, pg] == sentinel).all(), f"page {pg} was clobbered"
    assert (outk[:, 6, 3:] == sentinel).all()


# ------------------------------------------------------ fused sampler ----
def test_sample_per_row_matches_host_oracle():
    rng = jax.random.PRNGKey(0)
    logits = jax.random.normal(jax.random.PRNGKey(1), (6, 33)) * 3.0
    # greedy rows == argmax; near-zero temperature converges to argmax
    temps = jnp.asarray([0.0, -1.0, 1e-4, 1e-4, 0.0, 1e-4])
    toks = np.asarray(sample_per_row(rng, logits, temps))
    np.testing.assert_array_equal(
        toks, np.argmax(np.asarray(logits), axis=-1))
    # hot rows: valid token range, and temperature actually randomizes
    temps = jnp.full((6,), 2.0)
    draws = {tuple(np.asarray(sample_per_row(jax.random.PRNGKey(s),
                                             logits, temps)))
             for s in range(8)}
    assert len(draws) > 1
    for d in draws:
        assert all(0 <= t < 33 for t in d)


def test_engine_temperature_uses_device_sampler(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=1, max_len=64, seed=3)
    eng.submit(list(range(3, 12)), max_new_tokens=8, temperature=1.5)
    eng.run()
    sampled = eng.completed[0].out_tokens
    assert all(0 <= t < cfg.vocab_size for t in sampled)
    # host oracle is exposed for cross-checks and stays vectorized
    fake = np.zeros((4, cfg.vocab_size), np.float32)
    fake[:, 5] = 100.0
    np.testing.assert_array_equal(eng._sample(fake, 0.0), [5, 5, 5, 5])
    assert eng._sample(fake, 1.0).shape == (4,)


def test_engine_per_request_topk1_matches_greedy_stream(served):
    """Per-request sampler filters: a top_k=1 request at high temperature
    is deterministic and must emit exactly the greedy token stream, while
    sharing the batch with a plain greedy request (no cross-row leak)."""
    cfg, params = served
    prompts = [list(range(3, 12)), list(range(4, 13))]

    def run(**kw):
        mmu = MMU(MMUConfig(page_size=16, n_pages=128))
        eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=96)
        eng.submit(prompts[0], max_new_tokens=6, **kw)
        eng.submit(prompts[1], max_new_tokens=6)
        eng.run()
        return {tuple(r.prompt): r.out_tokens for r in eng.completed}

    greedy = run()
    hot_k1 = run(temperature=5.0, top_k=1)
    assert hot_k1[tuple(prompts[0])] == greedy[tuple(prompts[0])]
    assert hot_k1[tuple(prompts[1])] == greedy[tuple(prompts[1])]


def test_engine_per_request_filters_keep_single_trace(served):
    """Adding per-request top-k/top-p must not break the retrace guard:
    decode still compiles once per engine shape across filter churn."""
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    # max_len 160 -> a table shape unique to this test
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=160)
    eng.submit(list(range(3, 10)), max_new_tokens=3)
    before = TRACE_COUNTS.get("decode_step_paged", 0)
    eng.step()
    eng.submit(list(range(3, 14)), max_new_tokens=3,
               temperature=2.0, top_k=4, top_p=0.8)   # filters switch ON
    eng.run()
    assert TRACE_COUNTS["decode_step_paged"] - before == 1
    assert len(eng.completed) == 2
