"""Port API v2: one typed async interface for apps, services, and the
serving engine; drain-aware hot-swap; safe bitstream format."""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.apps import (make_aes_artifact, make_hll_artifact,
                        make_passthrough_artifact,
                        make_vector_add_artifact)
from repro.core import (Alloc, AppArtifact, Invocation, Oper, PortState,
                        SgEntry, Shell, ShellConfig)
from repro.core.bitstream import BitstreamError
from repro.core.services import (AESConfig, CollectiveConfig,
                                 CompressionConfig, MMUConfig,
                                 SnifferConfig)

ALL_SERVICES = {"mmu": MMUConfig(page_size=64, n_pages=64),
                "encryption": AESConfig(),
                "compression": CompressionConfig(),
                "collectives": CollectiveConfig(),
                "sniffer": SnifferConfig()}


def _shell(**kw):
    services = kw.pop("services", {"mmu": MMUConfig(page_size=64,
                                                    n_pages=64),
                                   "encryption": AESConfig()})
    s = Shell(ShellConfig.make(services=services, **kw))
    s.build()
    return s


# ========================================================= app ports =======
def test_port_submit_transfer_roundtrip():
    shell = _shell()
    shell.load_app(0, make_passthrough_artifact())
    port = shell.attach(0)
    src = np.arange(4096, dtype=np.uint8) % 251
    dst = np.zeros(4096, np.uint8)
    fut = port.submit(Invocation.from_sg(SgEntry(
        src=src, dst=dst, length=4096, opcode=Oper.LOCAL_TRANSFER)))
    comp = fut.result(timeout=30.0)
    assert comp.ok
    assert (src == dst).all()
    # completions still land on the legacy CQ (writeback counter)
    assert shell.vfpgas[0].iface.cq_read.writeback_counter >= 1
    assert port.stats()["completed"] == 1


def test_port_capabilities_registered_at_attach():
    shell = _shell()
    shell.load_app(0, make_aes_artifact("ecb"))
    shell.attach(0)
    ports = shell.status()["ports"]
    caps = ports["vfpga0"]["capabilities"]
    assert caps["csr_map"] == {"key_lo": 0, "key_hi": 1}
    assert caps["kind"] == "app"
    assert caps["mem_model"] == "host"


def test_all_five_apps_expose_capability_descriptors():
    from repro.apps.lm_serving import make_lm_serving_artifact
    from repro.apps.nn_inference import CoyoteOverlay, make_nn_artifact
    arts = [make_aes_artifact("ecb"), make_hll_artifact(),
            make_vector_add_artifact()]
    shell = _shell(n_vfpgas=1)
    arts.append(make_nn_artifact(CoyoteOverlay(shell)))
    # lm_serving needs a model config; the descriptor alone is cheap
    from repro.configs import get_config
    cfg = get_config("smollm-135m").reduced()
    arts.append(make_lm_serving_artifact(cfg, params=None))
    for art in arts:
        caps = art.capabilities
        assert caps is not None, art.name
        assert caps.kind == "app"
        assert caps.streams >= 1
        assert caps.mem_model in ("host", "paged", "device")
    lm = arts[-1].capabilities
    assert {"temperature_milli", "max_new_tokens",
            "top_k", "top_p_milli"} <= set(lm.csr_map)


def test_apps_route_through_port_submit():
    """aes / hll / vector_add invoked through the one port surface."""
    shell = _shell()
    # aes_ecb
    shell.load_app(0, make_aes_artifact("ecb"))
    port = shell.attach(0)
    data = np.arange(64, dtype=np.uint8)
    comp = port.submit(Invocation.from_sg(SgEntry(
        src=data, length=64, opcode=Oper.KERNEL))).result(30.0)
    assert comp.ok and np.asarray(comp.result).size >= 64
    # hll
    shell.reconfigure(0, make_hll_artifact())
    items = np.arange(1000, dtype=np.uint32).view(np.uint8)
    comp = port.submit(Invocation.from_sg(SgEntry(
        src=items, length=items.size, opcode=Oper.KERNEL))).result(30.0)
    assert comp.ok
    assert abs(comp.result - 1000) / 1000 < 0.15    # HLL estimate
    # vector_add (direct two-array form rides the streams)
    shell.reconfigure(1, make_vector_add_artifact())
    p1 = shell.attach(1)
    from repro.core.interfaces import Packet
    a = np.ones(8, np.float32)
    b = np.full(8, 2.0, np.float32)
    iface = shell.vfpgas[1].iface
    iface.host_in[0].push(Packet(tid=0, seq_no=0, payload=a,
                                 nbytes=a.nbytes, last=True))
    iface.host_in[1].push(Packet(tid=0, seq_no=0, payload=b,
                                 nbytes=b.nbytes, last=True))
    comp = p1.submit(Invocation.from_sg(SgEntry(
        src=None, length=a.nbytes, opcode=Oper.KERNEL))).result(30.0)
    assert comp.ok
    np.testing.assert_allclose(np.asarray(comp.result), a + b)
    shell.close()


def test_port_future_carries_failure_not_exception():
    shell = _shell()

    def bad_app(iface, vfpga, x):
        raise ValueError("malformed data")
    shell.load_app(0, AppArtifact(name="bad", fn=bad_app))
    comp = shell.attach(0).submit(Invocation.from_sg(SgEntry(
        src=np.zeros(16, np.uint8), length=16,
        opcode=Oper.LOCAL_TRANSFER))).result(30.0)
    assert not comp.ok
    assert isinstance(comp.result, ValueError)
    shell.close()


# ===================================================== service ports =======
def test_all_five_services_route_through_port_submit():
    shell = _shell(services=dict(ALL_SERVICES))
    # mmu: allocate, inspect, free — through the port
    mmu_port = shell.attach("mmu")
    assert mmu_port.submit(Invocation.call("alloc_seq", 7, 128)
                           ).result(30.0).ok
    comp = mmu_port.submit(Invocation.call("utilization")).result(30.0)
    assert comp.ok and comp.result["pages_used"] == 2
    assert mmu_port.submit(Invocation.call("free_seq", 7)).result(30.0).ok
    # encryption
    blocks = jnp.zeros((4, 16), jnp.uint8)
    comp = shell.attach("encryption").submit(
        Invocation.call("encrypt", blocks)).result(30.0)
    assert comp.ok and np.asarray(comp.result).shape == (4, 16)
    # compression
    g = jnp.arange(512, dtype=jnp.float32)
    comp = shell.attach("compression").submit(
        Invocation.call("compress_leaf", g)).result(30.0)
    assert comp.ok
    # collectives
    comp = shell.attach("collectives").submit(
        Invocation.call("wire_bytes", "flat", 1 << 20, 8, 2)).result(30.0)
    assert comp.ok and comp.result["intra"] > 0
    # sniffer: start through the port, see bytes move, read records
    sn = shell.attach("sniffer")
    assert sn.submit(Invocation.call("start")).result(30.0).ok
    shell.load_app(0, make_passthrough_artifact())
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 8192))
    ct.invoke(Oper.LOCAL_TRANSFER,
              SgEntry(src=ct.vaddr_of(buf), length=8192), timeout=30.0)
    comp = sn.submit(Invocation.call("to_records")).result(30.0)
    assert comp.ok and len(comp.result) >= 1
    # the service ports registered their capability descriptors
    ports = shell.status()["ports"]
    for name in ALL_SERVICES:
        assert name in ports, name
        assert ports[name]["capabilities"]["kind"] == "service"
    shell.close()


def test_service_port_rejects_undeclared_method():
    shell = _shell(services=dict(ALL_SERVICES))
    comp = shell.attach("mmu").submit(
        Invocation.call("_init_pools")).result(30.0)
    assert not comp.ok
    assert "does not expose" in str(comp.result)
    shell.close()


def test_service_port_billing_lands_on_scheduler():
    shell = _shell(services=dict(ALL_SERVICES))
    port = shell.attach("mmu", tenant="mgmt")
    assert port.submit(Invocation.call("utilization",
                                       nbytes=4096)).result(30.0).ok
    shell.drain()
    stats = shell.scheduler.stats()["tenants"]["mgmt"]
    assert stats["completions"] >= 1
    assert stats["bytes"] >= 4096
    shell.close()


# ============================================ drain-aware hot-swap =========
def test_reconfigure_holds_and_replays_on_new_logic():
    shell = _shell()
    seen_old, seen_new = [], []
    shell.load_app(0, AppArtifact(
        name="old", fn=lambda i, v, x: seen_old.append(1)))
    port = shell.attach(0)
    assert port.quiesce(timeout=10.0)
    assert port.state is PortState.QUIESCED
    futs = [port.submit(Invocation.from_sg(SgEntry(
        src=np.zeros(8, np.uint8), length=8, opcode=Oper.LOCAL_TRANSFER)))
        for _ in range(3)]
    assert not futs[0].done()                    # held, not lost
    assert port.held() == 3
    shell.reconfigure(0, AppArtifact(
        name="new", fn=lambda i, v, x: seen_new.append(1)))
    for f in futs:
        assert f.result(timeout=30.0).ok
    assert seen_old == [] and len(seen_new) == 3  # replayed on NEW logic
    shell.close()


@pytest.mark.parametrize("swap_mid_traffic", [True])
def test_hot_swap_mid_traffic_two_tenants_no_lost_completions(
        swap_mid_traffic):
    """Satellite acceptance: hot-swap slot 0 while both tenants drive
    traffic; zero lost/duplicated completions anywhere, and the OTHER
    tenant's traffic is unaffected (all complete, no intake stalls)."""
    shell = _shell(services={}, n_vfpgas=2)
    shell.register_tenant("gold", 2.0, slots=(0,))
    shell.register_tenant("bronze", 1.0, slots=(1,))
    executed = {"old": 0, "new": 0, "b": 0}
    lock = threading.Lock()

    def mk(tag):
        def fn(iface, vf, x):
            with lock:
                executed[tag] += 1
            return x
        return fn

    shell.load_app(0, AppArtifact(name="old", fn=mk("old")))
    shell.load_app(1, AppArtifact(name="bapp", fn=mk("b")))
    pa, pb = shell.attach(0), shell.attach(1)
    futs_a, futs_b = [], []
    n = 120

    def drive(port, futs):
        for i in range(n):
            futs.append(port.submit(Invocation.from_sg(SgEntry(
                src=np.full(64, i % 251, np.uint8), length=64,
                opcode=Oper.LOCAL_TRANSFER))))
    ta = threading.Thread(target=drive, args=(pa, futs_a))
    tb = threading.Thread(target=drive, args=(pb, futs_b))
    ta.start()
    tb.start()
    time.sleep(0.005)                       # let traffic get in flight
    stats = shell.reconfigure(0, AppArtifact(name="new", fn=mk("new")))
    ta.join()
    tb.join()
    comps_a = [f.result(timeout=30.0) for f in futs_a]
    comps_b = [f.result(timeout=30.0) for f in futs_b]
    # zero lost: every submission got exactly one completion
    assert len(comps_a) == n and all(c.ok for c in comps_a)
    assert len(comps_b) == n and all(c.ok for c in comps_b)
    # zero duplicated: execution count matches submissions exactly
    assert executed["old"] + executed["new"] == n
    assert executed["b"] == n
    assert stats["replayed"] == pa.stats()["replayed"]
    # the other tenant never drained, never stalled, finished everything
    sched = shell.scheduler.stats()["tenants"]["bronze"]
    assert sched["completions"] == n
    assert sched["intake_stalls"] == 0
    # per-port accounting is exact
    assert pa.stats()["submitted"] == pa.stats()["completed"] == n
    assert pb.stats()["submitted"] == pb.stats()["completed"] == n
    shell.drain()
    shell.close()


def test_reconfigure_preserves_csr_and_membuffers():
    shell = _shell()
    shell.load_app(0, make_passthrough_artifact())
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 512))
    buf[:] = 7
    ct.setCSR(0xBEEF, 3)
    shell.reconfigure(0, make_passthrough_artifact())
    assert ct.getCSR(3) == 0xBEEF               # CSR file restored
    vaddr = ct.vaddr_of(buf)                    # address map survived
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=vaddr, length=512), timeout=30.0)
    assert comp is not None and comp.ok
    shell.close()


def test_cthread_invoke_is_a_port_shim():
    """The legacy entry point and the port surface are the same path."""
    shell = _shell()
    shell.load_app(0, make_passthrough_artifact())
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 1024))
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(buf), length=1024),
                     timeout=30.0)
    assert comp is not None and comp.ok
    port = shell.attach(0)
    assert port.stats()["submitted"] >= 1       # billed through the port
    assert ct.port is port                      # one port per slot
    shell.close()


# ==================================================== bitstream format =====
def test_app_bitstream_roundtrip_npz(tmp_path):
    from repro.core.reconfig import load_app_bitstream, save_app_bitstream
    art = make_aes_artifact("cbc")
    p = tmp_path / "aes.cybs"
    n = save_app_bitstream(str(p), art)
    assert n > 0
    assert p.read_bytes()[:4] == b"CYBS"        # magic, not a pickle
    art2 = load_app_bitstream(str(p))
    assert art2.name == art.name and art2.fn is art.fn
    assert art2.requires[0].service == "encryption"
    assert art2.capabilities.csr_map == dict(art.capabilities.csr_map)


def test_shell_bitstream_roundtrip_with_weights(tmp_path):
    from repro.core.reconfig import (load_shell_bitstream,
                                     save_shell_bitstream)
    cfg = ShellConfig.make(services={"mmu": MMUConfig(page_size=32,
                                                      n_pages=16)},
                           n_vfpgas=2)
    w = {"layers": [{"w": np.arange(6.0).reshape(2, 3)}]}
    p = tmp_path / "shell.cybs"
    save_shell_bitstream(str(p), cfg, weights=w)
    cfg2, arrays = load_shell_bitstream(str(p))
    assert cfg2 == cfg
    np.testing.assert_allclose(arrays["layers"][0]["w"],
                               w["layers"][0]["w"])


def test_bitstream_rejects_unknown_kind_version_and_pickle(tmp_path):
    from repro.core import bitstream as B
    # unknown kind at encode AND at decode
    with pytest.raises(BitstreamError, match="unknown bitstream kind"):
        B.encode("exploit", {})
    good = B.encode("app", {"name": "x", "fn_ref": "os:getcwd"})
    tampered = good.replace(b'"kind": "app"', b'"kind": "zzz"', 1)
    with pytest.raises(BitstreamError, match="unknown bitstream kind"):
        B.decode(tampered)
    # future container version
    import struct
    future = (B.MAGIC + struct.pack("<HI", B.FORMAT_VERSION + 1, 2)
              + b"{}")
    with pytest.raises(BitstreamError, match="newer than this reader"):
        B.decode(future)
    # a legacy pickle blob is refused outright
    import pickle
    with pytest.raises(BitstreamError, match="bad magic"):
        B.decode(pickle.dumps({"kind": "app"}))
    # reconfig controller path surfaces the same errors
    from repro.core import Shell as _S  # noqa: F401  (import check only)
    p = tmp_path / "evil.bin"
    p.write_bytes(pickle.dumps({"kind": "shell"}))
    shell = _shell(services={})
    with pytest.raises(BitstreamError):
        shell.static.reconfig.load_bitstream(str(p))
    shell.close()


def test_failed_reconfigure_does_not_wedge_the_slot():
    """A swap that fails the link check must leave the port ACTIVE: held
    invocations replay on the old logic and later submits still work."""
    from repro.core.vfpga import LinkError
    shell = _shell(services={})                  # no encryption service
    shell.load_app(0, make_passthrough_artifact())
    port = shell.attach(0)
    with pytest.raises(LinkError):
        shell.reconfigure(0, make_aes_artifact("ecb"))   # requires enc
    assert port.state is PortState.ACTIVE
    comp = port.submit(Invocation.from_sg(SgEntry(
        src=np.zeros(8, np.uint8), length=8,
        opcode=Oper.LOCAL_TRANSFER))).result(timeout=30.0)
    assert comp.ok
    assert shell.vfpgas[0].app.name == "passthrough"     # old logic intact
    shell.close()


def test_port_future_completion_returns_none_on_timeout():
    shell = _shell(services={})
    shell.load_app(0, make_passthrough_artifact())
    port = shell.attach(0)
    port.quiesce(timeout=5.0)                    # intake held -> no resolve
    fut = port.submit(Invocation.from_sg(SgEntry(
        src=np.zeros(8, np.uint8), length=8,
        opcode=Oper.LOCAL_TRANSFER)))
    assert fut.completion(timeout=0.05) is None  # legacy contract
    port.resume()
    assert fut.completion(timeout=30.0).ok
    shell.close()


def test_port_completions_do_not_accumulate_in_cq():
    """Port-mediated completions bump the writeback counter but are NOT
    retained in the CompletionQueue (the future is the synchronization
    object) — no per-invocation leak, no ticket shadowing for legacy
    SendQueue waiters."""
    shell = _shell(services={})
    shell.load_app(0, make_passthrough_artifact())
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 256))
    for _ in range(20):
        ct.invoke(Oper.LOCAL_TRANSFER,
                  SgEntry(src=ct.vaddr_of(buf), length=256), timeout=30.0)
    cq = shell.vfpgas[0].iface.cq_read
    assert cq.writeback_counter == 20
    assert len(cq._by_ticket) == 0
    assert cq._q.qsize() == 0
    shell.close()


def test_cold_restart_invalidates_ports():
    """Ports wrap torn-down slots/services after cold_restart: held
    references fail fast; re-attach hands out live ports."""
    from repro.core.port import PortError
    shell = _shell(services=dict(ALL_SERVICES))
    shell.load_app(0, make_passthrough_artifact())
    old_slot, old_svc = shell.attach(0), shell.attach("mmu")
    shell.cold_restart()
    assert shell.status()["ports"] == {}         # registry emptied
    for port in (old_slot, old_svc):
        with pytest.raises(PortError, match="closed"):
            port.submit(Invocation.call("utilization"))
    fresh = shell.attach("mmu")                  # live again
    assert fresh is not old_svc
    assert fresh.submit(Invocation.call("utilization")).result(30.0).ok
    comp = shell.attach(0).submit(Invocation.from_sg(SgEntry(
        src=np.zeros(8, np.uint8), length=8,
        opcode=Oper.LOCAL_TRANSFER))).result(30.0)
    assert comp.ok
    shell.close()
