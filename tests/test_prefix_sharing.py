"""Copy-on-write prefix sharing in the paged MMU.

Pins the refcounted-page contract end to end: content-keyed prefix
index (alloc maps covered prompt pages onto existing physical pages),
CoW on translate-for-write, group eviction/fault-back of shared pages
with refcounted host payload lifecycle, snapshot/restore dedup, and —
the acceptance bar — token-for-token parity between sharing-on and
sharing-off engines (greedy AND seeded-sampled) across admission churn,
eviction fault-back, and a mid-decode migration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Shell, ShellConfig, migrate
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU, PageFaultError
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

PAGE = 16
POOL = 128
TEMPLATE = list(range(3, 3 + 3 * PAGE))       # 3 full shareable pages


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _mmu(n_pages=32, page=4, host=64, sharing=True):
    return MMU(MMUConfig(page_size=page, n_pages=n_pages,
                         host_pool_pages=host, prefix_sharing=sharing))


def _fake_pager(mmu):
    store = {}
    mmu.register_pager(lambda pp: store.get(pp),
                       lambda pp, d: store.__setitem__(pp, d), owner="t")
    return store


# ==================================================== refcount accounting ==
def test_alloc_seq_shares_full_prompt_pages():
    mmu = _mmu()
    p = list(range(100, 118))                 # 4 full pages + 2 tokens
    assert mmu.alloc_seq(1, len(p), prompt_tokens=p) == 0
    assert mmu.utilization()["pages_used"] == 5
    assert mmu.alloc_seq(2, len(p), prompt_tokens=p) == 16
    u = mmu.utilization()
    assert u["pages_used"] == 6               # 4 shared + 2 private partials
    assert u["pages_shared"] == 4
    assert u["shared_mappings"] == 4
    assert u["prefix_hits"] == 4
    # shared pages translate to the same physical page
    for tok in (0, 5, 15):
        assert mmu.translate(1, tok) == mmu.translate(2, tok)
    # the partial tail is private
    assert mmu.translate(1, 17) != mmu.translate(2, 17)


def test_partial_prefix_shares_only_matching_pages():
    mmu = _mmu()
    p = list(range(40))
    mmu.alloc_seq(1, len(p), prompt_tokens=p)
    q = p[:8] + [999] * 32                    # diverges at page 2
    assert mmu.alloc_seq(2, len(q), prompt_tokens=q) == 8
    assert mmu.translate(1, 0) == mmu.translate(2, 0)
    assert mmu.translate(1, 8) != mmu.translate(2, 8)


def test_sharing_disabled_allocates_private_pages():
    mmu = _mmu(sharing=False)
    p = list(range(16))
    assert mmu.alloc_seq(1, 16, prompt_tokens=p) == 0
    assert mmu.alloc_seq(2, 16, prompt_tokens=p) == 0
    assert mmu.probe_prefix(p) == 0
    assert mmu.utilization()["pages_shared"] == 0
    assert mmu.translate(1, 0) != mmu.translate(2, 0)


def test_free_recycles_only_refcount_zero_pages():
    mmu = _mmu()
    p = list(range(12))
    mmu.alloc_seq(1, 12, prompt_tokens=p)
    mmu.alloc_seq(2, 12, prompt_tokens=p)
    assert mmu.utilization()["pages_used"] == 3
    mmu.free_seq(2)                           # sharer dies: pages survive
    assert mmu.utilization()["pages_used"] == 3
    assert mmu.translate(1, 0) is not None
    mmu.free_seq(1)                           # last ref: everything recycles
    assert mmu.utilization()["pages_used"] == 0
    assert not mmu._ref and not mmu._prefix_index and not mmu._page_hash


def test_probe_prefix_matches_alloc_coverage():
    mmu = _mmu()
    p = list(range(18))                       # 4 full pages + 2 tokens
    assert mmu.probe_prefix(p) == 0           # nothing registered yet
    mmu.alloc_seq(1, len(p), prompt_tokens=p)
    assert mmu.probe_prefix(p) == 16
    assert mmu.probe_prefix(p[:4] + [77] * 8) == 4
    assert mmu.probe_prefix([77] * 12) == 0
    assert mmu.alloc_seq(2, len(p), prompt_tokens=p) == 16


# ========================================================== copy-on-write ==
def test_translate_for_write_triggers_cow_and_preserves_sharer():
    mmu = _mmu()
    store = _fake_pager(mmu)
    p = list(range(8))
    mmu.alloc_seq(1, 8, prompt_tokens=p)
    store[mmu.translate(1, 0)[0]] = "payload-A"
    assert mmu.alloc_seq(2, 8, prompt_tokens=p) == 8
    shared = mmu.translate(2, 0)[0]
    new_pp, off = mmu.translate(2, 0, for_write=True)
    assert new_pp != shared and off == 0
    assert store[new_pp] == "payload-A"       # device-side page copy
    assert mmu.translate(1, 0)[0] == shared   # sharer keeps the original
    assert mmu.cow_faults == 1
    u = mmu.utilization()
    assert u["pages_shared"] == 1             # page 1 still shared
    # writer's private copy is writable without further faults
    assert mmu.translate(2, 0, for_write=True)[0] == new_pp
    assert mmu.cow_faults == 1


def test_translate_for_write_on_private_page_is_plain():
    mmu = _mmu()
    _fake_pager(mmu)
    mmu.alloc_seq(1, 8, prompt_tokens=list(range(8)))
    pp = mmu.translate(1, 0)[0]
    assert mmu.translate(1, 0, for_write=True)[0] == pp
    assert mmu.cow_faults == 0


# ============================== shared eviction + pager lifecycle (sat. 2) ==
def test_shared_evict_both_sequences_fault_back_exact_bytes():
    mmu = _mmu(n_pages=3, page=4)
    store = _fake_pager(mmu)
    p = list(range(8))
    mmu.alloc_seq(1, 8, prompt_tokens=p)
    for pte in mmu._seqs[1].pages:
        store[pte.ppage] = f"bytes-{pte.vpage}"
    assert mmu.alloc_seq(2, 8, prompt_tokens=p) == 8
    mmu.alloc_seq(9, 8)                       # pressure -> evicts shared
    se1, se2 = mmu._seqs[1], mmu._seqs[2]
    hosted = [pte.vpage for pte in se1.pages if pte.on_host]
    assert hosted
    for v in hosted:
        # ONE host slot backs the whole sharing group
        assert se2.pages[v].on_host
        assert se2.pages[v].host_slot == se1.pages[v].host_slot
        assert (mmu.host_page_data(1, v) == mmu.host_page_data(2, v)
                == f"bytes-{v}")
    mmu.free_seq(9)
    v = hosted[0]
    pp1 = mmu.translate(1, v * 4)[0]          # group fault-in
    assert store[pp1] == f"bytes-{v}"
    assert not se2.pages[v].on_host and se2.pages[v].ppage == pp1
    assert mmu.translate(2, v * 4)[0] == pp1


def test_host_payload_survives_until_last_reference_dies():
    mmu = _mmu(n_pages=3, page=4)
    store = _fake_pager(mmu)
    p = list(range(8))
    mmu.alloc_seq(1, 8, prompt_tokens=p)
    for pte in mmu._seqs[1].pages:
        store[pte.ppage] = f"pp-{pte.vpage}"
    mmu.alloc_seq(2, 8, prompt_tokens=p)
    mmu.alloc_seq(9, 8)                       # force shared eviction
    hosted = [pte.vpage for pte in mmu._seqs[1].pages if pte.on_host]
    assert hosted
    v = hosted[0]
    mmu.free_seq(1)                           # one sharer dies
    assert mmu.host_page_data(2, v) == f"pp-{v}"   # payload retained
    mmu.free_seq(9)
    pp = mmu.translate(2, v * 4)[0]
    assert store[pp] == f"pp-{v}"
    mmu.free_seq(2)                           # last ref: host slot drained
    assert mmu.utilization()["host_pages_used"] == 0


# ==================================================== snapshot / restore ==
def test_snapshot_restore_dedupes_and_reshares():
    mmu = _mmu()
    p = list(range(12))
    mmu.alloc_seq(1, 12, prompt_tokens=p)
    mmu.alloc_seq(2, 12, prompt_tokens=p)
    snap = mmu.snapshot_seqs([1, 2])
    dst = _mmu()
    mapping = dst.restore_seqs(snap)
    assert dst.utilization()["pages_used"] == 3    # not 6: sharing kept
    assert dst.translate(1, 0) == dst.translate(2, 0)
    # mapping agrees: both seqs' vpage 0 landed on one physical page
    assert (mapping[1][0]["new_ppage"] == mapping[2][0]["new_ppage"])
    # chain hashes were re-registered: a NEW sequence shares on the dst
    assert dst.alloc_seq(3, 12, prompt_tokens=p) == 12


def test_restore_capacity_check_counts_unique_pages():
    mmu = _mmu()
    p = list(range(16))
    for sid in range(1, 5):
        mmu.alloc_seq(sid, 16, prompt_tokens=p)
    snap = mmu.snapshot_seqs([1, 2, 3, 4])
    # 4 seqs x 4 pages = 16 mappings but only 4 physical pages: fits in
    # a pool with exactly 4 free pages
    dst = _mmu(n_pages=4)
    dst.restore_seqs(snap)
    assert dst.utilization()["pages_used"] == 4
    tiny = _mmu(n_pages=3)
    with pytest.raises(PageFaultError, match="upfront capacity"):
        tiny.restore_seqs(snap)


# =============================================== engine parity (tentpole) ==
def _engine_pair(cfg, params, *, sharing, seed=11, n_pages=POOL,
                 max_batch=4):
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=n_pages,
                        prefix_sharing=sharing))
    return ServingEngine(cfg, params, mmu, max_batch=max_batch,
                         max_len=128, seed=seed)


def _churn_workload(eng, temp_cycle=(0.0, 0.0, 0.9, 0.0, 1.2)):
    """Three admission waves of templated prompts, with an anchor request
    keeping the shared prefix resident across waves."""
    eng.submit(TEMPLATE + [300], max_new_tokens=40)       # anchor
    outs = {}
    uid = 0
    for wave in range(3):
        for k in range(3):
            t = temp_cycle[(wave * 3 + k) % len(temp_cycle)]
            eng.submit(TEMPLATE + [400 + uid], max_new_tokens=5,
                       temperature=t)
            uid += 1
        for _ in range(8):
            eng.step()
    eng.run()
    for r in eng.completed:
        outs[tuple(r.prompt)] = list(r.out_tokens)
    return outs


def test_parity_sharing_on_vs_off_greedy_and_sampled_under_churn(served):
    cfg, params = served
    off = _engine_pair(cfg, params, sharing=False)
    on = _engine_pair(cfg, params, sharing=True)
    want = _churn_workload(off)
    got = _churn_workload(on)
    assert got == want
    # and the sharing engine actually shared: prefill compute was skipped
    assert on.prefill_skipped > 0
    assert on.mmu.prefix_hits > 0
    assert off.prefill_skipped == 0


def test_parity_across_eviction_fault_back(served):
    """Force-evict shared pages mid-decode, fault every page back, and
    the remaining tokens must match a never-evicted engine — in both
    sharing modes."""
    cfg, params = served

    def run(sharing, evict):
        eng = _engine_pair(cfg, params, sharing=sharing, n_pages=24,
                           max_batch=2)
        eng.submit(TEMPLATE + [71], max_new_tokens=10, temperature=0.7)
        eng.submit(TEMPLATE + [72], max_new_tokens=10)
        for _ in range(3):
            eng.step()
        if evict:
            mmu = eng.mmu
            live = [r.rid for r in eng.slots if r is not None]
            # dummy allocation large enough to force eviction of live KV
            free = len(mmu._free)
            mmu.alloc_seq(999, (free + 2) * PAGE)
            assert any(pte.on_host for rid in live
                       for pte in mmu._seqs[rid].pages), "no eviction?"
            mmu.free_seq(999)
            # fault everything back before the next step
            for rid in live:
                for pte in list(mmu._seqs[rid].pages):
                    if pte.on_host:
                        mmu.translate(rid, pte.vpage * PAGE)
        eng.run()
        return {tuple(r.prompt): list(r.out_tokens) for r in eng.completed}

    oracle = run(False, evict=False)
    assert run(False, evict=True) == oracle
    assert run(True, evict=True) == oracle


def test_parity_across_mid_decode_migration_with_dedup(served):
    cfg, params = served

    def shell():
        s = Shell(ShellConfig.make(
            services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
            n_vfpgas=2))
        s.build()
        return s

    src, dst = shell(), shell()
    eng_src = ServingEngine(cfg, params, src.services.get("mmu"),
                            max_batch=3, max_len=128, shell=src, slot=0,
                            tenant="gold")
    eng_dst = ServingEngine(cfg, params, dst.services.get("mmu"),
                            max_batch=3, max_len=128, shell=dst, slot=0,
                            tenant="gold")
    oracle = _engine_pair(cfg, params, sharing=False, seed=0, max_batch=3)
    for temp, tag in ((0.0, 1), (0.0, 2), (1.1, 3)):
        eng_src.submit(TEMPLATE + [tag], max_new_tokens=12,
                       temperature=temp)
        oracle.submit(TEMPLATE + [tag], max_new_tokens=12,
                      temperature=temp)
    for _ in range(4):
        eng_src.step()
        oracle.step()
    src_used = src.services.get("mmu").utilization()["pages_used"]
    assert src.services.get("mmu").utilization()["pages_shared"] > 0
    report = migrate(src, dst, "gold")
    assert report.n_requests == 3
    # dedup on the wire AND on arrival: the destination pool pays the
    # same page count the source did, not one page per (seq, vpage)
    dst_u = dst.services.get("mmu").utilization()
    assert dst_u["pages_used"] == src_used
    assert dst_u["pages_shared"] > 0
    assert report.n_pages == src_used
    while eng_dst.pending():
        eng_dst.step()
    while oracle.pending():
        oracle.step()
    got = {tuple(r.prompt): r.out_tokens for r in eng_dst.completed}
    want = {tuple(r.prompt): r.out_tokens for r in oracle.completed}
    assert got == want
    src.close()
    dst.close()


def test_snapshot_ships_each_shared_page_once(served):
    cfg, params = served
    eng = _engine_pair(cfg, params, sharing=True, max_batch=3)
    for tag in (1, 2, 3):
        eng.submit(TEMPLATE + [tag], max_new_tokens=8)
    eng.step()
    header, arrays = eng.snapshot_state()
    shipped = [p["ppage"] for p in header["pages"]]
    assert len(shipped) == len(set(shipped))
    mappings = sum(len(sd["pages"]) for sd in header["mmu"]["seqs"])
    assert len(shipped) < mappings            # dedup actually bites
    assert arrays["kv_k"].shape[0] == eng.cfg.n_layers * len(shipped)


# ========================================== capacity + prefill accounting ==
def test_effective_capacity_at_least_2x_under_full_sharing(served):
    """Fixed pool, templated traffic: the sharing engine concurrently
    admits >= 2x the sequences the private engine can hold."""
    cfg, params = served
    pool = 12                                 # template needs 4+ pages/seq

    def concurrent(sharing):
        eng = _engine_pair(cfg, params, sharing=sharing, n_pages=pool,
                           max_batch=8)
        for tag in range(8):
            eng.submit(TEMPLATE + [200 + tag], max_new_tokens=30)
        eng.step()                            # one admission pass
        return eng.active

    base, shared = concurrent(False), concurrent(True)
    assert shared >= 2 * base, (base, shared)


def test_prefill_skip_accounting(served):
    cfg, params = served
    eng = _engine_pair(cfg, params, sharing=True, max_batch=2)
    eng.submit(TEMPLATE + [41], max_new_tokens=2)
    eng.submit(TEMPLATE + [42], max_new_tokens=2)
    eng.run()
    plen = len(TEMPLATE) + 1
    # req 1 computes everything; req 2 only its uncovered suffix
    assert eng.prefill_computed == plen + (plen - 3 * PAGE)
    assert eng.prefill_skipped == 3 * PAGE
    stats_keys = {"prefill_computed", "prefill_skipped"}
    assert stats_keys <= set(eng.run().keys())


# ========================================= config-aliasing satellite fix ==
def test_default_constructed_services_do_not_share_config():
    from repro.core.services.collectives import CollectiveService
    from repro.core.services.compression import GradCompression
    from repro.core.services.encryption import AESService
    from repro.core.services.sniffer import TrafficSniffer
    assert MMU().config is not MMU().config
    for svc in (CollectiveService, GradCompression,
                AESService, TrafficSniffer):
        assert svc().config is not svc().config, svc.__name__
