"""LM serving through the full shell stack: cThread -> vFPGA -> engine ->
paged MMU, with CSR control and completion interrupts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.apps.lm_serving import (CSR_MAX_NEW_TOKENS,
                                   CSR_TEMPERATURE_MILLI,
                                   make_lm_serving_artifact)
from repro.configs import get_config
from repro.core import Oper, SgEntry, Shell, ShellConfig
from repro.core.services import MMUConfig
from repro.models import transformer as T


@pytest.fixture(scope="module")
def shell_with_lm():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    shell = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=16, n_pages=128)},
        n_vfpgas=1))
    shell.build()
    shell.load_app(0, make_lm_serving_artifact(cfg, params, max_len=96))
    return cfg, params, shell


def test_lm_app_serves_through_cthread(shell_with_lm):
    cfg, params, shell = shell_with_lm
    ct = shell.attach_thread(0, pid=42)
    ct.setCSR(5, CSR_MAX_NEW_TOKENS)
    prompt = np.arange(3, 15, dtype=np.int32)
    comp = ct.invoke(Oper.KERNEL, SgEntry(src=prompt, length=prompt.nbytes))
    assert comp.ok
    assert len(comp.result) == 5
    assert ct.poll_interrupt(timeout=1.0) is not None  # completion IRQ
    # greedy output matches the dense decode path
    toks = jnp.asarray(prompt)[None]
    logits, cache = T.prefill(params, cfg, toks, max_len=96,
                              cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, :cfg.vocab_size]))
    assert comp.result[0] == first


def test_lm_app_requires_mmu():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    shell = Shell(ShellConfig.make(services={}, n_vfpgas=1))
    shell.build()
    from repro.core.vfpga import LinkError
    with pytest.raises(LinkError):
        shell.load_app(0, make_lm_serving_artifact(cfg, params))
