"""Multi-device validation of the collective service (hierarchical
all-reduce) and the context-parallel decode attention.

Runs in a SUBPROCESS with 8 forced host devices — the main test process
must keep seeing exactly 1 CPU device (dry-run rule)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro import compat

    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"),
                            axis_types=compat.auto_axis_types(3))

    # ---- hierarchical all-reduce == flat psum -----------------------------
    from repro.core.services.collectives import CollectiveService, CollectiveConfig
    svc = CollectiveService(CollectiveConfig(schedule="hierarchical"))
    x = jnp.arange(32.0).reshape(8, 4)

    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    def hier(v):
        return svc.all_reduce(v, mesh)

    f = shard_map(flat, mesh=mesh, in_specs=P(("pod", "data"), None),
                  out_specs=P(None, None), check_rep=False)
    h = shard_map(hier, mesh=mesh, in_specs=P(("pod", "data"), None),
                  out_specs=P(None, None), check_rep=False)
    a, b = np.asarray(f(x)), np.asarray(h(x))
    assert np.allclose(a, b, atol=1e-5), (a, b)

    # ---- context-parallel decode attention == dense reference -------------
    from repro.models.attention import attend_decode, attend_decode_cp
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, D = 4, 16, 4, 2, 8
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, S, K, D))
    vc = jax.random.normal(ks[2], (B, S, K, D))
    lens = jnp.array([16, 9, 12, 5], jnp.int32)
    ref = attend_decode(q, kc, vc, lens)
    with mesh:
        qd = jax.device_put(q, jax.NamedSharding(mesh, P("data")))
        kd = jax.device_put(kc, jax.NamedSharding(mesh, P("data", "model")))
        vd = jax.device_put(vc, jax.NamedSharding(mesh, P("data", "model")))
        ld = jax.device_put(lens, jax.NamedSharding(mesh, P("data")))
        out = jax.jit(lambda *a: attend_decode_cp(
            *a, mesh, batch_axes=("data",)))(qd, kd, vd, ld)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, err
    print("MULTIDEV_OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("rep", [0])
def test_hierarchical_ar_and_cp_attention(rep):
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            # forced-host-device scripts are CPU-only; an
                            # unpinned platform probes for TPUs (minutes of
                            # metadata-server retries in some containers)
                            "JAX_PLATFORMS": "cpu"})
    assert "MULTIDEV_OK" in r.stdout, f"\nstdout:{r.stdout}\nstderr:{r.stderr[-2000:]}"
