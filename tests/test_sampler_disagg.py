"""Sampler suite + prefill/decode disaggregation hand-off."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.sampler import SamplerConfig, sample


def test_greedy_matches_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 100))
    out = sample(jax.random.PRNGKey(1), logits, SamplerConfig())
    np.testing.assert_array_equal(np.asarray(out),
                                  np.argmax(np.asarray(logits), -1))


def test_top_k_restricts_support():
    logits = jnp.asarray(np.random.RandomState(0).randn(2000, 50))
    cfg = SamplerConfig(temperature=1.0, top_k=3)
    toks = np.asarray(sample(jax.random.PRNGKey(2), logits, cfg))
    top3 = np.argsort(np.asarray(logits), -1)[:, -3:]
    assert all(t in row for t, row in zip(toks, top3))


def test_top_p_keeps_at_least_one_and_restricts():
    # peaked distribution: nucleus p=0.5 must keep only the top token
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]] * 200)
    cfg = SamplerConfig(temperature=1.0, top_p=0.5)
    toks = np.asarray(sample(jax.random.PRNGKey(3), logits, cfg))
    assert (toks == 0).all()


def test_min_p_filters_tail():
    logits = jnp.asarray([[5.0, 4.9, -10.0, -10.0]] * 500)
    cfg = SamplerConfig(temperature=1.0, min_p=0.5)
    toks = np.asarray(sample(jax.random.PRNGKey(4), logits, cfg))
    assert set(np.unique(toks)) <= {0, 1}


def test_temperature_spreads():
    logits = jnp.asarray([[2.0, 1.5, 1.0, 0.5]] * 2000)
    cold = np.asarray(sample(jax.random.PRNGKey(5), logits,
                             SamplerConfig(temperature=0.1)))
    hot = np.asarray(sample(jax.random.PRNGKey(5), logits,
                            SamplerConfig(temperature=5.0)))
    assert len(np.unique(cold)) <= len(np.unique(hot))


DISAGG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.serve.disaggregated import make_handoff_fn, handoff_wire_bytes

    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"),
                            axis_types=compat.auto_axis_types(3))
    handoff, qp = make_handoff_fn(mesh)
    # dim0 pod-sharded: rows 0-1 = prefill pod KV, rows 2-3 = decode pool
    cache = {"k": jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6),
             "v": -jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)}
    with mesh:
        dev = jax.device_put(cache, jax.tree.map(
            lambda _: jax.NamedSharding(mesh, P("pod")), cache))
        out = jax.jit(handoff)(dev)
    k = np.asarray(out["k"])
    np.testing.assert_array_equal(k[2:], np.asarray(cache["k"])[:2])  # delivered
    np.testing.assert_array_equal(k[:2], np.asarray(cache["k"])[:2])  # kept
    assert handoff_wire_bytes(cache) == sum(
        x.nbytes for x in cache.values()) / 2
    print("DISAGG_OK")
""")


@pytest.mark.slow
def test_disaggregated_handoff_multidev():
    r = subprocess.run([sys.executable, "-c", DISAGG], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            # see test_collectives_multidev: pin to CPU so
                            # the child never probes for TPU backends
                            "JAX_PLATFORMS": "cpu"})
    assert "DISAGG_OK" in r.stdout, f"\n{r.stdout}\n{r.stderr[-2000:]}"


# ---------------------- per-row top-k/top-p in the fused sampler ----------
def test_sample_per_row_topk1_is_exactly_greedy_even_hot():
    from repro.serve.sampler import sample_per_row
    logits = jax.random.normal(jax.random.PRNGKey(1), (6, 50)) * 3.0
    temps = jnp.full((6,), 5.0)
    tk = jnp.asarray([1, 0, 1, 3, 1, 0], jnp.int32)
    tp = jnp.ones((6,), jnp.float32)
    am = np.argmax(np.asarray(logits), -1)
    top3 = np.argsort(np.asarray(logits), -1)[:, -3:]
    for s in range(8):
        toks = np.asarray(sample_per_row(jax.random.PRNGKey(s), logits,
                                         temps, tk, tp))
        np.testing.assert_array_equal(toks[[0, 2, 4]], am[[0, 2, 4]])
        assert toks[3] in top3[3]               # row-local k=3 support


def test_sample_per_row_per_row_top_p():
    from repro.serve.sampler import sample_per_row
    # row 0 peaked + p=0.5 -> must collapse to the top token;
    # row 1 flat + p=1.0 -> unrestricted
    lg = jnp.asarray([[10.0, 0.0, 0.0, 0.0], [0.1, 0.2, 0.15, 0.12]])
    tp = jnp.asarray([0.5, 1.0], jnp.float32)
    tk = jnp.zeros((2,), jnp.int32)
    seen1 = set()
    for s in range(24):
        t = np.asarray(sample_per_row(jax.random.PRNGKey(s), lg,
                                      jnp.full((2,), 1.0), tk, tp))
        assert t[0] == 0
        seen1.add(int(t[1]))
    assert len(seen1) > 1                        # row 1 still samples


def test_sample_per_row_disabled_filters_match_legacy_path():
    from repro.serve.sampler import sample_per_row
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 40))
    temps = jnp.full((4,), 1.3)
    a = np.asarray(sample_per_row(jax.random.PRNGKey(7), logits, temps))
    b = np.asarray(sample_per_row(jax.random.PRNGKey(7), logits, temps,
                                  jnp.zeros((4,), jnp.int32),
                                  jnp.ones((4,), jnp.float32)))
    np.testing.assert_array_equal(a, b)


def test_host_oracle_matches_fused_support_restriction():
    """The engine's host Gumbel oracle stays in parity with the fused
    sampler: same top-k/top-p support rule on the same logits."""
    from repro.configs import get_config
    from repro.core.services.mmu import MMU, MMUConfig
    from repro.serve.engine import ServingEngine
    cfg = get_config("smollm-135m").reduced()
    eng = ServingEngine.__new__(ServingEngine)   # oracle only, no model
    eng.cfg = cfg
    eng._rng = np.random.RandomState(0)
    v = cfg.vocab_size
    logits = np.random.RandomState(1).randn(200, v) * 3.0
    toks = eng._sample(logits, 1.0, top_k=3)
    top3 = np.argsort(logits, -1)[:, -3:]
    assert all(t in row for t, row in zip(toks, top3))
    # top_k=1 == greedy exactly
    np.testing.assert_array_equal(eng._sample(logits, 5.0, top_k=1),
                                  np.argmax(logits, -1))
    # peaked distribution under p=0.5 keeps only the head
    peak = np.zeros((50, v), np.float32)
    peak[:, 7] = 12.0
    assert (eng._sample(peak, 1.0, top_p=0.5) == 7).all()
