"""Serving engine: paged decode == dense decode, continuous batching,
page-pressure behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _dense_greedy(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = T.prefill(params, cfg, toks, max_len=128,
                              cache_dtype=jnp.float32)
    seq = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        nt = jnp.asarray([[seq[-1]]], jnp.int32)
        logits, cache = T.decode_step(params, cfg, cache, nt,
                                      jnp.asarray([pos], jnp.int32))
        seq.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
        pos += 1
    return seq


def test_paged_engine_matches_dense_greedy(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    eng = ServingEngine(cfg, params, mmu, max_batch=3, max_len=128)
    prompts = [list(range(3, 3 + n)) for n in (5, 17, 9, 12)]
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    stats = eng.run()
    assert stats["completed"] == 4
    for req in eng.completed:
        dense = _dense_greedy(cfg, params, req.prompt, len(req.out_tokens))
        assert dense == req.out_tokens, f"rid {req.rid} diverged"


def test_continuous_batching_refills_slots(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=128))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=64)
    for i in range(5):
        eng.submit(list(range(3, 10 + i)), max_new_tokens=3)
    stats = eng.run()
    assert stats["completed"] == 5                 # queue drained via refill
    assert mmu.utilization()["pages_used"] == 0    # all pages freed


def test_page_pressure_eviction_path(served):
    cfg, params = served
    # tiny pool: long sequences force eviction + fault-back-in via MMU
    mmu = MMU(MMUConfig(page_size=8, n_pages=24, host_pool_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=80)
    eng.submit(list(range(3, 40)), max_new_tokens=4)
    eng.submit(list(range(3, 50)), max_new_tokens=4)
    stats = eng.run()
    assert stats["completed"] == 2


def test_temperature_sampling_differs(served):
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=16, n_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=1, max_len=64, seed=1)
    eng.submit(list(range(3, 12)), max_new_tokens=8, temperature=1.5)
    eng.run()
    sampled = eng.completed[0].out_tokens
    greedy = _dense_greedy(cfg, params, list(range(3, 12)), 8)
    assert sampled != greedy                       # overwhelmingly likely
