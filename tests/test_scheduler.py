"""Multi-tenant shell scheduler: weighted-credit QoS, SG coalescing,
per-tenant accounting, and the JAX cost_analysis compat helper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import normalize_cost_analysis
from repro.core import Alloc, AppArtifact, Oper, SgEntry, Shell, ShellConfig
from repro.core.credits import (Link, WeightedRRArbiter, jains_index,
                                weighted_jains_index)


def _contended_shares(events, finish_of):
    """Byte share per party over the window where EVERY party still has
    backlog — i.e. up to the first party's last transfer.  After that the
    survivors inherit the idle bandwidth, which is not a QoS signal."""
    t_star = min(finish_of.values())
    got = {k: 0 for k in finish_of}
    for t, key, nbytes in events:
        if t <= t_star:
            got[key] += nbytes
    return got


def _tenant_of_src(src: str) -> str:
    return src.split("/", 1)[0]


# ====================================================== weighted arbiter ====
def test_weighted_arbiter_dwrr_shares():
    link = Link("l", 1e9)
    arb = WeightedRRArbiter(link, packet_bytes=4096)
    events = []
    link.on_event(lambda ev: events.append((ev.t, ev.src, ev.nbytes)))
    arb.submit("gold", 4096 * 240, weight=3.0)
    arb.submit("bronze", 4096 * 240, weight=1.0)
    arb.drain()
    finish = {}
    for t, src, _ in events:
        finish[src] = t
    got = _contended_shares(events, finish)
    ratio = got["gold"] / got["bronze"]
    assert abs(ratio - 3.0) / 3.0 < 0.15, ratio
    # every byte moved exactly once regardless of weighting
    assert link.bytes_moved == 2 * 4096 * 240


def test_weighted_arbiter_equal_weights_is_plain_rr():
    link = Link("l", 1e9)
    arb = WeightedRRArbiter(link, packet_bytes=4096)
    for name in ("a", "b", "c"):
        arb.submit(name, 4096 * 50)
    arb.drain()
    shares = arb.fairness()
    assert abs(jains_index(shares) - 1.0) < 1e-9


def test_weighted_arbiter_rejects_nonpositive_weight():
    arb = WeightedRRArbiter(Link("l", 1e9))
    with pytest.raises(ValueError):
        arb.set_weight("x", 0.0)


def test_weighted_jains_index():
    # exact 3:1 split under 3:1 weights is perfectly weighted-fair
    assert abs(weighted_jains_index({"a": 0.75, "b": 0.25},
                                    {"a": 3.0, "b": 1.0}) - 1.0) < 1e-9
    # equal split under 3:1 weights is NOT
    assert weighted_jains_index({"a": 0.5, "b": 0.5},
                                {"a": 3.0, "b": 1.0}) < 0.9


# ==================================================== scheduler QoS (e2e) ===
def _shell(n_vfpgas=2, **kw):
    s = Shell(ShellConfig.make(services={}, n_vfpgas=n_vfpgas, **kw))
    s.build()
    return s


def test_weighted_shares_converge_to_configured_ratio():
    """Acceptance: two tenants at 3:1 under saturation -> contended byte
    ratio within 15% of 3:1, and Jain's indices reported per tenant."""
    shell = _shell(n_vfpgas=2)
    shell.register_tenant("gold", 3.0, slots=(0,))
    shell.register_tenant("bronze", 1.0, slots=(1,))
    events = []
    shell.static.pcie.on_event(
        lambda ev: events.append((ev.t, _tenant_of_src(ev.src), ev.nbytes)))
    threads = [shell.attach_thread(0, pid=1), shell.attach_thread(1, pid=2)]
    shell.scheduler.pause()                  # build up saturation demand
    for ct in threads:
        for _ in range(30):
            buf = ct.getMem((Alloc.REG, 32 << 10))
            ct.invoke(Oper.LOCAL_TRANSFER,
                      SgEntry(src=ct.vaddr_of(buf), length=buf.size),
                      wait=False)
    shell.scheduler.resume()
    shell.drain()

    finish = {}
    for t, ten, _ in events:
        finish[ten] = t
    got = _contended_shares(events, finish)
    ratio = got["gold"] / got["bronze"]
    assert abs(ratio - 3.0) / 3.0 < 0.15, ratio

    sched = shell.status()["scheduler"]
    assert set(sched["tenants"]) == {"gold", "bronze"}
    assert 0.0 < sched["jain_tenant"] <= 1.0
    assert 0.0 < sched["jain_weighted"] <= 1.0
    for t in sched["tenants"].values():
        assert t["completions"] == 30
        assert t["mean_latency_s"] >= 0.0


def test_batching_never_reorders_same_stream_entries():
    shell = _shell(n_vfpgas=1)
    order = []

    def recorder(iface, vfpga, x):
        order.append(int(x[0]))
        return x

    shell.load_app(0, AppArtifact(name="recorder", fn=recorder))
    ct = shell.attach_thread(0, pid=1)
    shell.scheduler.pause()                  # force a deep backlog
    n = 32
    for i in range(n):
        buf = ct.getMem((Alloc.REG, 256))    # small: 16 coalesce per packet
        buf[0] = i
        ct.invoke(Oper.LOCAL_TRANSFER,
                  SgEntry(src=ct.vaddr_of(buf), length=buf.size),
                  wait=False)
    shell.scheduler.resume()
    shell.drain()
    assert order == list(range(n))           # strict FIFO per stream
    # and the backlog really was coalesced, not sent 1 entry : 1 batch
    assert shell.scheduler.entries_coalesced > 0
    assert shell.scheduler.batches_issued < n


def test_per_tenant_stats_sum_to_arbiter_totals():
    shell = _shell(n_vfpgas=2)
    shell.register_tenant("gold", 2.0, slots=(0,))
    shell.register_tenant("bronze", 1.0, slots=(1,))
    threads = [shell.attach_thread(0, pid=1), shell.attach_thread(1, pid=2)]
    for ct, kb in zip(threads, (96, 160)):
        buf = ct.getMem((Alloc.REG, kb << 10))
        ct.invoke(Oper.LOCAL_TRANSFER,
                  SgEntry(src=ct.vaddr_of(buf), length=buf.size),
                  wait=False)
    shell.drain()
    sched = shell.scheduler.stats()
    tenant_bytes = sum(t["bytes"] for t in sched["tenants"].values())
    arbiter_bytes = sum(shell.arbiter.delivered.values())
    assert tenant_bytes == arbiter_bytes == (96 << 10) + (160 << 10)
    assert tenant_bytes == shell.static.pcie.bytes_moved
    assert sched["total_bytes"] == tenant_bytes


def test_completion_queues_still_synchronize_invoke():
    """wait=True invokes must behave exactly as before the async refactor."""
    shell = _shell(n_vfpgas=1)
    ct = shell.attach_thread(0, pid=1)
    src = ct.getMem((Alloc.REG, 8192))
    src[:] = np.arange(8192) % 251
    dst = ct.getMem((Alloc.REG, 8192))
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(src), dst=ct.vaddr_of(dst),
                             length=8192), timeout=30.0)
    assert comp is not None and comp.ok
    assert (src == dst).all()


def test_submit_io_bills_tenant():
    shell = _shell(n_vfpgas=1)
    shell.register_tenant("svc", 1.5, slots=(0,))
    ev = shell.scheduler.submit_io(1 << 20, slot=0, tenant="svc",
                                   wait=True, timeout=30.0)
    assert ev.is_set()
    stats = shell.scheduler.stats()["tenants"]["svc"]
    assert stats["bytes"] == 1 << 20
    assert stats["completions"] == 1
    # regression: submit_io naming an existing tenant must NOT reset its
    # configured weight back to the default
    assert stats["weight"] == 1.5
    # async submitters reconcile on this: nothing left in flight
    assert shell.scheduler.tenant_pending("svc") == 0
    assert shell.scheduler.tenant_pending("no-such-tenant") == 0


def test_default_tenant_autocreated_per_slot():
    shell = _shell(n_vfpgas=2)
    ct = shell.attach_thread(1, pid=9)
    buf = ct.getMem((Alloc.REG, 4096))
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(buf), length=4096),
                     timeout=30.0)
    assert comp is not None and comp.ok
    assert "tenant1" in shell.scheduler.stats()["tenants"]


def test_drained_stream_stops_diluting_tenant_weight():
    """A tenant fanned out over two slots must regain its full weight on
    the surviving stream once the other's backlog drains."""
    shell = _shell(n_vfpgas=2)
    shell.register_tenant("gold", 3.0, slots=(0, 1))
    ct0 = shell.attach_thread(0, pid=1)
    ct1 = shell.attach_thread(1, pid=2)
    b1 = ct1.getMem((Alloc.REG, 4096))          # touch + drain slot 1
    ct1.invoke(Oper.LOCAL_TRANSFER,
               SgEntry(src=ct1.vaddr_of(b1), length=4096), timeout=30.0)
    shell.drain()
    b0 = ct0.getMem((Alloc.REG, 64 << 10))      # then slot 0 alone
    ct0.invoke(Oper.LOCAL_TRANSFER,
               SgEntry(src=ct0.vaddr_of(b0), length=b0.size), timeout=30.0)
    shell.drain()
    assert shell.arbiter.weight("gold/vfpga0.s0") == pytest.approx(3.0)


def test_submit_with_unknown_tenant_autoregisters():
    shell = _shell(n_vfpgas=1)
    ev = shell.scheduler.submit_io(4096, slot=0, tenant="newbie",
                                   wait=True, timeout=30.0)
    assert ev.is_set()
    assert shell.scheduler.stats()["tenants"]["newbie"]["weight"] == 1.0


# ======================================== cost_analysis compat regression ===
def test_cost_analysis_normalization_helper():
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost_analysis([None, {"a": 1.0}]) == {"a": 1.0}
    # whatever shape the installed JAX returns must flatten to a dict
    c = (jax.jit(lambda a: a * 2)
         .lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile())
    ca = normalize_cost_analysis(c.cost_analysis())
    assert isinstance(ca, dict)
    assert float(ca.get("flops", 0.0)) >= 0.0
