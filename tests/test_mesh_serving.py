"""Mesh-sharded paged serving: tensor-parallel engine parity.

The acceptance pin of the sharding PR: a ``ServingEngine`` given a mesh
with ``model > 1`` produces EXACTLY the tokens the single-device engine
produces — greedy and sampled rows, through admission churn, eviction /
fault-back-in, live migration and in-place slot recovery.  Logits differ
in the last ulp across TP degrees (float reduction order), tokens must
not.

Multi-device runs happen in SUBPROCESSES with forced host devices — the
main test process must keep seeing exactly 1 CPU device (dry-run rule,
tests/conftest.py).  The in-process tests cover the pure-Python policy
pieces (MeshRules, tp_plan, make_host_mesh errors).
"""
import dataclasses
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.sharding import MeshRules
from repro.serve.tp import tp_plan

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
        # forced-host-device scripts are CPU-only; an unpinned platform
        # probes for TPUs (minutes of metadata-server retries)
        "JAX_PLATFORMS": "cpu"}


def _run_sub(script: str, ok: str):
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=540, env=_ENV)
    for line in r.stdout.splitlines():
        if line.startswith("SKIP:"):
            pytest.skip(line[5:].strip())
    assert ok in r.stdout, \
        f"\nstdout:{r.stdout}\nstderr:{r.stderr[-3000:]}"


# A shared preamble: force 4 host devices, build mesh or print SKIP with
# the make_host_mesh RuntimeError message (the descriptive-error
# satellite — tests skip on it rather than erroring).
_PREAMBLE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core.services.mmu import MMU, MMUConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.serve.engine import ServingEngine
    from repro.serve.paged_model import flat_page_indices, gather_kv_pages

    def mesh_or_skip(data, model):
        try:
            return make_host_mesh(data, model)
        except RuntimeError as e:
            print("SKIP:", e)
            raise SystemExit(0)

    def drain(*engines):
        for eng in engines:
            while eng.pending():
                eng.step()

    def tokens(eng):
        return {r.rid: list(r.out_tokens) for r in eng.completed}
""")


# ================================================ in-process (1 device) ====
def test_meshrules_unknown_scheme_raises():
    with pytest.raises(ValueError, match="unknown MeshRules scheme"):
        MeshRules.from_mesh(None, "diagonal")   # checked before mesh use


def test_meshrules_tp_divisibility_degrades_to_replication():
    rules = MeshRules(fsdp_axes=("data",), tp_axis="model", fsdp_size=0,
                      tp_size=3)
    assert rules.tp(6) == "model"       # divisible -> sharded
    assert rules.tp(7) is None          # not divisible -> replicated
    assert rules.tp(0) == "model"       # 0 % n == 0 (empty dim edge)
    serving = rules.serving()
    assert serving.shard_params_fsdp is False
    assert serving.tp(6) == "model"     # TP survives serving mode
    assert serving.fsdp(6) is None      # FSDP rows do not


def test_tp_plan_static_degradation():
    cfg = get_config("smollm-135m").reduced()   # 4 q / 2 kv heads, silu
    assert tp_plan(cfg, 2) == {"shard_heads": True, "shard_mlp": True}
    # kv heads (2) don't divide 4 -> attention replicates, MLP still shards
    assert tp_plan(cfg, 4) == {"shard_heads": False, "shard_mlp": True}
    assert tp_plan(cfg, 1) == {"shard_heads": False, "shard_mlp": False}
    # GELU applies b_down pre-reduction -> MLP must replicate
    gelu = dataclasses.replace(cfg, act="gelu")
    assert not tp_plan(gelu, 2)["shard_mlp"]
    # indivisible hidden dim -> MLP replicates
    odd = dataclasses.replace(cfg, d_ff=250)
    assert not tp_plan(odd, 4)["shard_mlp"]


def test_make_host_mesh_raises_descriptive_not_assert():
    """Single-device process asking for a 4-device mesh gets a
    RuntimeError naming the XLA_FLAGS fix, never a bare assert."""
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count=4"):
        make_host_mesh(1, 4)


# ================================================== subprocess (4 dev) ====
@pytest.mark.slow
def test_tp2_token_parity_under_churn_and_eviction():
    """TP=2 engine vs single-device engine: identical token streams with
    greedy AND sampled rows, slot churn (more requests than slots), and
    evict-with-copy byte-exactness on the sharded pools."""
    script = _PREAMBLE + textwrap.dedent("""
        mesh = mesh_or_skip(1, 2)
        cfg = get_config("smollm-135m").reduced()
        params = T.init_params(jax.random.PRNGKey(0), cfg,
                               dtype=jnp.float32)

        def build(mesh):
            mmu = MMU(MMUConfig(page_size=16, n_pages=128))
            return ServingEngine(cfg, params, mmu, max_batch=2,
                                 max_len=96, seed=0, mesh=mesh)

        single, tp2 = build(None), build(mesh)
        assert tp2.tp is not None and tp2.tp.shard_heads \\
            and tp2.tp.shard_mlp
        # local shard of the KV pool holds kv_heads // 2 heads
        local = tp2.pools["k"].addressable_shards[0].data.shape
        assert local[2] == cfg.n_kv_heads // 2, local
        # 5 requests through 2 slots: admission churn + queueing; greedy,
        # sampled, and top-k/top-p filtered rows
        reqs = [(list(range(3, 9)), 0.0, 0, 1.0),
                (list(range(3, 17)), 0.8, 0, 1.0),
                (list(range(5, 11)), 1.3, 5, 1.0),
                (list(range(2, 14)), 0.7, 0, 0.9),
                (list(range(9, 15)), 0.0, 0, 1.0)]
        for eng in (single, tp2):
            for p, t, k, tp_ in reqs:
                eng.submit(p, max_new_tokens=10, temperature=t,
                           top_k=k, top_p=tp_)
        drain(single, tp2)
        assert tokens(single) == tokens(tp2), (tokens(single), tokens(tp2))
        print("CHURN_PARITY_OK")

        # ---- evict-with-copy on SHARDED pools: byte-exact round trip ----
        mmu = MMU(MMUConfig(page_size=8, n_pages=8, host_pool_pages=64))
        eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=80,
                            seed=0, mesh=mesh)
        eng.submit(list(range(3, 30)), max_new_tokens=30)
        for _ in range(3):
            eng.step()
        se = mmu._seqs[1]
        pre = {p.vpage: eng._pager_gather(p.ppage)
               for p in se.pages if not p.on_host}
        mmu.alloc_seq(99, 8 * (len(mmu._free) + 2))   # pressure -> evict
        evicted = [p.vpage for p in se.pages if p.on_host]
        assert evicted
        for v in evicted:
            stored = mmu.host_page_data(1, v)
            np.testing.assert_array_equal(stored["k"], pre[v]["k"])
            np.testing.assert_array_equal(stored["v"], pre[v]["v"])
        mmu.free_seq(99)
        for v in evicted:                              # fault back in
            ppage, _ = mmu.translate(1, v * 8)
            flat = flat_page_indices([ppage], cfg.n_layers,
                                     mmu.config.n_pages)
            back = {k: np.asarray(x)
                    for k, x in gather_kv_pages(eng.pools, flat).items()}
            np.testing.assert_array_equal(back["k"], pre[v]["k"])
            np.testing.assert_array_equal(back["v"], pre[v]["v"])
        # pools stayed pinned to the TP layout through the scatter
        assert eng.pools["k"].sharding == eng.tp.kv_sharding
        print("TP2_SERVING_OK")
    """)
    _run_sub(script, "TP2_SERVING_OK")


@pytest.mark.slow
def test_tp4_token_parity_and_heads_degradation():
    """TP=4: with 4 kv heads the full stack shards; with the stock
    reduced config (2 kv heads) attention statically degrades to
    replication while the MLP still shards — parity must hold in BOTH
    regimes."""
    script = _PREAMBLE + textwrap.dedent("""
        mesh = mesh_or_skip(1, 4)
        base = get_config("smollm-135m").reduced()
        for cfg, want_heads in ((dataclasses.replace(base, n_kv_heads=4),
                                 True),
                                (base, False)):
            params = T.init_params(jax.random.PRNGKey(0), cfg,
                                   dtype=jnp.float32)

            def build(mesh):
                mmu = MMU(MMUConfig(page_size=16, n_pages=128))
                return ServingEngine(cfg, params, mmu, max_batch=3,
                                     max_len=64, seed=0, mesh=mesh)

            single, tp4 = build(None), build(mesh)
            assert tp4.tp.shard_heads is want_heads
            assert tp4.tp.shard_mlp is True
            for p, t in (([1, 2, 3, 4, 5], 0.0), ([7, 8, 9], 0.9),
                         (list(range(11, 18)), 1.2)):
                single.submit(p, max_new_tokens=8, temperature=t)
                tp4.submit(p, max_new_tokens=8, temperature=t)
            drain(single, tp4)
            assert tokens(single) == tokens(tp4), \\
                (want_heads, tokens(single), tokens(tp4))
        print("TP4_SERVING_OK")
    """)
    _run_sub(script, "TP4_SERVING_OK")


@pytest.mark.slow
def test_sharded_tenant_migrates_and_recovers():
    """PR-5 + PR-7 composition: a TP=2 tenant live-migrates to a
    SINGLE-DEVICE destination shell token-for-token (the wire format is
    shard-agnostic), and a TP=2 slot recovers in place KV-intact."""
    script = _PREAMBLE + textwrap.dedent("""
        from repro.core import Shell, ShellConfig, migrate
        mesh = mesh_or_skip(1, 2)
        cfg = get_config("smollm-135m").reduced()
        params = T.init_params(jax.random.PRNGKey(0), cfg,
                               dtype=jnp.float32)

        def shell():
            s = Shell(ShellConfig.make(
                services={"mmu": MMUConfig(page_size=16, n_pages=128)},
                n_vfpgas=2))
            s.build()
            return s

        def engine(sh, mesh):
            return ServingEngine(cfg, params, sh.services.get("mmu"),
                                 max_batch=3, max_len=128, shell=sh,
                                 slot=0, tenant="gold", mesh=mesh)

        reqs = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
                (list(range(3, 12)), 1.3)]

        def oracle():
            eng = ServingEngine(cfg, params,
                                MMU(MMUConfig(page_size=16, n_pages=128)),
                                max_batch=3, max_len=128)
            for p, t in reqs:
                eng.submit(p, max_new_tokens=12, temperature=t)
            return eng

        # ---- migrate: sharded source -> single-device destination ----
        src, dst = shell(), shell()
        eng_src, eng_dst = engine(src, mesh), engine(dst, None)
        want = oracle()
        for p, t in reqs:
            eng_src.submit(p, max_new_tokens=12, temperature=t)
        for _ in range(4):
            eng_src.step()
            want.step()
        report = migrate(src, dst, "gold")
        assert report.n_requests == 3
        drain(eng_dst, want)
        assert tokens(eng_dst) == tokens(want)
        assert src.services.get("mmu").utilization()["pages_used"] == 0
        src.close(); dst.close()
        print("MIGRATE_SHARDED_OK")

        # ---- recover_slot: sharded engine, in place, KV-intact ----
        sh = shell()
        eng = engine(sh, mesh)
        want = oracle()
        for p, t in reqs:
            eng.submit(p, max_new_tokens=12, temperature=t)
        for _ in range(4):
            eng.step()
            want.step()
        report = sh.recover_slot(0)
        assert report.n_requests == 3 and report.n_pages > 0
        # cold-reset preserved the TP layout
        assert eng.pools["k"].sharding == eng.tp.kv_sharding
        drain(eng, want)
        assert tokens(eng) == tokens(want)
        sh.close()
        print("RECOVER_SHARDED_OK")
    """)
    _run_sub(script, "RECOVER_SHARDED_OK")
