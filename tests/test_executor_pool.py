"""Per-slot executor lanes + cooperative preemption (PR 4 tentpole).

Covers the four contract points:

  * two-slot non-interference — a long-running invocation on slot A does
    not stall slot B's completions;
  * preemption honors priority at checkpoint boundaries without losing
    or duplicating completions;
  * ``Shell.reconfigure`` keeps the PR 3 zero-lost/zero-dup invariant
    with lanes active;
  * billing totals are identical lanes-on vs lanes-off.
"""
import threading
import time

import numpy as np
import pytest

from repro.apps import make_passthrough_artifact
from repro.core import (AppArtifact, Invocation, Oper, SgEntry, Shell,
                        ShellConfig)
from repro.core.services import MMUConfig


def _shell(lanes=True, n_vfpgas=2, services=None, **kw):
    s = Shell(ShellConfig.make(services=services or {},
                               executor_lanes=lanes,
                               n_vfpgas=n_vfpgas, **kw))
    s.build()
    return s


def _sg(nbytes=64, fill=1, stream=0):
    return SgEntry(src=np.full(nbytes, fill, np.uint8), length=nbytes,
                   src_stream=stream, opcode=Oper.LOCAL_TRANSFER)


# ================================================== non-interference =======
def test_two_slot_non_interference():
    """A blocked long invocation on slot 0 must not delay slot 1: the
    latency tenant's submissions all complete WHILE slot 0 is held."""
    shell = _shell(lanes=True)
    started, release = threading.Event(), threading.Event()

    def long_fn(iface, vf, x):
        started.set()
        assert release.wait(timeout=30.0)
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    shell.load_app(1, make_passthrough_artifact())
    p0, p1 = shell.attach(0, tenant="batch"), shell.attach(1,
                                                           tenant="latency")
    long_fut = p0.submit(Invocation.from_sg(_sg(4096)))
    assert started.wait(timeout=10.0)          # slot 0's lane is now busy
    comps = [p1.submit(Invocation.from_sg(_sg())).result(timeout=10.0)
             for _ in range(10)]
    assert all(c.ok for c in comps)            # slot 1 completed under hold
    assert not long_fut.done()                 # slot 0 still in flight
    release.set()
    assert long_fut.result(timeout=30.0).ok
    shell.drain()
    shell.close()


def test_io_completes_while_lane_is_busy():
    """Pure-I/O submissions (decode-step billing) finish inline on the
    scheduler thread — a busy lane must not delay their futures."""
    shell = _shell(lanes=True)
    started, release = threading.Event(), threading.Event()

    def long_fn(iface, vf, x):
        started.set()
        assert release.wait(timeout=30.0)
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    port = shell.attach(0, tenant="batch")
    port.submit(Invocation.from_sg(_sg(4096)))
    assert started.wait(timeout=10.0)
    comp = port.submit(Invocation.io(2048, tag="decode_io")
                       ).completion(timeout=10.0)
    assert comp is not None and comp.nbytes == 2048
    release.set()
    shell.drain()
    shell.close()


def test_serialized_baseline_blocks_across_slots():
    """Control: with lanes OFF the single worker serializes slots, so a
    held invocation on slot 0 stalls slot 1 (the gap lanes close)."""
    shell = _shell(lanes=False)
    started, release = threading.Event(), threading.Event()

    def long_fn(iface, vf, x):
        started.set()
        assert release.wait(timeout=30.0)
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    shell.load_app(1, make_passthrough_artifact())
    p0, p1 = shell.attach(0), shell.attach(1)
    p0.submit(Invocation.from_sg(_sg(4096)))
    assert started.wait(timeout=10.0)
    fast = p1.submit(Invocation.from_sg(_sg()))
    assert fast.completion(timeout=0.3) is None     # stuck behind slot 0
    release.set()
    assert fast.result(timeout=30.0).ok
    shell.drain()
    shell.close()


# ====================================================== preemption =========
def test_preemption_honors_priority_no_lost_no_dup():
    """High-priority invocations on the SAME slot run inside the long
    batch's checkpoint holds: they complete while the long invocation is
    still in flight, and every submission completes exactly once."""
    shell = _shell(lanes=True, n_vfpgas=1)
    order = []
    lock = threading.Lock()
    started, release = threading.Event(), threading.Event()

    def long_fn(iface, vf, x):
        started.set()
        while not release.is_set():            # checkpointed long loop
            time.sleep(0.005)
            vf.checkpoint()
        with lock:
            order.append("long")
        return x

    def hi_fn(iface, vf, x):
        with lock:
            order.append("hi")
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    port = shell.attach(0)
    long_fut = port.submit(Invocation.from_sg(_sg(4096)))
    assert started.wait(timeout=10.0)
    # point the slot's logic at the tagging fn for the preemptors (the
    # in-flight long invocation already entered long_fn); preemptors
    # ride their own stream — same-stream work may never overtake
    shell.vfpgas[0].app = AppArtifact(name="hi", fn=hi_fn)
    hi_futs = [port.submit(Invocation.from_sg(_sg(64, stream=1),
                                              priority=5))
               for _ in range(5)]
    comps = [f.result(timeout=30.0) for f in hi_futs]
    assert all(c.ok for c in comps)            # ran inside checkpoint holds
    assert not long_fut.done()                 # preempted, not displaced
    release.set()
    assert long_fut.result(timeout=30.0).ok
    with lock:
        assert order.count("hi") == 5          # zero lost, zero dup
        assert order.count("long") == 1
        assert order.index("long") == len(order) - 1   # highs ran first
    assert shell.vfpgas[0].preemptions >= 1
    lanes = shell.scheduler.stats()["lanes"]
    assert lanes["0"]["preempt_runs"] >= 1     # >=1 batch (they coalesce)
    shell.drain()
    shell.close()


def test_same_stream_priority_never_overtakes():
    """Per-stream FIFO is inviolable: a higher-priority submission on
    the SAME (slot, stream) as the held batch must NOT run inside its
    checkpoint holds — it executes only after the earlier batch
    completes (priority reorders only across streams)."""
    shell = _shell(lanes=True, n_vfpgas=1)
    order = []
    started, release = threading.Event(), threading.Event()

    def long_fn(iface, vf, x):
        started.set()
        while not release.is_set():
            time.sleep(0.005)
            vf.checkpoint()
        order.append("long")
        return x

    def hi_fn(iface, vf, x):
        order.append("hi")
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    port = shell.attach(0)
    long_fut = port.submit(Invocation.from_sg(_sg(4096, stream=0)))
    assert started.wait(timeout=10.0)
    shell.vfpgas[0].app = AppArtifact(name="hi", fn=hi_fn)
    hi_fut = port.submit(Invocation.from_sg(_sg(64, stream=0),
                                            priority=5))
    assert hi_fut.completion(timeout=0.3) is None   # held back: same stream
    release.set()
    assert long_fut.result(timeout=30.0).ok
    assert hi_fut.result(timeout=30.0).ok
    assert order == ["long", "hi"]                  # FIFO preserved
    shell.drain()
    shell.close()


def test_equal_priority_orders_by_deadline():
    """Among equal priorities the earliest absolute deadline runs first
    (streams differ, so per-stream FIFO does not constrain the order)."""
    shell = _shell(lanes=True, n_vfpgas=1, n_streams=4)
    order = []
    started, release = threading.Event(), threading.Event()

    def fn(iface, vf, x):
        tag = bytes(np.asarray(x)[:1]).decode()
        if tag == "L":
            started.set()
            assert release.wait(timeout=30.0)
        order.append(tag)
        return x

    shell.load_app(0, AppArtifact(name="tagged", fn=fn))
    port = shell.attach(0)
    futs = [port.submit(Invocation.from_sg(SgEntry(
        src=np.frombuffer(b"L" * 64, np.uint8), length=64,
        src_stream=0, opcode=Oper.LOCAL_TRANSFER)))]
    assert started.wait(timeout=10.0)          # lane busy; next two queue
    futs.append(port.submit(Invocation.from_sg(SgEntry(
        src=np.frombuffer(b"A" * 64, np.uint8), length=64,
        src_stream=1, opcode=Oper.LOCAL_TRANSFER), deadline_s=30.0)))
    futs.append(port.submit(Invocation.from_sg(SgEntry(
        src=np.frombuffer(b"B" * 64, np.uint8), length=64,
        src_stream=2, opcode=Oper.LOCAL_TRANSFER), deadline_s=0.5)))
    # both queued submissions must be ON the lane before releasing, or
    # the lane could pop A alone before B's grant arrives
    deadline = time.perf_counter() + 10.0
    while time.perf_counter() < deadline:
        lanes = shell.scheduler.stats()["lanes"]
        if lanes.get("0", {}).get("queued", 0) >= 2:
            break
        time.sleep(0.005)
    assert shell.scheduler.stats()["lanes"]["0"]["queued"] == 2
    release.set()
    for f in futs:
        assert f.result(timeout=30.0).ok
    assert order == ["L", "B", "A"]            # earlier deadline first
    shell.drain()
    shell.close()


def test_checkpoint_off_lane_is_noop():
    shell = _shell(lanes=True)
    assert shell.scheduler.checkpoint(0) == 0
    assert not shell.scheduler.preempt_requested(0)
    shell_off = _shell(lanes=False)
    assert shell_off.scheduler.checkpoint(0) == 0
    shell.close()
    shell_off.close()


# ====================================== reconfigure under lanes ============
def test_reconfigure_under_lanes_zero_lost_zero_dup():
    """PR 3 invariant with lanes active: hot-swap slot 0 mid-traffic
    while both tenants drive; every submission completes exactly once
    and the other slot never stalls."""
    shell = _shell(lanes=True)
    executed = {"old": 0, "new": 0, "b": 0}
    lock = threading.Lock()

    def mk(tag):
        def fn(iface, vf, x):
            with lock:
                executed[tag] += 1
            return x
        return fn

    shell.load_app(0, AppArtifact(name="old", fn=mk("old")))
    shell.load_app(1, AppArtifact(name="bapp", fn=mk("b")))
    shell.register_tenant("gold", 2.0, slots=(0,))
    shell.register_tenant("bronze", 1.0, slots=(1,))
    pa, pb = shell.attach(0), shell.attach(1)
    futs_a, futs_b = [], []
    n = 100

    def drive(port, futs):
        for i in range(n):
            futs.append(port.submit(Invocation.from_sg(_sg(64, i % 251))))
    ta = threading.Thread(target=drive, args=(pa, futs_a))
    tb = threading.Thread(target=drive, args=(pb, futs_b))
    ta.start()
    tb.start()
    time.sleep(0.005)
    shell.reconfigure(0, AppArtifact(name="new", fn=mk("new")))
    ta.join()
    tb.join()
    comps_a = [f.result(timeout=30.0) for f in futs_a]
    comps_b = [f.result(timeout=30.0) for f in futs_b]
    assert len(comps_a) == n and all(c.ok for c in comps_a)
    assert len(comps_b) == n and all(c.ok for c in comps_b)
    assert executed["old"] + executed["new"] == n     # exactly once each
    assert executed["b"] == n
    assert pa.stats()["submitted"] == pa.stats()["completed"] == n
    shell.drain()
    shell.close()


def test_reconfigure_waits_out_long_invocation_on_lane():
    """Quiesce must include a long-running lane execution: the swap
    happens only after it completes, and nothing is lost."""
    shell = _shell(lanes=True, n_vfpgas=1)
    done_marker = []

    def long_fn(iface, vf, x):
        time.sleep(0.15)
        done_marker.append("long")
        return x

    shell.load_app(0, AppArtifact(name="long", fn=long_fn))
    port = shell.attach(0)
    fut = port.submit(Invocation.from_sg(_sg(4096)))
    time.sleep(0.02)                           # in flight on the lane
    shell.reconfigure(0, make_passthrough_artifact())
    assert done_marker == ["long"]             # drained, not killed
    assert fut.result(timeout=30.0).ok
    comp = port.submit(Invocation.from_sg(_sg())).result(timeout=30.0)
    assert comp.ok                             # new logic live
    shell.drain()
    shell.close()


# ================================================= billing parity ==========
@pytest.mark.parametrize("with_io", [False, True])
def test_billing_identical_lanes_on_vs_off(with_io):
    """The lanes move WHERE execution happens, never WHAT is billed:
    per-tenant byte totals, completions, and batch counts must match the
    serialized baseline exactly."""
    def run(lanes):
        shell = _shell(lanes=lanes)
        shell.register_tenant("gold", 2.0, slots=(0,))
        shell.register_tenant("bronze", 1.0, slots=(1,))
        shell.load_app(0, make_passthrough_artifact())
        shell.load_app(1, make_passthrough_artifact())
        p0, p1 = shell.attach(0), shell.attach(1)
        for i in range(40):
            p0.submit(Invocation.from_sg(_sg(512, i % 251)))
            p1.submit(Invocation.from_sg(_sg(1024, i % 251)))
            if with_io:
                p0.submit(Invocation.io(256, tag="io"))
        shell.drain()
        stats = shell.scheduler.stats()["tenants"]
        out = {t: (s["bytes"], s["completions"], s["submissions"])
               for t, s in stats.items()}
        shell.close()
        return out

    assert run(lanes=True) == run(lanes=False)
