"""Serving gateway + chunked prefill: open-arrival frontend contracts.

Pins the PR's three load-bearing claims end to end:

  * **Chunked-prefill parity.**  Splitting a long prompt's prefill into
    fixed-size chunks interleaved with decode changes WHEN compute runs,
    never WHAT it computes: token streams are identical to one-shot
    prefill for any chunk size, greedy AND sampled, prefix sharing on
    and off (counter-based sampling keys make the streams scheduling-
    invariant).
  * **Admission control.**  Head-of-line fix (bounded skip-ahead that
    preserves per-tenant FIFO), SLO feasibility rejection, queued-
    deadline expiry, deadline-driven priority aging, and GATEWAY_FULL
    backpressure — all typed, all observable in counters.
  * **Exactly-once streams.**  Every accepted request completes exactly
    once with the same tokens a direct engine run would produce; every
    rejected/expired request carries a typed error; nothing is lost or
    duplicated under churn.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Shell, ShellConfig
from repro.core.faults import FaultKind
from repro.core.port import Invocation, PortError
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.gateway import ServingGateway

PAGE = 16
POOL = 128


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _prompts(cfg, lens, seed=7):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=n).tolist() for n in lens]


def _engine(cfg, params, *, max_batch=4, max_len=512, seed=3,
            prefill_chunk=None, n_pages=256, page=16, sharing=True,
            **kw):
    mmu = MMU(MMUConfig(page_size=page, n_pages=n_pages,
                        prefix_sharing=sharing))
    return ServingEngine(cfg, params, mmu, max_batch=max_batch,
                         max_len=max_len, seed=seed,
                         prefill_chunk=prefill_chunk, **kw)


def _run(cfg, params, prompts, *, chunk, temp, sharing=True, new=10):
    eng = _engine(cfg, params, prefill_chunk=chunk, sharing=sharing)
    for p in prompts:
        eng.submit(p, max_new_tokens=new, temperature=temp,
                   top_k=5 if temp else 0)
    eng.run()
    return ({r.rid: r.out_tokens for r in eng.completed},
            eng.prefill_computed + eng.prefill_skipped,
            eng.prefill_skipped)


# =========================================== chunked-prefill parity ========
@pytest.mark.parametrize("temp", [0.0, 0.8])
def test_chunked_prefill_token_parity_any_chunk_size(served, temp):
    """Chunked == one-shot, token for token, greedy and sampled — the
    counter-based sampling keys make streams invariant to how prefill
    is scheduled.  Prompt tokens processed must also balance exactly."""
    cfg, params = served
    prompts = _prompts(cfg, (97, 5, 33, 160, 12))
    base, base_total, _ = _run(cfg, params, prompts, chunk=None, temp=temp)
    assert len(base) == len(prompts)
    for chunk in (8, 32, 64):
        got, total, _ = _run(cfg, params, prompts, chunk=chunk, temp=temp)
        assert got == base, f"chunk={chunk} temp={temp} diverged"
        assert total == base_total, "prefill token accounting drifted"


@pytest.mark.parametrize("sharing", [True, False])
def test_chunked_prefill_parity_with_prefix_sharing(served, sharing):
    """Same token contract when prompts share a long prefix, sharing on
    and off.  (A chunking row defers its prefix-index publication, so a
    co-admitted sharer computes its own prefix rather than reading
    unwritten KV — tokens must still match one-shot exactly.)"""
    cfg, params = served
    rng = np.random.RandomState(11)
    prefix = rng.randint(0, cfg.vocab_size, size=64).tolist()
    tails = [rng.randint(0, cfg.vocab_size, size=n).tolist()
             for n in (40, 5, 23)]
    prompts = [prefix + t for t in tails]
    base, _, base_skip = _run(cfg, params, prompts, chunk=None, temp=0.8,
                              sharing=sharing, new=8)
    got, _, _ = _run(cfg, params, prompts, chunk=16, temp=0.8,
                     sharing=sharing, new=8)
    assert got == base
    assert (base_skip > 0) == sharing, \
        "one-shot admission must share the prefix iff sharing is on"


def test_chunked_rows_publish_prefix_only_after_final_chunk(served):
    """The safety half of chunked prefill x prefix sharing: a chunking
    row's prompt pages are not canonical while its KV is still landing
    (mid-chunk sharers would read garbage), and become shareable the
    moment the final chunk completes."""
    cfg, params = served
    eng = _engine(cfg, params, prefill_chunk=16)
    rng = np.random.RandomState(17)
    prefix = rng.randint(0, cfg.vocab_size, size=64).tolist()
    eng.submit(prefix + rng.randint(0, cfg.vocab_size, size=40).tolist(),
               max_new_tokens=16)
    eng.step()
    assert any(r is not None and r.prefill_pos >= 0 for r in eng.slots)
    assert eng.mmu.probe_prefix(prefix) == 0      # mid-chunk: unpublished
    for _ in range(20):
        eng.step()
        if not any(r is not None and r.prefill_pos >= 0
                   for r in eng.slots):
            break
    assert eng.mmu.probe_prefix(prefix) == 64     # final chunk: canonical
    skipped = eng.prefill_skipped
    eng.submit(prefix + rng.randint(0, cfg.vocab_size, size=5).tolist(),
               max_new_tokens=2)
    eng.step()                                    # late sharer maps it
    assert eng.prefill_skipped >= skipped + 64
    eng.run()


# ================================================ head-of-line fix =========
def _tiny_engine(cfg, params, **kw):
    # 8 pages x 4 tokens = 32-token budget: a 20+16 request can never fit
    return _engine(cfg, params, max_batch=2, max_len=64, page=4,
                   n_pages=8, **kw)


def test_admit_skips_blocked_head_for_fitting_request(served):
    """A request too big for the page budget no longer starves everyone
    behind it: admission scans past the stuck head and admits a smaller
    request from another tenant."""
    cfg, params = served
    eng = _tiny_engine(cfg, params)
    big = eng.submit(list(range(3, 23)), max_new_tokens=16, tid=0)
    small = eng.submit(list(range(3, 7)), max_new_tokens=8, tid=1)
    eng.step()
    live = {r.rid for r in eng.slots if r is not None}
    assert small in live and big not in live
    assert [r.rid for r in eng.queue] == [big]


def test_admit_skip_ahead_preserves_per_tenant_fifo(served):
    """Skip-ahead never reorders one tenant's own stream: a small
    request behind its tenant's blocked head waits; an independent
    tenant leapfrogs."""
    cfg, params = served
    eng = _tiny_engine(cfg, params)
    big0 = eng.submit(list(range(3, 23)), max_new_tokens=16, tid=0)
    small0 = eng.submit(list(range(3, 7)), max_new_tokens=8, tid=0)
    small1 = eng.submit(list(range(3, 7)), max_new_tokens=8, tid=1)
    eng.step()
    live = {r.rid for r in eng.slots if r is not None}
    assert small1 in live
    assert big0 not in live and small0 not in live
    assert [r.rid for r in eng.queue] == [big0, small0]


def test_admit_window_bounds_the_skip_ahead(served):
    """admit_window=1: once the head blocks, nothing deeper is scanned
    — the fix is bounded, not an unbounded reorder."""
    cfg, params = served
    eng = _tiny_engine(cfg, params, admit_window=1)
    eng.submit(list(range(3, 23)), max_new_tokens=16, tid=0)
    eng.submit(list(range(3, 7)), max_new_tokens=2, tid=1)
    eng.step()
    assert eng.active == 0 and len(eng.queue) == 2


# =========================================== engine latency stats ==========
def test_engine_run_reports_ttft_tpot_percentiles(served):
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, max_len=128)
    for p in _prompts(cfg, (9, 17)):
        eng.submit(p, max_new_tokens=4)
    stats = eng.run()
    for key in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms",
                "tpot_p99_ms"):
        assert stats[key] > 0.0
    assert stats["ttft_p99_ms"] >= stats["ttft_p50_ms"]


# ================================================== gateway streams ========
def test_gateway_streams_match_direct_engine_exactly_once(served):
    """Oracle parity: the gateway's continuous backfill over a 2-slot
    engine produces byte-identical sampled streams to a direct 4-slot
    engine run — and every stream completes exactly once."""
    cfg, params = served
    prompts = _prompts(cfg, (41, 7, 19, 64, 11), seed=13)
    ref_eng = _engine(cfg, params, seed=5)
    for p in prompts:
        ref_eng.submit(p, max_new_tokens=8, temperature=0.8, top_k=5)
    ref_eng.run()
    ref = [r.out_tokens for r in sorted(ref_eng.completed,
                                        key=lambda r: r.rid)]

    eng = _engine(cfg, params, max_batch=2, seed=5)
    gw = ServingGateway(eng, mode="continuous", admission="fifo")
    streams = [gw.submit(p, max_new_tokens=8, temperature=0.8, top_k=5)
               for p in prompts]
    gw.drain()
    got = [s.tokens for s in sorted(gw.completed, key=lambda s: s.gid)]
    assert got == ref
    # exactly-once: every stream done, none duplicated, sink drained
    assert [s.gid for s in sorted(streams, key=lambda s: s.gid)] \
        == sorted(s.gid for s in gw.completed)
    assert all(s.done and s.error is None for s in streams)
    assert not gw.streams and not gw.queue
    st = gw.stats()
    assert st["completed"] == st["dispatched"] == len(prompts)
    assert st["goodput"] > 0 and st["ttft_p99_ms"] >= st["ttft_p50_ms"]
    assert st["tpot_p50_ms"] > 0


def test_continuous_backfills_while_wave_waits_for_drain(served):
    """The A/B the benchmark measures: continuous mode dispatches a
    queued arrival while a long request still runs; wave mode holds it
    until the engine fully drains."""
    cfg, params = served

    def dispatch_overlap(mode):
        eng = _engine(cfg, params, max_batch=2, max_len=128, seed=0)
        gw = ServingGateway(eng, mode=mode, admission="fifo")
        gw.submit(list(range(3, 9)), max_new_tokens=2)
        long = gw.submit(list(range(3, 12)), max_new_tokens=24)
        third = gw.submit(list(range(3, 7)), max_new_tokens=2)
        for _ in range(200):
            gw.step()
            if third.rid is not None:
                break
        overlap = not long.done
        gw.drain()
        assert third.done and long.done
        return overlap

    assert dispatch_overlap("continuous") is True
    assert dispatch_overlap("wave") is False


# ================================================ SLO admission ============
def test_slo_infeasible_deadline_rejected_at_the_door(served):
    """Once the timing model is warm, a deadline below the best-case
    service estimate rejects immediately with a typed, non-retryable
    PortError — no page credits burned on a guaranteed miss."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, max_len=128)
    gw = ServingGateway(eng, min_obs=1)
    for p in _prompts(cfg, (9, 13)):
        gw.submit(p, max_new_tokens=4)
    gw.drain()
    assert gw._service_estimate(32, 8) is not None     # model is warm
    with pytest.raises(PortError) as ei:
        gw.submit(list(range(3, 35)), max_new_tokens=8, deadline_s=1e-6)
    assert ei.value.kind == FaultKind.SLO_INFEASIBLE
    assert not ei.value.retryable
    assert gw.rejected_infeasible == 1
    assert gw.rejected[-1].error is ei.value
    assert gw.stats()["rejected_infeasible"] == 1


def test_queued_request_expires_past_its_deadline(served):
    """A request whose deadline passes while queued is expired before
    it wastes a prefill: typed SLO_EXPIRED error, never dispatched."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, max_len=128)
    gw = ServingGateway(eng)            # cold EWMAs: door check skipped
    s = gw.submit(list(range(3, 12)), max_new_tokens=4, deadline_s=0.01)
    time.sleep(0.02)
    gw.step()
    assert s.rejected and s.error.kind == FaultKind.SLO_EXPIRED
    assert s.rid is None and not s.done
    assert gw.expired == 1 and not gw.queue


def test_priority_ages_as_deadline_approaches(served):
    """Inside the aging window a deadlined request's effective priority
    grows (bounded by aging_max) and it leapfrogs earlier no-deadline
    arrivals in dispatch order."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=1, max_len=128)
    gw = ServingGateway(eng, aging_window_s=10.0, aging_max=4)
    lo = gw.submit(list(range(3, 9)), max_new_tokens=2)
    hot = gw.submit(list(range(3, 10)), max_new_tokens=2, deadline_s=5.0)
    gw.step()
    assert hot.eff_priority > hot.priority
    assert hot.eff_priority <= hot.priority + 4
    assert hot.rid is not None and lo.rid is None     # aged ahead
    gw.drain()
    assert lo.done and hot.done


def test_gateway_full_backpressure_is_typed_and_retryable(served):
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, max_len=128)
    gw = ServingGateway(eng, max_queue=1)
    s1 = gw.submit(list(range(3, 8)), max_new_tokens=2)
    with pytest.raises(PortError) as ei:
        gw.submit(list(range(3, 8)), max_new_tokens=2)
    assert ei.value.kind == FaultKind.GATEWAY_FULL and ei.value.retryable
    assert gw.rejected_full == 1
    gw.drain()
    assert s1.done and len(gw.completed) == 1


def test_nothing_lost_or_duplicated_under_slo_churn(served):
    """Accounting identity under mixed accept/expire/complete traffic:
    submitted == completed + expired, each exactly once, completed
    streams carry their full token budget."""
    cfg, params = served
    eng = _engine(cfg, params, max_batch=2, max_len=128)
    gw = ServingGateway(eng)
    ok = [gw.submit(p, max_new_tokens=4, priority=pr)
          for pr, p in enumerate(_prompts(cfg, (9, 21, 13), seed=23))]
    dead = gw.submit(list(range(3, 9)), max_new_tokens=4,
                     deadline_s=0.005)
    time.sleep(0.01)
    gw.drain()
    assert dead.rejected and dead.error.kind == FaultKind.SLO_EXPIRED
    assert all(s.done and len(s.tokens) == 4 for s in ok)
    gids = sorted(s.gid for s in gw.completed) \
        + sorted(s.gid for s in gw.rejected)
    assert sorted(gids) == list(range(gw.submitted))
    st = gw.stats()
    assert st["submitted"] == st["completed"] + st["expired"]
    assert st["queued"] == 0 and not gw.streams


# ============================================ shell-bound front door =======
def _shell():
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
        n_vfpgas=2))
    s.build()
    return s


def test_gateway_admissions_are_port_billed_and_quarantine_applies(served):
    """Every accepted request is billed through port.submit as a
    gateway_admit IO — tenant accounting sees the front door — and a
    quarantined tenant is rejected at submit with the typed error."""
    cfg, params = served
    shell = _shell()
    try:
        eng = ServingEngine(cfg, params, shell.services.get("mmu"),
                            max_batch=2, max_len=128, shell=shell,
                            slot=0, tenant="gold")
        gw = ServingGateway(eng, admission="fifo")
        for p in _prompts(cfg, (9, 13, 7), seed=31):
            gw.submit(p, max_new_tokens=2)
        gw.drain()
        assert eng.flush_io()
        assert not gw._admit_futs                     # admissions settled
        ten = shell.scheduler.stats()["tenants"]["gold"]
        # 3 gateway_admit IOs + per-step decode IOs all land on the tenant
        assert ten["completions"] >= 3
        shell.health.quarantine("gold")
        with pytest.raises(PortError) as ei:
            gw.submit(list(range(3, 8)), max_new_tokens=2)
        assert ei.value.kind == FaultKind.QUARANTINED
    finally:
        shell.close()


def test_scheduler_accounts_deadline_misses_per_tenant(served):
    """The shell scheduler's QoS counters gained deadline_misses: an IO
    completing past its absolute deadline is counted against its
    tenant; on-time (or deadline-free) IOs are not."""
    del served
    shell = _shell()
    try:
        shell.register_tenant("gold", 1.0, slots=(0,))
        port = shell.attach(0, tenant="gold")
        port.submit(Invocation.io(64, tenant="gold",
                                  deadline_s=1e-9)).result(timeout=10.0)
        port.submit(Invocation.io(64, tenant="gold")).result(timeout=10.0)
        ten = shell.scheduler.stats()["tenants"]["gold"]
        assert ten["deadline_misses"] >= 1
        assert ten["completions"] >= 2
    finally:
        shell.close()
