"""Substrate tests: optimizer, data determinism, checkpoint/restart,
trainer fault tolerance, compression, AES/HLL app math."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # offline env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.services.compression import (CompressionConfig,
                                             GradCompression)
from repro.core.services import encryption as E
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.optim import adamw
from repro.train.loop import TrainConfig, Trainer


# ============================================================== optimizer ===
def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=200)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw.update(grads, opt, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_clips_global_norm():
    params = {"w": jnp.zeros((4,))}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw.update(grads, opt, params, cfg)
    assert float(m["grad_norm"]) > 1e6 - 1     # reported pre-clip


# =================================================================== data ===
def test_data_determinism_and_restart_purity():
    cfg = DataConfig(seq_len=64, global_batch=2, vocab_size=1000, seed=3)
    c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(c1.batch(step)["tokens"],
                                      c2.batch(step)["tokens"])
    assert not np.array_equal(c1.batch(0)["tokens"],
                              c1.batch(1)["tokens"])


# ============================================================= checkpoint ===
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"a": jnp.arange(8.0), "n": {"b": jnp.ones((3, 3))}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, state))
    assert mgr.all_steps() == [20, 30]            # retention
    restored, at = mgr.restore(state)
    assert at == 30
    np.testing.assert_allclose(restored["a"], np.arange(8.0) + 30)


def test_checkpoint_fingerprint_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.zeros(2)}, fingerprint="modelA")
    with pytest.raises(ValueError, match="fingerprint"):
        mgr.restore({"a": jnp.zeros(2)}, expect_fingerprint="modelB")


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, {"a": jnp.zeros((256, 256))})
    mgr.wait()
    assert not list(tmp_path.glob(".tmp_*"))


# ================================================================ trainer ====
@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeConfig("t", "train", 32, 2)
    return cfg, shape


def test_trainer_restart_bit_identical(tiny, tmp_path):
    cfg, shape = tiny
    kw = dict(steps=8, log_every=2, ckpt_every=4, seed=11)
    t1 = Trainer(cfg, shape, TrainConfig(ckpt_dir=str(tmp_path / "a"), **kw))
    r1 = t1.run()
    t2 = Trainer(cfg, shape, TrainConfig(ckpt_dir=str(tmp_path / "b"),
                                         fail_at_step=6, **kw))
    r2 = t2.run()
    assert r2["restarts"] == 1
    assert r1["final_loss"] == r2["final_loss"]   # bitwise identical


def test_trainer_elastic_restore_across_instances(tiny, tmp_path):
    """A NEW trainer process restores the old checkpoint (elastic re-mesh
    degenerate case: same topology, fresh process)."""
    cfg, shape = tiny
    d = str(tmp_path / "c")
    t1 = Trainer(cfg, shape, TrainConfig(steps=4, ckpt_every=4, seed=11,
                                         ckpt_dir=d))
    t1.run()
    t2 = Trainer(cfg, shape, TrainConfig(steps=8, ckpt_every=8, seed=11,
                                         ckpt_dir=d))
    t2.restore()
    assert t2.step == 4


def test_trainer_straggler_skip(tiny, tmp_path):
    cfg, shape = tiny
    t = Trainer(cfg, shape, TrainConfig(
        steps=4, ckpt_every=0, seed=1, ckpt_dir=str(tmp_path / "d"),
        straggler_steps=(2, 3), straggler_delay_s=3.0,
        batch_timeout_s=0.05))
    r = t.run()
    assert r["final_step"] == 4
    assert len(r["skipped_steps"]) >= 1           # waited-out straggler


# ============================================================ compression ===
def test_compression_roundtrip_error_bounded():
    svc = GradCompression(CompressionConfig(bits=8, block=64))
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
    payload = svc.compress_leaf(g)
    ghat = svc.decompress_leaf(payload)
    # int8 blockwise: error bounded by scale/2 per element
    scale = np.abs(np.asarray(g)).max() / 127
    assert float(jnp.max(jnp.abs(ghat - g))) <= scale * 1.01


def test_compression_error_feedback_unbiased():
    """EF: the *accumulated* update converges to the true gradient sum."""
    svc = GradCompression(CompressionConfig(bits=4, block=32))
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (256,)) * 0.1}
    ef = svc.init_state(g)
    total_hat = jnp.zeros((256,))
    for _ in range(30):
        ghat, ef, _ = svc.apply(g, ef)
        total_hat = total_hat + ghat["w"]
    total_true = g["w"] * 30
    rel = float(jnp.linalg.norm(total_hat - total_true)
                / jnp.linalg.norm(total_true))
    assert rel < 0.05                              # residual is bounded


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 400), scale=st.floats(1e-4, 10.0))
def test_compression_quantize_property(n, scale):
    svc = GradCompression(CompressionConfig(bits=8, block=64))
    g = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    ghat = svc.decompress_leaf(svc.compress_leaf(g))
    assert ghat.shape == g.shape
    err = jnp.abs(ghat - g)
    assert float(jnp.max(err)) <= scale * 8 / 127 + 1e-6 or \
        float(jnp.max(err)) <= float(jnp.max(jnp.abs(g))) / 127 * 1.02


# ==================================================================== AES ====
def test_aes_fips197_vector():
    key = np.frombuffer(bytes.fromhex(
        "000102030405060708090a0b0c0d0e0f"), np.uint8).copy()
    pt = np.frombuffer(bytes.fromhex(
        "00112233445566778899aabbccddeeff"), np.uint8).copy()
    rk = jnp.asarray(E.expand_key(key))
    ct = np.asarray(E.encrypt_block(jnp.asarray(pt[None]), rk))[0]
    assert ct.tobytes().hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"


def test_aes_cbc_chains():
    key = np.arange(16, dtype=np.uint8)
    rk = jnp.asarray(E.expand_key(key))
    blocks = jnp.asarray(np.zeros((4, 16), np.uint8))
    iv = jnp.zeros((16,), jnp.uint8)
    cbc = np.asarray(E.aes_cbc(blocks, iv, rk))
    ecb = np.asarray(E.aes_ecb(blocks, rk))
    assert not (cbc[1:] == ecb[1:]).all()          # chaining differs
    # manual chain check for block 1
    b1 = jnp.asarray(cbc[0] ^ np.zeros(16, np.uint8))
    exp = np.asarray(E.encrypt_block(b1[None], rk))[0]
    np.testing.assert_array_equal(cbc[1], exp)


def test_aes_multistream_equals_per_stream():
    key = np.arange(16, dtype=np.uint8)
    rk = jnp.asarray(E.expand_key(key))
    data = jnp.asarray(np.random.RandomState(0).randint(
        0, 255, (3, 5, 16), dtype=np.uint8))
    ivs = jnp.asarray(np.random.RandomState(1).randint(
        0, 255, (3, 16), dtype=np.uint8))
    ms = E.aes_cbc_multistream(data, ivs, rk)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(ms[i]), np.asarray(E.aes_cbc(data[i], ivs[i], rk)))


# ==================================================================== HLL ====
@pytest.mark.parametrize("n,tol", [(1000, 0.10), (100_000, 0.05)])
def test_hll_accuracy(n, tol):
    from repro.apps import hll_count
    items = np.unique(np.random.RandomState(0).randint(
        0, 1 << 31, size=2 * n))[:n]
    est = hll_count(items, p=12)
    assert abs(est - n) / n < tol


def test_hll_merge_equals_union():
    from repro.apps import hll_estimate, hll_merge, hll_sketch
    a = np.arange(0, 5000, dtype=np.int64)
    b = np.arange(2500, 7500, dtype=np.int64)
    sa = hll_sketch(jnp.asarray(a), p=12)
    sb = hll_sketch(jnp.asarray(b), p=12)
    su = hll_sketch(jnp.asarray(np.union1d(a, b)), p=12)
    np.testing.assert_array_equal(np.asarray(hll_merge(sa, sb)),
                                  np.asarray(su))
