"""Pre-copy migration surface: MMU dirty tracking, container integrity,
transfer-shape buckets, warm-round failure containment, and the
cross-seed determinism matrix for migrate/recover parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (FaultKind, FaultPlan, FaultSpec, MigrationError,
                        Shell, ShellConfig)
from repro.core import bitstream as B
from repro.core.bitstream import BitstreamError
from repro.core.migrate import migrate_precopy
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.paged_model import bucket_pages

PAGE = 16
POOL = 128


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _shell(n_vfpgas=2):
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
        n_vfpgas=n_vfpgas))
    s.build()
    return s


def _engine(cfg, params, shell, *, rid_base=0, seed=0):
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=3, max_len=128, shell=shell, slot=0,
                         tenant="gold", rid_base=rid_base, seed=seed)


# ==================================================== MMU dirty bitmap =====
def test_dirty_bitmap_alloc_extend_write_semantics():
    """Fresh pages, appended tails, and for-write translations all mark
    dirty; ``dirty_snapshot`` peeks without clearing; ``clear_dirty`` is
    the only way flags drop (short of the page dying)."""
    mmu = MMU(MMUConfig(page_size=4, n_pages=16))
    mmu.alloc_seq(1, 10)                        # 3 fresh pages
    keys = {("d", p.ppage) for p in mmu._seqs[1].pages}
    assert mmu.dirty_snapshot() == keys
    assert mmu.dirty_snapshot() == keys          # peek-only, no clear
    assert mmu.utilization()["dirty_pages"] == 3
    mmu.clear_dirty()
    assert mmu.dirty_snapshot() == set()
    # append: the tail page the decode step wrote is dirty again
    mmu.extend_seq(1, 1)
    tail = mmu._seqs[1].pages[-1]
    assert ("d", tail.ppage) in mmu.dirty_snapshot()
    # a write-intent translation marks its page
    mmu.clear_dirty()
    pp, _ = mmu.translate(1, 0, for_write=True)
    assert ("d", pp) in mmu.dirty_snapshot()
    # explicit range marking (the chunked-prefill path) covers the pages
    # holding [start, end)
    mmu.clear_dirty()
    mmu.mark_dirty_range(1, 0, 11)
    assert len(mmu.dirty_snapshot()) == len(mmu._seqs[1].pages)
    # a freed sequence's pages drop their flags with the pages
    mmu.free_seq(1)
    assert mmu.dirty_snapshot() == set()


def test_dirty_bitmap_cow_marks_private_copy_not_canonical():
    """A CoW break marks the NEW private page dirty; the canonical
    shared page the other sequence keeps is untouched."""
    mmu = MMU(MMUConfig(page_size=4, n_pages=32))
    prompt = list(range(10, 22))                 # 3 full pages
    mmu.alloc_seq(1, 12, prompt_tokens=prompt)
    assert mmu.alloc_seq(2, 12, prompt_tokens=prompt) == 12  # all shared
    shared_pp = mmu._seqs[2].pages[0].ppage
    assert mmu._ref[shared_pp] == 2
    mmu.clear_dirty()
    new_pp, _ = mmu.translate(2, 0, for_write=True)
    assert new_pp != shared_pp                   # the copy broke off
    d = mmu.dirty_snapshot()
    assert ("d", new_pp) in d
    assert ("d", shared_pp) not in d
    assert mmu._ref[shared_pp] == 1 and mmu._ref[new_pp] == 1


def test_dirty_bitmap_follows_group_eviction_and_fault_in():
    """Evicting a dirty shared page moves the flag to its host-slot
    identity (the content is what's dirty, not the address); faulting it
    back in retires the host flag with the slot."""
    mmu = MMU(MMUConfig(page_size=4, n_pages=4, host_pool_pages=8))
    prompt = list(range(20, 28))                 # 2 full pages
    mmu.alloc_seq(1, 8, prompt_tokens=prompt)
    assert mmu.alloc_seq(2, 8, prompt_tokens=prompt) == 8
    mmu.clear_dirty()
    mmu.mark_dirty_range(1, 4, 8)                # tail page dirty
    tail_pp = mmu._seqs[1].pages[1].ppage
    assert ("d", tail_pp) in mmu.dirty_snapshot()
    mmu.alloc_seq(9, 4 * (len(mmu._free) + 1))   # pressure -> group evict
    p1, p2 = mmu._seqs[1].pages[1], mmu._seqs[2].pages[1]
    assert p1.on_host and p2.on_host and p1.host_slot == p2.host_slot
    assert mmu._host_ref[p1.host_slot] == 2      # refs moved as a group
    d = mmu.dirty_snapshot()
    assert ("h", p1.host_slot) in d
    # the freed device page was recycled to the pressure seq: if its
    # address is dirty again, that flag belongs to the NEW owner
    if ("d", tail_pp) in d:
        assert tail_pp in {p.ppage for p in mmu._seqs[9].pages
                           if not p.on_host}
    hslot = p1.host_slot
    mmu.free_seq(9)                              # room to fault back in
    mmu.translate(1, 4)
    assert not mmu._seqs[1].pages[1].on_host
    assert ("h", hslot) not in mmu.dirty_snapshot()


def test_dirty_clean_pages_skippable_is_sound(served):
    """The pre-copy soundness pin at the engine level: pages NOT in the
    dirty set after ``clear_dirty`` are byte-identical to their state at
    clear time — shipping only the dirty delta loses nothing."""
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    for n in (18, 40):
        eng.submit(list(range(3, 3 + n)), max_new_tokens=8)
    for _ in range(3):
        eng.step()
    mmu = eng.mmu
    live = mmu.live_page_keys()
    before = {k: eng._pager_gather(k[1]) for k in live if k[0] == "d"}
    mmu.clear_dirty()
    for _ in range(2):                           # decode dirties tails
        eng.step()
    dirty = mmu.dirty_snapshot()
    clean = [k for k in before if k not in dirty
             and k in mmu.live_page_keys()]
    assert clean, "expected some page to stay clean across two steps"
    assert dirty, "decode steps must dirty the tail pages"
    for k in clean:
        after = eng._pager_gather(k[1])
        np.testing.assert_array_equal(np.asarray(before[k]["k"]),
                                      np.asarray(after["k"]))
        np.testing.assert_array_equal(np.asarray(before[k]["v"]),
                                      np.asarray(after["v"]))
    shell.close()


# ============================================== container integrity ========
def test_container_integrity_tamper_and_unknown_algo_rejected():
    blob = B.encode("app", {"x": 1}, arrays={"a": np.arange(64)})
    kind, header, arrays = B.decode(blob)        # round-trip intact
    assert kind == "app" and header == {"x": 1}
    np.testing.assert_array_equal(arrays["a"], np.arange(64))
    # one flipped payload bit -> refused before np.load ever runs
    tampered = bytearray(blob)
    tampered[-3] ^= 0xFF
    with pytest.raises(BitstreamError, match="integrity check failed"):
        B.decode(bytes(tampered))
    # a forged algo name is refused outright, not skipped (treating it
    # as "no hash" would let a forger strip verification)
    forged = blob.replace(b'"algo": "blake2b"', b'"algo": "md5x512"', 1)
    assert forged != blob
    with pytest.raises(BitstreamError, match="unsupported bitstream "
                                             "integrity algo"):
        B.decode(forged)
    # pre-integrity containers (no stanza) stay loadable
    import json
    import struct
    hjson = json.dumps({"kind": "raw", "header": {"v": 7},
                        "arrays": None}).encode()
    legacy = (B.MAGIC + struct.pack("<HI", B.FORMAT_VERSION, len(hjson))
              + hjson)
    assert B.decode(legacy)[1] == {"v": 7}


def test_container_stream_codec_chunking_invariant():
    """decode_stream must not care where chunk boundaries fall, and the
    incremental hash must equal the one-shot hash."""
    header = {"nested": {"deep": [1, 2, 3]}}
    arrays = {"kv": np.random.default_rng(0).normal(size=(6, 8)),
              "small": np.arange(3, dtype=np.int32)}
    blob = B.encode("migration", header, arrays)
    for chunk_bytes in (7, 1 << 20):
        chunks = list(B.encode_stream("migration", header, arrays,
                                      chunk_bytes=chunk_bytes))
        assert b"".join(chunks) == blob
        kind, h2, a2 = B.decode_stream(chunks, expect_kind="migration")
        assert kind == "migration" and h2 == header
        np.testing.assert_array_equal(a2["kv"], arrays["kv"])
    # tampering a mid-stream chunk fails the incremental hash too
    chunks = list(B.encode_stream("migration", header, arrays,
                                  chunk_bytes=64))
    bad = bytearray(chunks[-1])
    bad[0] ^= 0x01
    with pytest.raises(BitstreamError, match="integrity check failed"):
        B.decode_stream(chunks[:-1] + [bytes(bad)])


def test_bucket_pages_powers_of_two():
    assert bucket_pages(0) == 4 and bucket_pages(1) == 4
    assert bucket_pages(4) == 4
    assert bucket_pages(5) == 8 and bucket_pages(8) == 8
    assert bucket_pages(9) == 16
    assert bucket_pages(3, floor=1) == 4 and bucket_pages(1, floor=1) == 1


# ================================================= pre-copy end to end =====
def test_precopy_mid_decode_token_parity(served):
    """The pre-copy analogue of the stop-and-copy acceptance pin: warm
    rounds ship pages while the source decodes, the freeze ships only
    the delta, and the destination continues token-for-token."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    eng_dst = _engine(cfg, params, dst, rid_base=1000)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    reqs = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
            (list(range(3, 12)), 1.3)]
    for prompt, temp in reqs:
        eng_src.submit(prompt, max_new_tokens=12, temperature=temp)
        oracle.submit(prompt, max_new_tokens=12, temperature=temp)
    for _ in range(4):                           # mid-decode
        eng_src.step()
        oracle.step()
    report = migrate_precopy(src, dst, "gold", max_rounds=4)
    assert report.precopy_rounds >= 1
    assert report.precopy_pages >= report.n_pages
    assert 0 < report.delta_pages <= report.n_pages
    # the source keeps decoding DURING warm rounds, so oracle steps must
    # match: run the oracle forward by the same number of steps
    for _ in range(report.precopy_rounds):
        oracle.step()
    while eng_dst.pending():
        eng_dst.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng_dst.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want
    # the source is fully evacuated, the destination owns every page
    assert src.services.get("mmu").utilization()["pages_used"] == 0
    assert eng_src.active == 0
    src.close()
    dst.close()


def test_precopy_warm_fault_releases_staging_source_serves(served):
    """A warm-round fault (second round, staging populated) aborts the
    move, releases every staged destination page, and leaves the source
    serving — it was never paused."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    _engine(cfg, params, dst, rid_base=1000)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    for prompt in (list(range(3, 20)), list(range(3, 40))):
        eng_src.submit(prompt, max_new_tokens=10)
        oracle.submit(prompt, max_new_tokens=10)
    for _ in range(2):
        eng_src.step()
        oracle.step()
    # after=1: round 0 stages the full footprint, round 1 fires
    src.set_fault_plan(FaultPlan([FaultSpec(
        FaultKind.MIGRATION_FAIL, site="migrate.precopy", after=1)]))
    with pytest.raises(MigrationError, match="keeps serving"):
        migrate_precopy(src, dst, "gold", max_rounds=4)
    src.set_fault_plan(None)
    # every reserved destination page went back to the free pool
    du = dst.services.get("mmu").utilization()
    assert du["pages_used"] == 0
    assert not dst.services.get("mmu")._ref
    # one decode step ran between round 0 and the round-1 fault
    oracle.step()
    while eng_src.pending():
        eng_src.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng_src.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want                           # source never skipped a beat
    src.close()
    dst.close()


# =============================================== cross-seed determinism ====
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_cross_seed_recover_and_precopy_parity(served, seed):
    """The single-seed parity pins in test_migrate/test_faults, swept
    over a 4-seed matrix: in-place recovery followed by a pre-copy
    migration reproduces the oracle's sampled token streams for every
    PRNG seed, with zero lost or duplicated completions."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src, seed=seed)
    eng_dst = _engine(cfg, params, dst, rid_base=1000, seed=seed)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128, seed=seed)
    reqs = [(list(range(3, 10)), 0.0), (list(range(3, 24)), 0.9),
            (list(range(3, 15)), 1.3)]
    for prompt, temp in reqs:
        eng_src.submit(prompt, max_new_tokens=10, temperature=temp)
        oracle.submit(prompt, max_new_tokens=10, temperature=temp)
    for _ in range(2):
        eng_src.step()
        oracle.step()
    rep_r = src.recover_slot(0)                  # KV-intact local recovery
    assert rep_r.n_requests == 3
    for _ in range(2):
        eng_src.step()
        oracle.step()
    rep_m = migrate_precopy(src, dst, "gold", max_rounds=3)
    for _ in range(rep_m.precopy_rounds):        # source decoded per round
        oracle.step()
    while eng_dst.pending():
        eng_dst.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng_dst.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want
    assert len(eng_dst.completed) == 3           # exactly once each
    assert src.services.get("mmu").utilization()["pages_used"] == 0
    src.close()
    dst.close()
