"""Shell behaviour: three-layer lifecycle, reconfiguration contracts,
credits/fairness invariants, MMU paging, sniffer, interrupts."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # offline env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.apps import (make_aes_artifact, make_hll_artifact,
                        make_passthrough_artifact)
from repro.core import (Alloc, AppArtifact, Oper, SgEntry, Shell,
                        ShellConfig)
from repro.core.credits import (CreditAccount, Link, RRArbiter,
                                jains_index, packetize)
from repro.core.services import (AESConfig, MMU, MMUConfig, PageFaultError,
                                 SnifferConfig, TLB, ServiceRequirement)
from repro.core.services.sniffer import CSR_SNIFFER_ENABLE


def _shell(**kw):
    services = kw.pop("services", {"mmu": MMUConfig(page_size=64,
                                                    n_pages=64),
                                   "encryption": AESConfig()})
    s = Shell(ShellConfig.make(services=services, **kw))
    s.build()
    return s


# ============================================================== lifecycle ===
def test_build_and_load():
    shell = _shell(n_vfpgas=2)
    assert shell.services.names() == ["encryption", "mmu"]
    stats = shell.load_app(0, make_passthrough_artifact())
    assert shell.vfpgas[0].app.name == "passthrough"
    assert shell.vfpgas[1].app is None            # other slot untouched


def test_app_requirements_fail_safe():
    shell = _shell(services={"encryption": AESConfig()})
    art = make_hll_artifact()                      # requires mmu
    from repro.core.vfpga import LinkError
    with pytest.raises(LinkError):
        shell.load_app(0, art)


def test_shell_reconfig_refuses_to_strand_app():
    shell = _shell()
    shell.load_app(0, make_aes_artifact("ecb"))    # requires encryption
    bad = ShellConfig.make(services={"mmu": MMUConfig()})
    with pytest.raises(RuntimeError, match="strand"):
        shell.reconfigure_shell(bad)
    # original services intact after the refused swap
    assert "encryption" in shell.services.names()


def test_app_hot_swap_preserves_neighbors():
    shell = _shell(n_vfpgas=2)
    shell.load_app(0, make_aes_artifact("ecb"))
    shell.load_app(1, make_passthrough_artifact())
    gen0 = shell.services.get("mmu").generation
    shell.reconfigure_app(1, make_hll_artifact())
    assert shell.vfpgas[0].app.name == "aes_ecb"
    assert shell.vfpgas[1].app.name == "hll"
    assert shell.services.get("mmu").generation == gen0  # services untouched


def test_cold_restart_reloads_apps():
    shell = _shell()
    shell.load_app(0, make_passthrough_artifact())
    r = shell.cold_restart()
    assert r["total_s"] > 0
    assert shell.vfpgas[0].app.name == "passthrough"


def test_hbm_budget_enforced():
    import jax.numpy as jnp
    shell = _shell()
    shell.vfpgas[0].hbm_budget = 64
    art = AppArtifact(name="fat", fn=lambda i, v, x: x,
                      weights={"w": jnp.zeros((1024,), jnp.float32)})
    from repro.core.vfpga import LinkError
    with pytest.raises(LinkError, match="budget"):
        shell.load_app(0, art)


# ============================================================= datapath ====
def test_cthread_transfer_roundtrip():
    shell = _shell()
    shell.load_app(0, make_passthrough_artifact())
    ct = shell.attach_thread(0, pid=1)
    src = ct.getMem((Alloc.HPF, 8192))
    src[:] = np.arange(8192) % 251
    dst = ct.getMem((Alloc.REG, 8192))
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(src), dst=ct.vaddr_of(dst),
                             length=8192))
    assert comp.ok
    assert (src == dst).all()
    assert shell.vfpgas[0].iface.cq_read.writeback_counter >= 1


def test_app_fault_raises_interrupt_not_crash():
    shell = _shell()

    def bad_app(iface, vfpga, x):
        raise ValueError("malformed data")
    shell.load_app(0, AppArtifact(name="bad", fn=bad_app))
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 64))
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(buf), length=64))
    assert not comp.ok
    irq = ct.poll_interrupt(timeout=1.0)
    assert irq is not None                       # IRQ_USER was raised


def test_sniffer_capture_and_csr_control():
    shell = _shell(services={"encryption": AESConfig(),
                             "mmu": MMUConfig(),
                             "sniffer": SnifferConfig()})
    shell.load_app(0, make_passthrough_artifact())
    sniffer = shell.services.get("sniffer")
    sniffer.csr.set_csr(1, CSR_SNIFFER_ENABLE)
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.REG, 16384))
    ct.invoke(Oper.LOCAL_TRANSFER,
              SgEntry(src=ct.vaddr_of(buf), length=16384))
    recs = sniffer.to_records()
    assert len(recs) == 4                        # 16KB / 4KB packets
    assert all(r["len"] == 4096 for r in recs)
    sniffer.csr.set_csr(0, CSR_SNIFFER_ENABLE)   # stop
    n = len(sniffer.to_records())
    ct.invoke(Oper.LOCAL_TRANSFER,
              SgEntry(src=ct.vaddr_of(buf), length=4096))
    assert len(sniffer.to_records()) == n        # capture stopped


# ======================================================== credits/fairness ==
def test_packetize_exact():
    assert packetize(0) == []
    assert packetize(4096) == [4096]
    assert packetize(10000) == [4096, 4096, 1808]
    assert sum(packetize(123456, 1000)) == 123456


@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.integers(1, 200_000), min_size=2, max_size=6))
def test_rr_arbiter_fairness_property(sizes):
    """Property: equal-demand tenants get equal shares (Jain -> 1); the
    link moves every byte exactly once; per-tenant ordering holds."""
    link = Link("l", 1e9)
    arb = RRArbiter(link, packet_bytes=4096)
    total = max(sizes)
    for i in range(len(sizes)):
        arb.submit(f"t{i}", total)               # equal demand
    arb.drain()
    shares = arb.fairness()
    assert abs(jains_index(shares) - 1.0) < 1e-9
    assert link.bytes_moved == total * len(sizes)


def test_credit_backpressure_contained():
    """A stalled consumer exhausts ITS credits; the account stalls the
    requester, not the link."""
    acct = CreditAccount(4)
    assert all(acct.try_acquire() for _ in range(4))
    assert not acct.try_acquire()                # 5th stalls
    assert acct.stalls == 1
    acct.release(2)
    assert acct.try_acquire() and acct.try_acquire()
    assert not acct.try_acquire()


# ================================================================== MMU =====
def test_mmu_paging_and_translation():
    mmu = MMU(MMUConfig(page_size=16, n_pages=8, host_pool_pages=8))
    mmu.alloc_seq(1, 40)                         # 3 pages
    p, off = mmu.translate(1, 39)
    assert off == 39 % 16
    table = mmu.block_table([1], 4)
    assert (table[0, :3] >= 0).all() and table[0, 3] == -1
    mmu.free_seq(1)
    assert mmu.utilization()["pages_used"] == 0


def test_mmu_eviction_and_fault_in():
    mmu = MMU(MMUConfig(page_size=16, n_pages=4, host_pool_pages=8))
    mmu.alloc_seq(1, 48)                         # 3 pages
    mmu.alloc_seq(2, 32)                         # needs 2 -> evicts from 1
    assert mmu.migrations_out >= 1
    # touching the evicted page faults it back in
    p, _ = mmu.translate(1, 47)
    assert p >= 0
    assert mmu.migrations_in >= 1


def test_mmu_pool_exhaustion_raises():
    mmu = MMU(MMUConfig(page_size=16, n_pages=2, host_pool_pages=0))
    mmu.alloc_seq(1, 32)
    with pytest.raises(PageFaultError):
        mmu.alloc_seq(2, 32)


@settings(max_examples=20, deadline=None)
@given(accesses=st.lists(st.integers(0, 1023), min_size=5, max_size=60),
       entries=st.sampled_from([4, 8, 16]),
       assoc=st.sampled_from([1, 2, 4]))
def test_tlb_never_wrong_property(accesses, entries, assoc):
    """Property: the TLB may miss but never returns a stale/wrong page."""
    mmu = MMU(MMUConfig(page_size=16, n_pages=128, tlb_entries=entries,
                        tlb_assoc=assoc))
    mmu.alloc_seq(7, 1024)
    truth = {}
    for pos in accesses:
        p, off = mmu.translate(7, pos)
        vp = pos // 16
        if vp in truth:
            assert truth[vp] == p, "translation changed without remap"
        truth[vp] = p
        assert off == pos % 16


def test_mmu_reconfigure_requires_drain():
    mmu = MMU(MMUConfig(page_size=16, n_pages=8))
    mmu.alloc_seq(1, 16)
    with pytest.raises(RuntimeError, match="drain"):
        mmu.configure(MMUConfig(page_size=1024, n_pages=8))
    mmu.free_seq(1)
    mmu.configure(MMUConfig(page_size=1024, n_pages=8))
    assert mmu.config.page_size == 1024
    assert mmu.generation == 1
