"""Fault-injection harness + self-healing shell.

Seeded deterministic faults (repro.core.faults) injected across every
layer — port dispatch, executor lanes, IO completion, service calls, the
MMU pager, reconfigure, migration — and the recovery machinery that
keeps tenants alive through them: typed failure propagation, bounded
deadline-aware retry, the slot watchdog, KV-intact local recovery, and
quarantine of repeatedly-faulting tenants.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AppArtifact, FaultKind, FaultPlan, FaultSpec,
                        Invocation, MigrationError, Oper, PortState,
                        SgEntry, Shell, ShellConfig, migrate)
from repro.core.faults import (DEFAULT_RETRYABLE, DEFAULT_SITES,
                               InjectedFault, maybe_fire)
from repro.core.port import PortError
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

PAGE = 16
POOL = 128


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _shell(n_vfpgas=2, **mmu_kw):
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL,
                                   **mmu_kw)},
        n_vfpgas=n_vfpgas))
    s.build()
    return s


def _engine(cfg, params, shell, *, tenant="gold", rid_base=0, slot=0):
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                        max_batch=3, max_len=128, shell=shell, slot=slot,
                        tenant=tenant, rid_base=rid_base)


def _echo_shell(tenant="a", slot=0, n_vfpgas=2):
    """Shell with a trivial echo app loaded: the SG-path harness."""
    shell = _shell(n_vfpgas=n_vfpgas)
    shell.register_tenant(tenant, 1.0, slots=(slot,))
    shell.load_app(slot, AppArtifact(name="echo", fn=lambda i, v, x: x))
    return shell, shell.attach(slot)


def _sg(i=0, n=64):
    return Invocation.from_sg(SgEntry(src=np.full(n, i % 251, np.uint8),
                                      length=n,
                                      opcode=Oper.LOCAL_TRANSFER))


# ===================================================== the fault plan ======
def test_fault_plan_deterministic_and_positional():
    """after/count/filters are positional; probabilistic firing draws
    from the plan's OWN seeded RNG — two same-seed plans fed the same
    probe sequence fire at exactly the same hits."""
    plan = FaultPlan([FaultSpec(FaultKind.LANE_CRASH, after=2, count=2)])
    for _ in range(2):                        # hits 1-2: grace
        plan.fire("lane.execute")
    for _ in range(2):                        # hits 3-4: armed
        with pytest.raises(InjectedFault) as ei:
            plan.fire("lane.execute")
        assert ei.value.kind is FaultKind.LANE_CRASH
        assert ei.value.retryable            # DEFAULT_RETRYABLE
    plan.fire("lane.execute")                 # hit 5: spec spent
    assert plan.exhausted()
    assert plan.stats()["specs"][0]["fired"] == 2

    # slot/tenant filters
    scoped = FaultPlan([FaultSpec(FaultKind.IO_ERROR, slot=1,
                                  tenant="gold")])
    scoped.fire("io.complete", slot=0, tenant="gold")     # wrong slot
    scoped.fire("io.complete", slot=1, tenant="bronze")   # wrong tenant
    with pytest.raises(InjectedFault):
        scoped.fire("io.complete", slot=1, tenant="gold")

    # probabilistic determinism: same seed => same firing hits
    def run(seed):
        p = FaultPlan([FaultSpec(FaultKind.DISPATCH, count=100, p=0.3)],
                      seed=seed)
        hits = []
        for i in range(200):
            try:
                p.fire("port.dispatch")
            except InjectedFault:
                hits.append(i)
        return hits
    assert run(7) == run(7)
    assert 20 < len(run(7)) < 100             # p=0.3 actually gates

    # default sites cover every injectable kind; kinds without a default
    # site must be given one explicitly
    for kind, site in DEFAULT_SITES.items():
        assert FaultSpec(kind).site == site
    with pytest.raises(ValueError, match="needs a site"):
        FaultSpec(FaultKind.WEDGE)
    maybe_fire(None, "port.dispatch")         # unarmed runs: no-op


# ========================================== typed failure propagation ======
def test_dispatch_fault_fails_future_typed():
    """A dispatch-path exception can never leave the future unresolved:
    it fails with a structured PortError (kind/slot/tenant/retryable)
    and is accounted in the health ledger."""
    shell, port = _echo_shell(tenant="gold")
    shell.set_fault_plan(FaultPlan.single(FaultKind.DISPATCH))
    fut = port.submit(Invocation.io(256, tenant="gold"))
    with pytest.raises(PortError) as ei:
        fut.result(timeout=10.0)
    err = ei.value
    assert err.kind == "dispatch"
    assert err.slot == 0 and err.tenant == "gold"
    assert err.retryable
    assert isinstance(err.cause, InjectedFault)
    st = port.stats()
    assert st["failed"] == 1 and st["inflight"] == 0
    assert shell.health.status()["fault_counts"]["dispatch"] == 1
    # the port is not poisoned: the next submission completes
    assert port.submit(Invocation.io(256, tenant="gold")).result(
        timeout=10.0).ok
    shell.close()


def test_lane_crash_surfaces_failed_completion_and_retries():
    """An executor-lane body exception becomes Completion(ok=False)
    carrying the typed fault (legacy semantics, default policy); with
    max_retries the SAME invocation re-dispatches and succeeds."""
    shell, port = _echo_shell()
    plan = FaultPlan.single(FaultKind.LANE_CRASH)
    shell.set_fault_plan(plan)
    comp = port.submit(_sg()).result(timeout=10.0)
    assert not comp.ok
    assert isinstance(comp.result, InjectedFault)
    assert comp.result.kind is FaultKind.LANE_CRASH
    assert shell.scheduler.stats()["lane_faults"] == 1
    assert shell.health.status()["fault_counts"]["lane_crash"] == 1

    plan.arm(FaultSpec(FaultKind.LANE_CRASH))         # re-arm once
    inv = _sg(1)
    inv.max_retries = 1
    comp = port.submit(inv).result(timeout=10.0)
    assert comp.ok                                     # retry recovered it
    assert port.stats()["retried"] == 1
    assert inv.retries == 1
    shell.close()


def test_io_error_fails_future_typed_and_retries():
    shell, port = _echo_shell(tenant="gold")
    plan = FaultPlan.single(FaultKind.IO_ERROR)
    shell.set_fault_plan(plan)
    with pytest.raises(PortError) as ei:
        port.submit(Invocation.io(512, tenant="gold")).result(timeout=10.0)
    assert ei.value.kind == "io_error" and ei.value.retryable
    assert shell.health.status()["fault_counts"]["io_error"] == 1

    plan.arm(FaultSpec(FaultKind.IO_ERROR))
    inv = Invocation.io(512, tenant="gold")
    inv.max_retries = 2
    comp = port.submit(inv).result(timeout=10.0)
    assert comp.ok and port.stats()["retried"] == 1
    shell.close()


def test_retry_respects_deadline():
    """Deadline-aware retry: a backoff that cannot finish before the
    invocation's SLO deadline is not attempted — the fault surfaces
    immediately instead of sleeping past the deadline."""
    shell, port = _echo_shell(tenant="gold")
    shell.set_fault_plan(FaultPlan.single(FaultKind.DISPATCH, count=3))
    inv = Invocation.io(64, tenant="gold", deadline_s=0.05)
    inv.max_retries = 3
    inv.retry_backoff_s = 5.0                 # way past the deadline
    t0 = time.perf_counter()
    with pytest.raises(PortError) as ei:
        port.submit(inv).result(timeout=10.0)
    assert time.perf_counter() - t0 < 2.0     # no 5s backoff sleep
    assert ei.value.kind == "dispatch"
    assert inv.retries == 0                   # retry declined, not burned
    shell.close()


def test_service_call_fault_completion_and_retry(served):
    shell = _shell()
    port = shell.attach("mmu")
    plan = FaultPlan.single(FaultKind.SERVICE_CALL)
    shell.set_fault_plan(plan)
    comp = port.call(Invocation.call("utilization"), timeout=10.0)
    assert not comp.ok
    assert isinstance(comp.result, InjectedFault)
    assert comp.result.kind is FaultKind.SERVICE_CALL
    # spec spent: the same call now succeeds
    comp = port.call(Invocation.call("utilization"), timeout=10.0)
    assert comp.ok and comp.result["pages_total"] == POOL

    plan.arm(FaultSpec(FaultKind.SERVICE_CALL))
    inv = Invocation.call("utilization")
    inv.max_retries = 1
    comp = port.submit(inv).result(timeout=10.0)
    assert comp.ok and port.stats()["retried"] == 1
    shell.close()


def test_quiesce_timeout_restores_active_intake():
    """Satellite fix: a quiesce that cannot drain no longer leaves the
    port wedged DRAINING — intake reopens and the timeout is a typed
    health event."""
    shell, port = _echo_shell()
    shell.scheduler.pause()                   # in-flight tail can't drain
    futs = [port.submit(_sg(i)) for i in range(3)]
    assert port.quiesce(timeout=0.2) is False
    assert port.state is PortState.ACTIVE     # intake reopened
    counts = shell.health.status()["fault_counts"]
    assert counts.get("quiesce_timeout") == 1
    assert not shell.health.status()["quarantined"]   # strike-free
    shell.scheduler.resume()
    assert all(f.result(timeout=10.0).ok for f in futs)
    assert port.quiesce(timeout=10.0)         # drains fine when unblocked
    port.resume()
    shell.close()


def test_flush_io_timeout_typed_and_strict(served):
    """Satellite fix: flush_io's False return is now observable — the
    residue is health-recorded and strict=True raises it typed."""
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    shell.scheduler.pause()
    eng._io_futs.append(eng.port.submit(
        Invocation.io(64, tenant="gold")))
    assert eng.flush_io(timeout=0.2) is False
    with pytest.raises(PortError) as ei:
        eng.flush_io(timeout=0.2, strict=True)
    assert ei.value.kind == "io_flush_timeout" and ei.value.retryable
    counts = shell.health.status()["fault_counts"]
    assert counts.get("io_flush_timeout", 0) >= 2
    shell.scheduler.resume()
    assert eng.flush_io(timeout=10.0) is True
    shell.close()


# ================================================= the pager under fault ===
def test_pager_gather_fault_typed_then_preserved(served):
    """An evict-with-copy gather failure surfaces typed; the victim
    sequence is never corrupted, and once the fault clears the same
    eviction preserves the exact bytes."""
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=8, n_pages=8, host_pool_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=80)
    eng.submit(list(range(3, 30)), max_new_tokens=30)
    for _ in range(3):
        eng.step()
    se = mmu._seqs[1]
    pre = {p.vpage: eng._pager_gather(p.ppage)
           for p in se.pages if not p.on_host}
    mmu.faults = FaultPlan.single(FaultKind.PAGER_GATHER)
    with pytest.raises(InjectedFault) as ei:
        mmu.alloc_seq(99, 8 * (len(mmu._free) + 2))   # pressure -> evict
    assert ei.value.kind is FaultKind.PAGER_GATHER
    if 99 in mmu._seqs:                       # partial alloc: roll back
        mmu.free_seq(99)
    # fault cleared: the eviction completes and the bytes are preserved
    mmu.alloc_seq(99, 8 * (len(mmu._free) + 2))
    evicted = [p.vpage for p in se.pages if p.on_host]
    assert evicted
    for v in evicted:
        stored = mmu.host_page_data(1, v)
        np.testing.assert_array_equal(stored["k"], pre[v]["k"])
        np.testing.assert_array_equal(stored["v"], pre[v]["v"])


def test_pager_scatter_fault_leaks_no_device_page(served):
    """A fault-back-in scatter failure returns the freshly allocated
    device page to the pool and keeps the host payload, so the retry
    restores the exact bytes."""
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=8, n_pages=8, host_pool_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=80)
    eng.submit(list(range(3, 30)), max_new_tokens=30)
    for _ in range(3):
        eng.step()
    se = mmu._seqs[1]
    pre = {p.vpage: eng._pager_gather(p.ppage)
           for p in se.pages if not p.on_host}
    mmu.alloc_seq(99, 8 * (len(mmu._free) + 2))       # evict some of seq 1
    evicted = [p.vpage for p in se.pages if p.on_host]
    assert evicted
    mmu.free_seq(99)                                  # room to fault in
    free_before = len(mmu._free)
    mmu.faults = FaultPlan.single(FaultKind.PAGER_SCATTER)
    with pytest.raises(InjectedFault) as ei:
        mmu.translate(1, evicted[0] * 8)
    assert ei.value.kind is FaultKind.PAGER_SCATTER
    assert len(mmu._free) == free_before              # page returned
    assert mmu.host_page_data(1, evicted[0]) is not None  # payload kept
    ppage, _ = mmu.translate(1, evicted[0] * 8)       # retry succeeds
    assert ppage >= 0
    got = eng._pager_gather(ppage)
    np.testing.assert_array_equal(got["k"], pre[evicted[0]]["k"])
    np.testing.assert_array_equal(got["v"], pre[evicted[0]]["v"])


def test_page_fault_storm_token_parity(served):
    """The behavioural fault: a forced eviction storm churns pages
    through the evict-with-copy pager mid-decode — and because the pager
    preserves bytes, the tokens are identical to a storm-free run."""
    cfg, params = served
    shell = _shell(host_pool_pages=256)
    eng = _engine(cfg, params, shell)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    plan = FaultPlan.single(FaultKind.PAGE_FAULT_STORM, count=6)
    shell.set_fault_plan(plan)
    reqs = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
            (list(range(3, 12)), 1.3)]
    for prompt, temp in reqs:
        eng.submit(prompt, max_new_tokens=12, temperature=temp)
        oracle.submit(prompt, max_new_tokens=12, temperature=temp)
    while eng.pending():
        eng.step()
    while oracle.pending():
        oracle.step()
    mmu = shell.services.get("mmu")
    assert mmu.page_faults >= 1                       # storm really churned
    assert plan.stats()["specs"][0]["fired"] >= 1
    got = {r.rid: r.out_tokens for r in eng.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want
    shell.close()


# ============================================= watchdog + local recovery ===
def test_recover_slot_kv_intact_token_parity(served):
    """THE acceptance pin: a slot recovered in place (quiesce, snapshot
    through the migration container, cold-reset, restore) resumes
    decoding token-for-token — greedy AND sampled rows — with zero lost
    or duplicated completions, while a bystander tenant's traffic is
    untouched."""
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    reqs = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
            (list(range(3, 12)), 1.3)]
    for prompt, temp in reqs:
        eng.submit(prompt, max_new_tokens=12, temperature=temp)
        oracle.submit(prompt, max_new_tokens=12, temperature=temp)
    for _ in range(4):                                # mid-decode
        eng.step()
        oracle.step()

    # bystander tenant on slot 1, in flight THROUGH the recovery
    shell.register_tenant("bronze", 1.0, slots=(1,))
    shell.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    bport = shell.attach(1)
    n = 60
    bfuts = []

    def drive():
        for i in range(n):
            bfuts.append(bport.submit(_sg(i)))

    t = threading.Thread(target=drive)
    t.start()
    report = shell.recover_slot(0)
    t.join()

    assert report.slot == 0 and report.tenant == "gold"
    assert report.n_requests == 3 and report.n_pages > 0
    assert report.downtime_s > 0
    while eng.pending():
        eng.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want                                # KV survived intact

    comps = [f.result(timeout=30.0) for f in bfuts]
    assert len(comps) == n and all(c.ok for c in comps)
    shell.drain()
    bstats = shell.scheduler.stats()["tenants"]["bronze"]
    assert bstats["completions"] == n
    assert bstats["intake_stalls"] == 0
    # zero lost/dup on the recovered slot's port
    pstats = shell.attach(0).stats()
    assert pstats["submitted"] == pstats["completed"] + pstats["failed"]
    assert pstats["inflight"] == 0 and pstats["held"] == 0
    assert shell.health.recoveries == 1
    shell.close()


def test_check_health_detects_and_recovers_wedged_slot(served):
    """The watchdog loop end to end: a slot with pending work and a
    stale heartbeat is flagged WEDGED, quarantine-free recovered, and
    finishes its decode token-for-token."""
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    eng.submit(list(range(3, 12)), max_new_tokens=8)
    oracle.submit(list(range(3, 12)), max_new_tokens=8)
    eng.step()                                        # beats once
    oracle.step()
    shell.health.heartbeat_timeout_s = 0.05
    time.sleep(0.12)                                  # ...then goes quiet
    res = shell.check_health(auto_recover=True)
    assert res["pending"][0] is True
    assert 0 in res["wedged"] and 0 in res["recovered"]
    assert shell.health.status()["fault_counts"]["wedge"] == 1
    while eng.pending():
        eng.step()
    while oracle.pending():
        oracle.step()
    assert ([r.out_tokens for r in eng.completed]
            == [r.out_tokens for r in oracle.completed])
    # idle slots are never wedged: a fresh sweep flags nothing
    time.sleep(0.12)
    assert shell.check_health()["wedged"] == []
    shell.close()


def test_watchdog_thread_sweeps_and_stops(served):
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    shell.health.heartbeat_timeout_s = 0.03
    eng.submit(list(range(3, 10)), max_new_tokens=4)
    eng.step()                                        # beat, then silence
    wd = shell.start_watchdog(interval_s=0.02, auto_recover=False)
    assert shell.start_watchdog() is wd               # idempotent
    deadline = time.perf_counter() + 5.0
    while (not shell.health.status()["fault_counts"].get("wedge")
           and time.perf_counter() < deadline):
        time.sleep(0.02)
    assert wd.sweeps >= 1
    assert shell.health.status()["fault_counts"].get("wedge", 0) >= 1
    shell.stop_watchdog()
    assert not wd.thread.is_alive()
    while eng.pending():
        eng.step()
    shell.close()                                     # double-stop is fine


# ======================================================== quarantine =======
def test_repeated_faults_quarantine_tenant_typed_rejections(served):
    """Graceful degradation: strikes inside the window quarantine the
    tenant — port AND engine submissions reject fast with a typed
    PortError — while a bystander keeps flowing; unquarantine lifts."""
    cfg, params = served
    shell = _shell()
    eng = _engine(cfg, params, shell)
    shell.register_tenant("bronze", 1.0, slots=(1,))
    shell.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    bport = shell.attach(1)
    shell.health.quarantine_after = 2
    shell.set_fault_plan(FaultPlan.single(
        FaultKind.DISPATCH, count=2, tenant="gold"))
    port = shell.attach(0)
    for _ in range(2):                                # two strikes...
        with pytest.raises(PortError):
            port.submit(Invocation.io(64, tenant="gold")).result(
                timeout=10.0)
    assert shell.health.is_quarantined("gold")        # ...you're out
    with pytest.raises(PortError) as ei:
        port.submit(Invocation.io(64, tenant="gold"))
    assert ei.value.kind == "quarantined" and not ei.value.retryable
    with pytest.raises(PortError) as ei:
        eng.submit(list(range(3, 10)), max_new_tokens=4)
    assert ei.value.kind == "quarantined"
    assert shell.health.rejections == 2
    assert "gold" in shell.status()["health"]["quarantined"]
    # the bystander never noticed
    assert bport.submit(_sg()).result(timeout=10.0).ok
    # operator verb lifts it; the strike window restarts clean
    assert shell.health.unquarantine("gold")
    assert port.submit(Invocation.io(64, tenant="gold")).result(
        timeout=10.0).ok
    eng.submit(list(range(3, 10)), max_new_tokens=2)
    while eng.pending():
        eng.step()
    shell.close()


# =============================================== migration / reconfig ======
def test_mid_migration_abort_leaves_source_serving_parity(served):
    """An injected restore-stage failure aborts the migration; the
    source tenant keeps serving and produces the fault-free tokens."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    _engine(cfg, params, dst)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                           max_batch=3, max_len=128)
    reqs = [(list(range(3, 8)), 0.0), (list(range(3, 12)), 1.3)]
    for prompt, temp in reqs:
        eng_src.submit(prompt, max_new_tokens=10, temperature=temp)
        oracle.submit(prompt, max_new_tokens=10, temperature=temp)
    for _ in range(3):
        eng_src.step()
        oracle.step()
    src.set_fault_plan(FaultPlan.single(FaultKind.MIGRATION_FAIL))
    with pytest.raises(MigrationError):
        migrate(src, dst, "gold")
    assert src.health.status()["fault_counts"]["migration_fail"] == 1
    assert src.attach(0).state is PortState.ACTIVE
    while eng_src.pending():
        eng_src.step()
    while oracle.pending():
        oracle.step()
    assert ({r.rid: r.out_tokens for r in eng_src.completed}
            == {r.rid: r.out_tokens for r in oracle.completed})
    # the plan is spent: the SAME migration now goes through
    report = migrate(src, dst, "gold")
    assert report.n_requests == 0                     # all done already
    src.close()
    dst.close()


def test_reconfig_abort_typed_and_slot_survives():
    shell, port = _echo_shell()
    shell.set_fault_plan(FaultPlan.single(FaultKind.RECONFIG_ABORT))
    with pytest.raises(InjectedFault) as ei:
        shell.reconfigure(0, AppArtifact(name="echo2",
                                         fn=lambda i, v, x: x))
    assert ei.value.kind is FaultKind.RECONFIG_ABORT
    counts = shell.health.status()["fault_counts"]
    assert counts["reconfig_abort"] == 1
    assert port.state is PortState.ACTIVE             # intake reopened
    assert port.submit(_sg()).result(timeout=10.0).ok
    # spec spent: the swap now succeeds
    stats = shell.reconfigure(0, AppArtifact(name="echo2",
                                             fn=lambda i, v, x: x))
    assert stats["total_s"] > 0
    shell.close()


# ================================================== trainer unification ====
def test_trainer_failure_is_shared_taxonomy():
    """Satellite: SimulatedFailure IS an InjectedFault of kind
    NODE_FAILURE — one taxonomy across serving and training — and
    TrainConfig.fault_plan probes the standard train.step site."""
    from repro.train.loop import SimulatedFailure, TrainConfig
    e = SimulatedFailure("boom")
    assert isinstance(e, InjectedFault)
    assert e.kind is FaultKind.NODE_FAILURE
    assert e.site == "train.step" and not e.retryable
    assert FaultKind.NODE_FAILURE not in DEFAULT_RETRYABLE
    plan = FaultPlan.single(FaultKind.NODE_FAILURE, after=1)
    assert TrainConfig(fault_plan=plan).fault_plan is plan
    maybe_fire(plan, "train.step")                    # grace hit
    with pytest.raises(InjectedFault) as ei:
        maybe_fire(plan, "train.step")
    assert ei.value.kind is FaultKind.NODE_FAILURE


# ============================================================ fuzz =========
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fuzz_storm_every_future_resolves(seed):
    """Seed sweep under a probabilistic multi-site storm: whatever
    fires, every future resolves exactly once (Completion or typed
    error) and the port accounting balances — nothing hangs, nothing is
    double-counted."""
    shell, port = _echo_shell(tenant="a")
    shell.health.quarantine_after = 10 ** 6           # keep intake open
    shell.set_fault_plan(FaultPlan([
        FaultSpec(FaultKind.DISPATCH, count=100, p=0.25),
        FaultSpec(FaultKind.LANE_CRASH, count=100, p=0.25),
        FaultSpec(FaultKind.IO_ERROR, count=100, p=0.25),
        FaultSpec(FaultKind.SERVICE_CALL, count=100, p=0.25),
    ], seed=seed))
    mmu_port = shell.attach("mmu")
    futs = []
    for i in range(20):
        inv = _sg(i)
        inv.max_retries = i % 2
        futs.append((port, port.submit(inv)))
        io = Invocation.io(64, tenant="a")
        io.max_retries = i % 2
        futs.append((port, port.submit(io)))
        futs.append((mmu_port, mmu_port.submit(
            Invocation.call("utilization"))))
    ok = failed = 0
    for _p, fut in futs:
        try:
            comp = fut.result(timeout=30.0)
            ok += 1
            assert comp is not None
        except PortError:
            failed += 1
    assert ok + failed == len(futs)                   # all resolved
    for p in (port, mmu_port):
        st = p.stats()
        assert st["submitted"] == st["completed"] + st["failed"]
        assert st["inflight"] == 0 and st["held"] == 0
    shell.drain()
    shell.close()
