"""Property-based fuzz of the migration/recovery surface + fleet tests.

Satellite (a) of the fleet-controller PR: a state-machine fuzz drives
random interleavings of admit / shared-prefix admit / decode / CoW
write / eviction pressure / pre-copy migration / injected migration
faults across a two-member fleet, checking after EVERY op that the MMU
bookkeeping invariants hold on both members:

- the device pool partitions exactly into free + refcounted pages;
- the page-table census never exceeds the refcounts (host analogues
  included);
- every dirty flag references a live page identity.

and at the end of every run that the system converged clean:

- exactly-once completion — every submitted request finished exactly
  once, on whichever member ended up owning the tenant;
- zero page leaks on both members (failed/faulted migrations must
  release their pre-copy staging).

Runs under real Hypothesis when installed, else the deterministic
``_hypothesis_fallback`` shim (same decorators, seeded draws).  A
4-seed parametrized storm repeats the machine with a denser, hostile
op mix (migrate/fault heavy) outside the shim for CI determinism.

Deterministic FleetController unit tests (placement scoring, wedged-
slot healing, hotspot reroute, operator verbs) share the module model.
"""
from __future__ import annotations

import random
import time

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_config
from repro.core import Shell, ShellConfig
from repro.core.faults import FaultKind, FaultPlan, FaultSpec, InjectedFault
from repro.core.migrate import MigrationError, migrate_precopy
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU, PageFaultError
from repro.fleet import FleetController
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.gateway import ServingGateway

PAGE = 8
POOL = 48          # small device pool: eviction pressure is reachable
HOST = 96
FAULT_SITES = ["migrate.precopy", "migrate.snapshot",
               "migrate.restore", "migrate.replay"]


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _shell(name, pool=POOL):
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=pool,
                                   host_pool_pages=HOST)},
        n_vfpgas=2), name=name)
    s.build()
    s.health.quarantine_after = 10**6    # fault storms must not close intake
    return s


def _engine(cfg, params, shell, *, rid_base=0):
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=4, max_len=256, shell=shell, slot=0,
                         tenant="gold", rid_base=rid_base)


def _check_mmu(mmu: MMU) -> None:
    """MMU bookkeeping invariants; cheap enough to run after every op."""
    free = list(mmu._free)
    assert len(free) == len(set(free)), "duplicate pages in free list"
    assert not (set(free) & set(mmu._ref)), "page both free and mapped"
    assert len(free) + len(mmu._ref) == mmu.config.n_pages, \
        "device pool does not partition into free + mapped"
    hfree = list(mmu._host_free)
    assert len(hfree) == len(set(hfree))
    assert not (set(hfree) & set(mmu._host_ref))
    # page-table census vs refcounts: a page may carry extra refs
    # (pre-copy staging holds pages with no mapping) but never fewer
    # than its mappings
    dcount, hcount = {}, {}
    for se in mmu._seqs.values():
        for p in se.pages:
            if p.on_host:
                if p.host_slot >= 0:
                    hcount[p.host_slot] = hcount.get(p.host_slot, 0) + 1
            else:
                dcount[p.ppage] = dcount.get(p.ppage, 0) + 1
    for pp, n in dcount.items():
        assert mmu._ref.get(pp, 0) >= n, f"device page {pp} under-refed"
    for hs, n in hcount.items():
        assert mmu._host_ref.get(hs, 0) >= n, f"host slot {hs} under-refed"
    for kind, ident in mmu._dirty:
        live = mmu._ref if kind == "d" else mmu._host_ref
        assert ident in live, f"dirty flag ({kind},{ident}) on dead page"


class _Machine:
    """Two-member fleet as a fuzzable state machine."""

    def __init__(self, served, rng: random.Random):
        cfg, params = served
        self.rng = rng
        self.shells = [_shell("fz-a"), _shell("fz-b")]
        self.engines = [_engine(cfg, params, self.shells[0], rid_base=0),
                        _engine(cfg, params, self.shells[1], rid_base=1000)]
        self.cur = 0                     # member currently owning "gold"
        self.submitted = []
        self.last_prompt = None
        self.naux = 0

    # -- ops ----------------------------------------------------------------
    def _inflight(self) -> int:
        done = sum(len(e.completed) for e in self.engines)
        return len(self.submitted) - done

    def op_admit(self):
        if self._inflight() >= 5:        # bound the live footprint
            return
        n = self.rng.randrange(6, 30)
        start = self.rng.randrange(0, 40)
        prompt = list(range(3 + start, 3 + start + n))
        self.last_prompt = prompt
        rid = self.engines[self.cur].submit(
            prompt, max_new_tokens=self.rng.randrange(4, 12))
        self.submitted.append(rid)

    def op_admit_shared(self):
        """Re-submit a half-shared prefix: exercises CoW page sharing."""
        if self.last_prompt is None or self._inflight() >= 5:
            return self.op_admit()
        head = self.last_prompt[:max(len(self.last_prompt) // 2, 1)]
        tail = [self.rng.randrange(3, 60)
                for _ in range(self.rng.randrange(2, 10))]
        rid = self.engines[self.cur].submit(
            head + tail, max_new_tokens=self.rng.randrange(4, 12))
        self.submitted.append(rid)

    def op_decode(self):
        for _ in range(self.rng.randrange(1, 3)):
            self.engines[self.cur].step()

    def op_cow_write(self):
        """A for_write translate on a live sequence splits any sharing."""
        mmu = self.shells[self.cur].services.get("mmu")
        sids = [sid for sid, se in mmu._seqs.items() if se.pages]
        if not sids:
            return
        mmu.translate(self.rng.choice(sids), 0, for_write=True)

    def op_evict_pressure(self):
        """Transient aux allocation forces tail eviction to the host."""
        mmu = self.shells[self.cur].services.get("mmu")
        sid = 10**6 + self.naux
        self.naux += 1
        try:
            mmu.alloc_seq(sid, PAGE * self.rng.randrange(2, 6), slot=1)
        except PageFaultError:
            pass                         # both pools full: legal outcome
        if sid in mmu._seqs:
            mmu.free_seq(sid)

    def op_migrate(self):
        src, dst = self.shells[self.cur], self.shells[1 - self.cur]
        migrate_precopy(src, dst, "gold", max_rounds=2, drain_timeout=10.0)
        self.cur = 1 - self.cur

    def op_fault_migrate(self):
        """Inject a migration fault at a random site and assert the
        documented containment: the tenant stays exactly-once owned and
        the would-be source keeps serving."""
        site = self.rng.choice(FAULT_SITES)
        src, dst = self.shells[self.cur], self.shells[1 - self.cur]
        src.set_fault_plan(FaultPlan([FaultSpec(
            FaultKind.MIGRATION_FAIL, site=site,
            after=self.rng.randrange(0, 2))]))
        try:
            migrate_precopy(src, dst, "gold", max_rounds=2,
                            drain_timeout=10.0)
        except (MigrationError, InjectedFault):
            if site == "migrate.replay":
                # replay fires after evacuation: the tenant HAS moved
                self.cur = 1 - self.cur
        else:
            self.cur = 1 - self.cur      # fault never fired (converged)
        finally:
            src.set_fault_plan(None)
        self.engines[self.cur].step()    # the owner must still serve

    OPS = {0: "op_admit", 1: "op_admit", 2: "op_admit_shared",
           3: "op_decode", 4: "op_decode", 5: "op_cow_write",
           6: "op_evict_pressure", 7: "op_migrate", 8: "op_migrate",
           9: "op_fault_migrate"}

    def apply(self, code: int) -> None:
        getattr(self, self.OPS[code])()
        for s in self.shells:
            _check_mmu(s.services.get("mmu"))

    # -- teardown with final invariants -------------------------------------
    def finish(self) -> None:
        for _ in range(600):
            if not any(e.pending() for e in self.engines):
                break
            for e in self.engines:
                if e.pending():
                    e.step()
        else:
            raise AssertionError("drain did not converge")
        done = sorted(r.rid for e in self.engines for r in e.completed)
        assert done == sorted(self.submitted), \
            f"lost/duplicated requests: {done} vs {self.submitted}"
        for s in self.shells:
            mmu = s.services.get("mmu")
            _check_mmu(mmu)
            u = mmu.utilization()
            assert u["pages_used"] == 0 and u["sequences"] == 0, u
            assert not mmu._ref and not mmu._host_ref, \
                "page leak (orphan refcounts survive the drain)"
            s.close()


@settings(max_examples=4)
@given(seed=st.integers(min_value=0, max_value=2**16),
       ops=st.lists(st.integers(min_value=0, max_value=9),
                    min_size=1, max_size=12))
def test_migration_surface_fuzz(served, seed, ops):
    m = _Machine(served, random.Random(seed * 2654435761 + 17))
    try:
        for code in ops:
            m.apply(code)
    finally:
        m.finish()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_migration_fault_storm(served, seed):
    """Hostile mix: every other op is a migration or an injected fault."""
    rng = random.Random(seed)
    m = _Machine(served, rng)
    # admissions land early so the moves actually carry KV state
    codes = [0, 3, 2, 3] + [rng.choice([3, 5, 6, 7, 9, 9])
                            for _ in range(12)]
    try:
        for code in codes:
            m.apply(code)
    finally:
        m.finish()


# --------------------------------------------------------------------------
# FleetController: deterministic unit tests
# --------------------------------------------------------------------------

def test_placement_scoring_exclusion_and_fault_penalty(served):
    a, b = _shell("pl-a", pool=32), _shell("pl-b", pool=64)
    fc = FleetController()
    fc.add_shell(a)
    fc.add_shell(b)
    with pytest.raises(ValueError, match="duplicate"):
        fc.add_shell(_shell("pl-a"))

    # occupancy dominates: load pages onto a, b wins the placement
    a.services.get("mmu").alloc_seq(1, PAGE * 3)
    assert fc.place(pages_needed=2) is b
    assert fc.place(pages_needed=2, exclude=("pl-b",)) is a
    # a member that cannot fit is excluded outright, not down-scored
    assert fc.placement_score(a, pages_needed=10**6) is None
    assert fc.place(pages_needed=10**6) is None
    # recent faults subtract a fixed penalty each: a clean member beats
    # a flapping one at BETTER occupancy
    a.services.get("mmu").free_seq(1)
    for _ in range(4):
        b.health.record_fault(FaultKind.MIGRATION_FAIL, tenant=None,
                              strike=False)
    assert fc.place(pages_needed=2) is a
    assert fc.decisions[-1].action == "place"
    a.close()
    b.close()


def test_sweep_heals_wedged_slot_token_exact(served):
    cfg, params = served
    shell = _shell("heal-a", pool=64)
    shell.health.heartbeat_timeout_s = 0.05
    eng = _engine(cfg, params, shell)
    oracle = ServingEngine(cfg, params,
                           MMU(MMUConfig(page_size=PAGE, n_pages=64,
                                         host_pool_pages=HOST)),
                           max_batch=4, max_len=256)
    prompt = list(range(3, 23))
    rid = eng.submit(prompt, max_new_tokens=8)
    orid = oracle.submit(prompt, max_new_tokens=8)
    eng.step()                           # beats, then goes silent...
    oracle.step()
    time.sleep(0.12)                     # ...past the heartbeat timeout

    fc = FleetController()
    fc.add_shell(shell)
    decisions = fc.sweep()
    healed = [d for d in decisions if d.action == "recover" and d.ok]
    assert healed and healed[0].src == "heal-a"
    assert fc.status()["recoveries"] == 1

    while eng.pending():
        eng.step()
    while oracle.pending():
        oracle.step()
    out = {r.rid: r.out_tokens for r in eng.completed}
    oout = {r.rid: r.out_tokens for r in oracle.completed}
    assert out[rid] == oout[orid], "recovery was not token-exact"
    shell.close()


def test_sweep_hotspot_migrates_and_reroutes_gateway(served):
    cfg, params = served
    hot, cold = _shell("hs-hot", pool=16), _shell("hs-cold", pool=64)
    eng_hot = _engine(cfg, params, hot, rid_base=0)
    eng_cold = _engine(cfg, params, cold, rid_base=1000)
    gw_hot = ServingGateway(eng_hot, admission="fifo")
    gw_cold = ServingGateway(eng_cold, admission="fifo")
    fc = FleetController(precopy=True, hot_util=0.25, cold_util=0.60)
    fc.add_shell(hot)
    fc.add_shell(cold)
    fc.attach_gateway(hot, gw_hot)
    fc.attach_gateway(cold, gw_cold)

    stream = gw_hot.submit(list(range(3, 43)), max_new_tokens=8)
    for _ in range(2):
        gw_hot.step()                    # 5/16 pages used: above hot_util

    moved = [d for d in fc.sweep() if d.action == "migrate" and d.ok]
    assert moved and moved[0].src == "hs-hot" and moved[0].dst == "hs-cold"
    assert moved[0].report.precopy_rounds >= 1
    assert fc.status()["moves"] == 1

    gw_cold.drain()
    assert stream.done and stream.error is None
    assert not gw_hot.streams and not gw_hot.queue
    assert [id(s) for s in gw_cold.completed] == [id(stream)]
    hot.close()
    cold.close()


def test_migrate_tenant_operator_verb_and_unknown(served):
    cfg, params = served
    a, b = _shell("op-a"), _shell("op-b")
    eng_a = _engine(cfg, params, a, rid_base=0)
    _engine(cfg, params, b, rid_base=1000)
    fc = FleetController()
    fc.add_shell(a)
    fc.add_shell(b)
    rid = eng_a.submit(list(range(3, 20)), max_new_tokens=6)
    eng_a.step()

    d = fc.migrate_tenant("gold")
    assert d.ok and d.src == "op-a" and d.dst == "op-b"
    dst_eng = b.engines[d.report.dst_slot]
    while dst_eng.pending():
        dst_eng.step()
    assert [r.rid for r in dst_eng.completed] == [rid]

    with pytest.raises(KeyError, match="ghost"):
        fc.migrate_tenant("ghost")
    a.close()
    b.close()
