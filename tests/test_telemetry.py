"""HLO cost walker + roofline: trip-count multipliers, collective parsing,
fusion byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import normalize_cost_analysis
from repro.telemetry import hlo_cost, roofline


def _compile(fn, *avals):
    return jax.jit(fn).lower(*avals).compile()


def test_dot_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 512), jnp.float32))
    t = hlo_cost.analyze_text(c.as_text())
    expect = 2 * 128 * 256 * 512
    assert abs(t.flops - expect) / expect < 0.02


def test_while_trip_count_multiplier():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((9, 64, 64), jnp.float32))
    t = hlo_cost.analyze_text(c.as_text())
    one = 2 * 64 * 64 * 64
    assert abs(t.flops - 9 * one) / (9 * one) < 0.1
    xla = normalize_cost_analysis(c.cost_analysis())["flops"]  # body x1
    assert t.flops > 5 * xla                  # the bug we fixed


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((4, 32, 32), jnp.float32))
    t = hlo_cost.analyze_text(c.as_text())
    expect = 4 * 3 * 2 * 32 ** 3
    assert abs(t.flops - expect) / expect < 0.15


def test_dus_inplace_bytes_not_full_buffer():
    """Writing one row into a big buffer must cost ~row bytes, not buffer
    bytes — otherwise paged-KV decode traffic is overstated 1000x."""
    big = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)
    row = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(buf, r):
        return jax.lax.dynamic_update_slice(buf, r, (17, 0))
    c = jax.jit(f, donate_argnums=(0,)).lower(big, row).compile()
    t = hlo_cost.analyze_text(c.as_text())
    assert t.bytes < 4096 * 1024 * 4 * 0.5    # far below full-buffer copy


def test_collective_parse_shapes_and_groups():
    txt = """
HloModule m
ENTRY %main (p: f32[1024,8]) -> f32[1024,8] {
  %p = f32[1024,8]{1,0} parameter(0)
  ROOT %ar = f32[1024,8]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    t = hlo_cost.analyze_text(txt)
    assert t.coll_counts == {"all-reduce": 1.0}
    nbytes = 1024 * 8 * 4
    assert t.coll_bytes_naive["all-reduce"] == nbytes
    # ring wire bytes for group of 4: 2*(4-1)/4
    assert abs(t.coll_bytes_wire["all-reduce"] - 1.5 * nbytes) < 1


def test_tuple_type_with_index_comments_parses():
    txt = """
HloModule m
ENTRY %main (p: s32[]) -> s32[] {
  %p = s32[] parameter(0)
  %w = (s32[], f32[8,8]{1,0}, /*index=2*/f32[30,16]{1,0}) while(%t), body=%b, condition=%c, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = s32[] get-tuple-element(%w), index=0
}
%b (a: (s32[], f32[8,8], f32[30,16])) -> (s32[], f32[8,8], f32[30,16]) {
  %a = (s32[], f32[8,8]{1,0}, f32[30,16]{1,0}) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%a), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t2 = (s32[], f32[8,8]{1,0}, f32[30,16]{1,0}) tuple(%p, %d, %y)
}
"""
    t = hlo_cost.analyze_text(txt)
    assert t.flops >= 5 * 2 * 8 * 8 * 8       # trip-multiplied dot


def test_roofline_terms_and_dominance():
    r = roofline.Roofline(
        flops_per_device=197e12, bytes_per_device=819e9 * 2,
        coll=roofline.CollectiveStats(), chips=256,
        model_flops=197e12 * 256 * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.roofline_fraction - 0.25) < 1e-9


def test_wire_factor_model():
    assert hlo_cost._wire_factor("all-reduce", 2) == 1.0
    assert hlo_cost._wire_factor("all-gather", 4) == 0.75
    assert hlo_cost._wire_factor("reduce-scatter", 4) == 3.0
    assert hlo_cost._wire_factor("collective-permute", 2) == 1.0
    assert hlo_cost._wire_factor("all-reduce", 1) == 0.0
