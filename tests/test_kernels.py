"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # offline env: deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd.ref import ssd_chunked, ssd_sequential
from repro.kernels.ssd.ssd import ssd_chunked_pallas


# ============================================================ flash attn ===
FA_CASES = [
    # b, h, kh, sq, sk, d, causal, window, dtype
    (2, 4, 2, 256, 256, 64, True, 0, jnp.float32),
    (1, 8, 8, 128, 384, 128, True, 0, jnp.float32),
    (2, 4, 1, 200, 200, 64, True, 0, jnp.float32),    # pad path
    (1, 4, 2, 256, 256, 64, True, 128, jnp.float32),  # SWA
    (1, 2, 2, 128, 256, 64, False, 0, jnp.float32),   # cross-attn
    (1, 4, 2, 128, 128, 64, True, 0, jnp.bfloat16),   # low precision
]


@pytest.mark.parametrize("case", FA_CASES,
                         ids=[f"fa{i}" for i in range(len(FA_CASES))])
def test_flash_attention_matches_ref(case):
    b, h, kh, sq, sk, d, causal, window, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, sk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, sk, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(17, 192), sk=st.integers(17, 192),
       blk=st.sampled_from([32, 64, 128]))
def test_flash_attention_block_size_invariance(sq, sk, blk):
    """Property: output is independent of block tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, sk, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, sk, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=blk, block_k=blk, interpret=True)
    b = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


# ============================================================ paged attn ===
PA_CASES = [
    (2, 8, 2, 64, 128, 4, 16),
    (3, 4, 4, 128, 64, 6, 32),
    (1, 16, 8, 64, 256, 3, 8),
]


def _tables(b, page, maxp, npages, lens):
    tables = np.full((b, maxp), -1, np.int32)
    for i in range(b):
        need = -(-int(lens[i]) // page)
        tables[i, :need] = np.random.RandomState(i).permutation(
            npages)[:need]
    return tables


@pytest.mark.parametrize("ppb", [1, 2, None],
                         ids=["ppb1", "ppb2", "ppbauto"])
@pytest.mark.parametrize("case", PA_CASES,
                         ids=[f"pa{i}" for i in range(len(PA_CASES))])
def test_paged_attention_matches_ref(case, ppb):
    b, h, kh, d, page, maxp, npages = case
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (npages, page, kh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (npages, page, kh, d), jnp.float32)
    lens = np.minimum(np.arange(1, b + 1) * (page + 7), page * maxp)
    tables = _tables(b, page, maxp, npages, lens)
    out = paged_attention(q, kp, vp, jnp.asarray(tables),
                          jnp.asarray(lens, jnp.int32),
                          pages_per_block=ppb, interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(tables),
                              jnp.asarray(lens, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_attention_ragged_occupancy_page_groups():
    """pages_per_block > 1 over ragged occupancy: an empty slot (all -1),
    a length exactly on a page-group boundary, and a host-swapped page
    (-1 mid-table) all match the oracle for every group width."""
    b, h, kh, d, page, maxp, npages = 3, 4, 2, 64, 16, 7, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (npages, page, kh, d), jnp.float32)
    vp = jax.random.normal(ks[2], (npages, page, kh, d), jnp.float32)
    lens = jnp.asarray([0, 32, 100], jnp.int32)
    tables = np.full((b, maxp), -1, np.int32)
    tables[1, :2] = [5, 9]
    tables[2, :7] = [1, 2, 3, -1, 4, 6, 7]
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(tables), lens)
    for ppb in (1, 2, 3, 4, None):        # 3: maxp not a group multiple
        out = paged_attention(q, kp, vp, jnp.asarray(tables), lens,
                              pages_per_block=ppb, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, err_msg=f"ppb={ppb}")


def test_paged_matches_dense_attention():
    """Paged attention over scattered pages == dense attention over the
    same logical sequence (the MMU indirection is value-invisible)."""
    b, h, kh, d, page, maxp, npages = 2, 4, 2, 64, 32, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    lens = np.array([100, 57], np.int32)
    tables = _tables(b, page, maxp, npages, lens)
    kd = jax.random.normal(ks[1], (b, maxp * page, kh, d), jnp.float32)
    vd = jax.random.normal(ks[2], (b, maxp * page, kh, d), jnp.float32)
    # scatter the dense kv into pages per the tables
    kp = jnp.zeros((npages, page, kh, d), jnp.float32)
    vp = jnp.zeros((npages, page, kh, d), jnp.float32)
    for i in range(b):
        for vp_i in range(maxp):
            pp = tables[i, vp_i]
            if pp < 0:
                continue
            sl = slice(vp_i * page, (vp_i + 1) * page)
            kp = kp.at[pp].set(kd[i, sl])
            vp = vp.at[pp].set(vd[i, sl])
        # dense ref per row (pages are per-row exclusive in this test)
        q = jax.random.normal(ks[0], (1, h, d), jnp.float32)
        out = paged_attention(q, kp, vp, jnp.asarray(tables[i:i+1]),
                              jnp.asarray(lens[i:i+1]), interpret=True)
        qr = q.reshape(1, h, 1, d).transpose(0, 1, 2, 3)
        ref = attention_ref(q[:, :, None], kd[i:i+1].transpose(0, 2, 1, 3),
                            vd[i:i+1].transpose(0, 2, 1, 3),
                            causal=False)[:, :, 0]
        # mask to lens[i]: rebuild ref with masked attention
        ref = paged_attention_ref(q, kp, vp, jnp.asarray(tables[i:i+1]),
                                  jnp.asarray(lens[i:i+1]))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


# =================================================================== ssd ===
SSD_CASES = [
    (2, 128, 4, 64, 1, 32, 32),
    (1, 200, 8, 64, 2, 64, 64),     # padded seq
    (2, 256, 4, 32, 4, 16, 128),
]


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=[f"ssd{i}" for i in range(len(SSD_CASES))])
def test_ssd_kernel_matches_sequential(case):
    b, s, h, p, g, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y_ref, st_ref = ssd_sequential(x, dt, A, Bm, C)
    y_chk, st_chk = ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
    y_pal, st_pal = ssd_chunked_pallas(x, dt, A, Bm, C, chunk=chunk,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(st_pal), np.asarray(st_ref),
                               atol=5e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(8, 96), chunk=st.sampled_from([8, 16, 32]))
def test_ssd_chunk_invariance(s, chunk):
    """Property: the chunked algorithm is exact for ANY chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (1, s, 2, 16), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, 2)))
    A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.5)
    Bm = jax.random.normal(ks[3], (1, s, 1, 8)) * 0.3
    C = jax.random.normal(ks[4], (1, s, 1, 8)) * 0.3
    y1, st1 = ssd_sequential(x, dt, A, Bm, C)
    y2, st2 = ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=5e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st1), atol=5e-4)


def test_ssd_decode_continuation():
    """Chunked prefill state + single-token decode == longer sequential."""
    from repro.models.ssm import ssd_decode
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    b, s, h, p, g, n = 1, 33, 2, 16, 1, 8
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    y_all, _ = ssd_sequential(x, dt, A, Bm, C)
    _, st = ssd_chunked(x[:, :-1], dt[:, :-1], A, Bm[:, :-1], C[:, :-1],
                        chunk=16)
    y_last, _ = ssd_decode(x[:, -1], dt[:, -1], A, Bm[:, -1], C[:, -1], st)
    np.testing.assert_allclose(np.asarray(y_last),
                               np.asarray(y_all[:, -1]), atol=5e-4)


# ============================================================ flash bwd ====
BWD_CASES = [
    (1, 4, 2, 128, 128, 64, True, 0),
    (2, 2, 1, 96, 160, 64, True, 0),     # padded + MHA-as-GQA
    (1, 4, 4, 128, 128, 64, False, 0),   # non-causal
    (1, 2, 2, 128, 128, 64, True, 64),   # sliding window
]


@pytest.mark.parametrize("case", BWD_CASES,
                         ids=[f"fabwd{i}" for i in range(len(BWD_CASES))])
def test_flash_attention_bwd_matches_grad_of_ref(case):
    from repro.kernels.flash_attention.flash_attention_bwd import (
        flash_attention_bwd)
    b, h, kh, sq, sk, d, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kh, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kh, sk, d), jnp.float32)
    do = jax.random.normal(ks[3], (b, h, sq, d), jnp.float32)

    def f(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal,
                                     window=window) * do)
    dq_r, dk_r, dv_r = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             interpret=True, return_lse=True)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, do, lse, causal=causal,
                                     window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(dq_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_r), atol=5e-4)


def test_mha_fused_custom_vjp_end_to_end():
    from repro.kernels.flash_attention.ops import mha_fused
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)

    def loss_fused(q, k, v):
        return jnp.sum(mha_fused(q, k, v, True, 0, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_fused, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
