"""Unit tests for the bench trend tooling: scripts/diff_bench.py metric
fallbacks, near-zero-baseline unit-scale deltas, REMOVED-row reporting,
the --strict missing-artifact gate, and the bench_history store +
fallback-baseline path."""
import importlib.util
import json
import os
import sys

import pytest

_SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_SCRIPTS, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault(name, mod)
    spec.loader.exec_module(mod)
    return mod


bench_history = _load("bench_history")
diff_bench = _load("diff_bench")


def _row(config, tps=0.0, mean=0.0, extra=None, bench="bench_x"):
    r = {"bench": bench, "config": config, "tokens_per_s": tps,
         "mean_s": mean}
    if extra:
        r["extra"] = extra
    return r


# ============================================ _metric fallback chain =======
def test_metric_prefers_tokens_per_s():
    name, val, sense = diff_bench._metric(
        _row("a", tps=100.0, mean=0.5, extra={"ratio_err_pct": 2.0}))
    assert (name, val, sense) == ("tokens_per_s", 100.0, +1)


def test_metric_falls_back_to_mean_s():
    name, val, sense = diff_bench._metric(_row("a", mean=0.5))
    assert (name, val, sense) == ("mean_s", 0.5, -1)


def test_metric_falls_back_to_extras_in_order():
    name, _, sense = diff_bench._metric(
        _row("a", extra={"jain_weighted": 0.99, "ratio_err_pct": 1.0}))
    assert (name, sense) == ("ratio_err_pct", -1)
    name, _, sense = diff_bench._metric(
        _row("a", extra={"jain_weighted": 0.99}))
    assert (name, sense) == ("jain_weighted", +1)
    name, _, sense = diff_bench._metric(
        _row("a", extra={"p99_speedup_x": 12.0}))
    assert (name, sense) == ("p99_speedup_x", +1)


def test_metric_none_when_no_signal():
    assert diff_bench._metric(_row("a", extra={"batch": 4})) is None


# ============================================= diff_file behaviors =========
def _diff(tmp_path, monkeypatch, capsys, cur, base, warn_pct=20.0):
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps(cur))
    monkeypatch.setattr(diff_bench, "_load_baseline", lambda path: base)
    regs, missing = diff_bench.diff_file(str(p), warn_pct,
                                         history=str(tmp_path / "no.jsonl"))
    return regs, missing, capsys.readouterr().out


def test_near_zero_baseline_compares_on_unit_scale(tmp_path, monkeypatch,
                                                   capsys):
    """A 0 -> 0.5 move on ratio_err_pct must read as +0.5 points (denom
    1.0), not an infinite relative regression."""
    cur = [_row("w3:1", extra={"ratio_err_pct": 0.5})]
    base = [_row("w3:1", extra={"ratio_err_pct": 0.0})]
    regs, missing, out = _diff(tmp_path, monkeypatch, capsys, cur, base,
                               warn_pct=60.0)
    assert not missing
    assert regs == 0                       # 0.5 pts = +50.0% < 60% floor
    assert "(+50.0%)" in out
    # and beyond the floor it IS flagged
    regs, _, out = _diff(tmp_path, monkeypatch, capsys, cur, base,
                         warn_pct=10.0)
    assert regs == 1 and "REGRESSION" in out


def test_regression_flagging_respects_sense(tmp_path, monkeypatch, capsys):
    # tokens_per_s: lower is worse
    regs, _, out = _diff(tmp_path, monkeypatch, capsys,
                         [_row("c", tps=50.0)], [_row("c", tps=100.0)])
    assert regs == 1 and "REGRESSION" in out
    # mean_s: higher is worse
    regs, _, _ = _diff(tmp_path, monkeypatch, capsys,
                       [_row("c", mean=2.0)], [_row("c", mean=1.0)])
    assert regs == 1
    # improvements never flag
    regs, _, _ = _diff(tmp_path, monkeypatch, capsys,
                       [_row("c", tps=200.0)], [_row("c", tps=100.0)])
    assert regs == 0


def test_removed_rows_are_reported(tmp_path, monkeypatch, capsys):
    cur = [_row("kept", tps=10.0)]
    base = [_row("kept", tps=10.0), _row("gone", tps=5.0)]
    _, _, out = _diff(tmp_path, monkeypatch, capsys, cur, base)
    assert "gone" in out and "REMOVED" in out


def test_new_rows_are_reported_not_flagged(tmp_path, monkeypatch, capsys):
    regs, _, out = _diff(tmp_path, monkeypatch, capsys,
                         [_row("fresh", tps=10.0)], [])
    assert regs == 0 and "NEW" in out


# ======================================== --strict missing artifact ========
def test_strict_fails_on_missing_artifact(tmp_path, monkeypatch):
    missing = str(tmp_path / "BENCH_never_written.json")
    assert diff_bench.main([missing]) == 0             # informational: ok
    assert diff_bench.main([missing, "--strict"]) == 1  # gated: fail


def test_strict_fails_on_flagged_regression(tmp_path, monkeypatch):
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps([_row("c", tps=50.0)]))
    monkeypatch.setattr(diff_bench, "_load_baseline",
                        lambda path: [_row("c", tps=100.0)])
    assert diff_bench.main([str(p)]) == 0
    assert diff_bench.main([str(p), "--strict"]) == 1
    assert diff_bench.main([str(p), "--strict", "--warn-pct", "60"]) == 0


# ================================================ history store ============
def test_history_append_dedupes_and_trend(tmp_path, capsys):
    hist = str(tmp_path / "H.jsonl")
    art = tmp_path / "BENCH_x.json"
    art.write_text(json.dumps([_row("c1", tps=100.0),
                               _row("c2", mean=0.2)]))
    bench_history.append([str(art)], commit="aaa", path=hist)
    art.write_text(json.dumps([_row("c1", tps=110.0)]))
    bench_history.append([str(art)], commit="aaa", path=hist)  # replaces
    art.write_text(json.dumps([_row("c1", tps=120.0)]))
    bench_history.append([str(art)], commit="bbb", path=hist)
    rows = bench_history.load_history(hist)
    aaa_c1 = [r for r in rows if r["commit"] == "aaa"
              and r["config"] == "c1"]
    assert len(aaa_c1) == 1 and aaa_c1[0]["tokens_per_s"] == 110.0
    capsys.readouterr()
    bench_history.trend(suite="bench_x", config="c1", path=hist)
    out = capsys.readouterr().out
    assert "110" in out and "120" in out


def test_history_latest_rows_excludes_current_commit(tmp_path):
    hist = str(tmp_path / "H.jsonl")
    art = tmp_path / "BENCH_x.json"
    art.write_text(json.dumps([_row("c1", tps=100.0)]))
    bench_history.append([str(art)], commit="old", path=hist)
    art.write_text(json.dumps([_row("c1", tps=200.0)]))
    bench_history.append([str(art)], commit="cur", path=hist)
    rows = bench_history.latest_rows("bench_x", exclude_commit="cur",
                                     path=hist)
    assert rows is not None and rows[0]["tokens_per_s"] == 100.0
    assert bench_history.latest_rows("bench_x", exclude_commit=None,
                                     path=hist)[0]["tokens_per_s"] == 200.0
    assert bench_history.latest_rows("bench_zzz", path=hist) is None


def test_diff_falls_back_to_history_baseline(tmp_path, monkeypatch,
                                             capsys):
    """No committed baseline at HEAD -> the history store supplies one
    (the 'more than one PR back' path)."""
    hist = str(tmp_path / "H.jsonl")
    art = tmp_path / "BENCH_x.json"
    art.write_text(json.dumps([_row("c1", tps=100.0)]))
    bench_history.append([str(art)], commit="prev", path=hist)
    art.write_text(json.dumps([_row("c1", tps=50.0)]))   # regressed 2x
    monkeypatch.setattr(diff_bench, "_load_baseline", lambda path: None)
    monkeypatch.setattr(diff_bench.bench_history, "git_head",
                        lambda default="unknown": "cur")
    regs, missing = diff_bench.diff_file(str(art), 20.0, history=hist)
    out = capsys.readouterr().out
    assert not missing and regs == 1
    assert "history" in out and "REGRESSION" in out


def test_history_rebench_of_old_commit_does_not_become_baseline(tmp_path):
    """Re-running CI on an old checkout rewrites its rows at the file
    end, but the newest-first-seen commit must stay the fallback
    baseline (first-seen timestamps are preserved across re-appends)."""
    hist = str(tmp_path / "H.jsonl")
    art = tmp_path / "BENCH_x.json"
    art.write_text(json.dumps([_row("c1", tps=100.0)]))
    bench_history.append([str(art)], commit="old", path=hist)
    art.write_text(json.dumps([_row("c1", tps=200.0)]))
    bench_history.append([str(art)], commit="new", path=hist)
    art.write_text(json.dumps([_row("c1", tps=105.0)]))
    bench_history.append([str(art)], commit="old", path=hist)  # re-bench
    rows = bench_history.latest_rows("bench_x", path=hist)
    assert rows[0]["tokens_per_s"] == 200.0     # still commit "new"


def test_history_survives_corrupt_lines(tmp_path):
    hist = tmp_path / "H.jsonl"
    hist.write_text('{"commit": "a", "suite": "s", "config": "c", '
                    '"tokens_per_s": 1.0, "mean_s": 0.0}\n'
                    "{truncated garbage\n")
    rows = bench_history.load_history(str(hist))
    assert len(rows) == 1 and rows[0]["commit"] == "a"
