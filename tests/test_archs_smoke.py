"""Per-architecture smoke tests (assignment requirement f).

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step plus a prefill->decode step on CPU, asserting output
shapes and finiteness.  The FULL configs are exercised compile-only by the
dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_SHAPES, ARCHS, get_config, shape_applicable
from repro.models import transformer as T

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.n_encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_loss(arch_id, key):
    cfg = get_config(arch_id).reduced()
    params = T.init_params(key, cfg, dtype=jnp.float32)
    batch = _batch(cfg, key)
    loss, metrics = T.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_updates_params(arch_id, key):
    from repro.optim import adamw
    cfg = get_config(arch_id).reduced()
    params = T.init_params(key, cfg, dtype=jnp.float32)
    opt = adamw.init(params)
    batch = _batch(cfg, key)

    def loss(p):
        return T.loss_fn(p, cfg, batch)[0]

    grads = jax.grad(loss)(params)
    new_params, new_opt, m = adamw.update(grads, opt, params,
                                          adamw.AdamWConfig())
    # at least one leaf moved, no NaNs anywhere
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved, f"{arch_id}: optimizer step was a no-op"
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert int(new_opt["step"]) == 1
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode(arch_id, key):
    cfg = get_config(arch_id).reduced()
    params = T.init_params(key, cfg, dtype=jnp.float32)
    b, s = 2, 12
    batch = _batch(cfg, key, b, s)
    logits, cache = T.prefill(params, cfg, batch["tokens"], max_len=s + 8,
                              encoder_frames=batch.get("frames"),
                              cache_dtype=jnp.float32)
    assert logits.shape == (b, cfg.padded_vocab)
    pos = jnp.full((b,), s, jnp.int32)
    nt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = T.decode_step(params, cfg, cache, nt, pos)
    assert logits2.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch_id}: decode NaN"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_id, key):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = get_config(arch_id).reduced()
    params = T.init_params(key, cfg, dtype=jnp.float32)
    b, s = 1, 10
    batch = _batch(cfg, key, b, s)
    hidden, _, _, _ = T.forward(params, cfg, batch["tokens"],
                                encoder_frames=batch.get("frames"))
    full_logits = T.lm_logits(params, cfg, hidden)     # (B,S,V)

    prefix = 6
    logits_p, cache = T.prefill(params, cfg, batch["tokens"][:, :prefix],
                                max_len=s + 2,
                                encoder_frames=batch.get("frames"),
                                cache_dtype=jnp.float32)
    # prefill last-token logits == forward at position prefix-1
    assert jnp.allclose(logits_p, full_logits[:, prefix - 1],
                        atol=2e-3), f"{arch_id}: prefill mismatch"
    # teacher-forced decode of the rest
    for t in range(prefix, s):
        pos = jnp.full((b,), t, jnp.int32)
        logits_d, cache = T.decode_step(params, cfg, cache,
                                        batch["tokens"][:, t:t + 1], pos)
        assert jnp.allclose(logits_d, full_logits[:, t], atol=2e-3), \
            f"{arch_id}: decode@{t} mismatch " \
            f"{float(jnp.max(jnp.abs(logits_d - full_logits[:, t])))}"


def test_param_count_sanity():
    """Analytic n_params matches actual initialized leaves (full config is
    analytic-only; reduced configs are materialized and compared)."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id).reduced()
        params = T.init_params(jax.random.PRNGKey(0), cfg,
                               dtype=jnp.float32)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expect = cfg.n_params()
        # zamba2's shared block is counted once per pattern in the analytic
        # formula but stored once: allow family-level slack
        tol = 0.30 if cfg.family == "hybrid" else 0.02
        assert abs(actual - expect) / expect < tol, \
            f"{arch_id}: analytic {expect} vs actual {actual}"


def test_assignment_cells_accounted():
    """40 cells: each is either applicable or documented-skipped."""
    cells = [(c.arch_id, s.name, ok)
             for c, s, ok, _ in __import__("repro.configs",
                                           fromlist=["all_cells"]).all_cells()]
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok in cells if not ok]
    # exactly the 7 pure full-attention archs skip long_500k
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
