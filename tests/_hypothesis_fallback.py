"""Deterministic stand-in for ``hypothesis`` in offline environments.

The real package is uninstallable here, so property tests import through::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

The shim replays a fixed, seeded set of examples per test: the boundary
example first (every strategy's minimum), then pseudo-random draws from a
``random.Random`` seeded per test name — deterministic across runs, no
shrinking, no database.  ``@settings(max_examples=N)`` caps the example
count exactly like the real library; ``deadline`` is accepted and ignored.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List


class _Strategy:
    """A draw recipe: ``sample(rng)`` for random draws + a boundary value."""

    def __init__(self, sample: Callable[[random.Random], Any],
                 boundary: Callable[[], Any]):
        self._sample = sample
        self._boundary = boundary

    def example(self, rng: random.Random) -> Any:
        return self._sample(rng)

    def boundary(self) -> Any:
        return self._boundary()


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     lambda: min_value)


def _floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     lambda: min_value)


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), lambda: False)


def _sampled_from(elements) -> _Strategy:
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                     lambda: seq[0])


def _lists(elements: _Strategy, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def sample(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]

    def boundary() -> List[Any]:
        return [elements.boundary() for _ in range(max(min_size, 1))]

    return _Strategy(sample, boundary)


class _StrategiesNamespace:
    """Mimics ``hypothesis.strategies`` for the subset this repo uses."""
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)
    booleans = staticmethod(_booleans)
    sampled_from = staticmethod(_sampled_from)
    lists = staticmethod(_lists)


strategies = _StrategiesNamespace()

_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline=None, **_kw):
    """Decorator factory: records the example budget on the test wrapper.

    Applied above ``@given`` (the only order this repo uses), so it
    annotates the wrapper ``given`` produced.
    """
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test once on boundary values, then on seeded random draws."""
    if arg_strategies:
        raise TypeError("fallback @given supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"repro:{fn.__name__}")
            for i in range(max(n, 1)):
                if i == 0:
                    drawn = {k: s.boundary()
                             for k, s in kw_strategies.items()}
                else:
                    drawn = {k: s.example(rng)
                             for k, s in kw_strategies.items()}
                try:
                    fn(*args, **dict(kwargs, **drawn))
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on fallback example "
                        f"#{i}: {drawn!r}") from e
        wrapper.hypothesis_fallback = True
        # pytest reads the signature to resolve fixtures: hide the
        # strategy-supplied parameters (and the original signature that
        # functools.wraps exposed via __wrapped__).
        del wrapper.__wrapped__
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco
