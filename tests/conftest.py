"""Shared fixtures.  NOTE: never set XLA_FLAGS device-count here — smoke
tests and benches must see exactly 1 CPU device (the 512-device init lives
only in repro.launch.dryrun)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
