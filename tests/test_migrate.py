"""Quiesce-and-migrate: live tenant migration across shells with real KV
copy, plus the evict-with-copy pager inside one shell."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AppArtifact, Invocation, MigrationError, Oper,
                        PortState, SgEntry, Shell, ShellConfig, migrate)
from repro.core.bitstream import BitstreamError
from repro.core.migrate import decode_snapshot, encode_snapshot
from repro.core.port import PortError
from repro.core.services import MMUConfig
from repro.core.services.mmu import MMU
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.serve.paged_model import flat_page_indices, gather_kv_pages

PAGE = 16
POOL = 128


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _shell(n_vfpgas=2):
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
        n_vfpgas=n_vfpgas))
    s.build()
    return s


def _engine(cfg, params, shell, *, tenant="gold", rid_base=0, slot=0):
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=3, max_len=128, shell=shell, slot=slot,
                         tenant=tenant, rid_base=rid_base)


def _live_pages(engine):
    """{(rid, vpage): {"k": bytes, "v": bytes}} for device-resident pages."""
    out = {}
    mmu = engine.mmu
    for sid, se in mmu._seqs.items():
        for pte in se.pages:
            if pte.on_host:
                continue
            flat = flat_page_indices([pte.ppage], engine.cfg.n_layers,
                                     mmu.config.n_pages)
            kv = gather_kv_pages(engine.pools, flat)
            out[(sid, pte.vpage)] = {k: np.asarray(v)
                                     for k, v in kv.items()}
    return out


# ================================================== the migration story ====
def test_mid_decode_migrate_token_for_token_parity(served):
    """Acceptance pin: a live tenant migrated mid-decode produces exactly
    the tokens an unmigrated oracle produces — greedy AND sampled rows
    (the PRNG stream moves with the tenant)."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    eng_dst = _engine(cfg, params, dst)
    oracle = ServingEngine(cfg, params, MMU(MMUConfig(page_size=PAGE,
                                                      n_pages=POOL)),
                           max_batch=3, max_len=128)
    reqs = [(list(range(3, 8)), 0.0), (list(range(3, 20)), 0.0),
            (list(range(3, 12)), 1.3)]
    for prompt, temp in reqs:
        eng_src.submit(prompt, max_new_tokens=12, temperature=temp)
        oracle.submit(prompt, max_new_tokens=12, temperature=temp)
    for _ in range(4):                       # mid-decode
        eng_src.step()
        oracle.step()
    report = migrate(src, dst, "gold")
    assert report.n_requests == 3
    assert report.downtime_s > 0
    while eng_dst.pending():
        eng_dst.step()
    while oracle.pending():
        oracle.step()
    got = {r.rid: r.out_tokens for r in eng_dst.completed}
    want = {r.rid: r.out_tokens for r in oracle.completed}
    assert got == want
    # the source tenant's pages are gone; the source engine is reusable
    assert src.services.get("mmu").utilization()["pages_used"] == 0
    assert eng_src.active == 0
    src.close()
    dst.close()


def test_migrate_kv_bytes_identical_post_restore(served):
    """Acceptance pin: every live KV page lands on the destination
    byte-identical, at its sequence's rebuilt mapping."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    eng_dst = _engine(cfg, params, dst)
    for n in (5, 30, 17):
        eng_src.submit(list(range(3, 3 + n)), max_new_tokens=20)
    for _ in range(6):
        eng_src.step()
    before = _live_pages(eng_src)
    assert before                             # tenant has live KV
    # shared prefix pages (the 30- and 17-token prompts open with the
    # same first page) ship ONCE in the v2 wire format
    n_phys = len({pte.ppage
                  for se in eng_src.mmu._seqs.values()
                  for pte in se.pages if not pte.on_host})
    report = migrate(src, dst, 0)
    after = _live_pages(eng_dst)
    assert set(after) == set(before)
    for key in before:
        np.testing.assert_array_equal(before[key]["k"], after[key]["k"])
        np.testing.assert_array_equal(before[key]["v"], after[key]["v"])
    assert report.n_pages == n_phys <= len(before)
    assert report.payload_bytes > 0
    src.close()
    dst.close()


def test_migrate_replays_held_invocations_zero_lost_dup(served):
    """Invocations held while the source quiesces replay on the
    DESTINATION port: every future resolves exactly once, executed by
    the destination shell."""
    cfg, params = served
    src, dst = _shell(), _shell()
    _engine(cfg, params, src)
    _engine(cfg, params, dst)
    src_port, dst_port = src.attach(0), dst.attach(0)
    assert src_port.quiesce(timeout=10.0)     # idempotent under migrate()
    futs = [src_port.submit(Invocation.io(256, tenant="gold"))
            for _ in range(5)]
    assert src_port.held() == 5
    assert not futs[0].done()
    report = migrate(src, dst, "gold")
    assert report.replayed == 5
    for f in futs:
        comp = f.result(timeout=30.0)
        assert comp.ok
    # exactly-once: source held is empty, destination billed the replay
    assert src_port.held() == 0
    assert src_port.state is PortState.ACTIVE
    assert dst_port.stats()["replayed"] == 5
    dst.drain()
    assert dst.scheduler.stats()["tenants"]["gold"]["completions"] >= 5
    src.close()
    dst.close()


def test_bystander_tenants_on_both_shells_unaffected(served):
    """Bronze tenants drive slot-1 traffic on BOTH shells throughout the
    migration: everything completes, zero intake stalls."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    _engine(cfg, params, dst)
    src.register_tenant("bronze_src", 1.0, slots=(1,))
    dst.register_tenant("bronze_dst", 1.0, slots=(1,))
    src.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    dst.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    p_src, p_dst = src.attach(1), dst.attach(1)
    eng_src.submit(list(range(3, 20)), max_new_tokens=24)
    for _ in range(3):
        eng_src.step()

    n = 80
    futs = {"src": [], "dst": []}

    def drive(port, key):
        for i in range(n):
            futs[key].append(port.submit(Invocation.from_sg(SgEntry(
                src=np.full(64, i % 251, np.uint8), length=64,
                opcode=Oper.LOCAL_TRANSFER))))

    threads = [threading.Thread(target=drive, args=(p_src, "src")),
               threading.Thread(target=drive, args=(p_dst, "dst"))]
    for t in threads:
        t.start()
    time.sleep(0.002)                        # bystanders in flight
    migrate(src, dst, "gold")
    for t in threads:
        t.join()
    for key in futs:
        comps = [f.result(timeout=30.0) for f in futs[key]]
        assert len(comps) == n and all(c.ok for c in comps)
    src.drain()
    dst.drain()
    for shell, tname in ((src, "bronze_src"), (dst, "bronze_dst")):
        stats = shell.scheduler.stats()["tenants"][tname]
        assert stats["completions"] == n
        assert stats["intake_stalls"] == 0
    src.close()
    dst.close()


def test_migrate_moves_queue_and_avoids_rid_collisions(served):
    """Queued (not yet admitted) requests ride the snapshot and complete
    on the destination; post-migration submissions on the destination
    never collide with adopted rids."""
    cfg, params = served
    src, dst = _shell(), _shell()
    eng_src = _engine(cfg, params, src)
    eng_dst = _engine(cfg, params, dst)
    for n in (5, 7, 9, 11, 6):               # 5 > max_batch=3: 2 queue
        eng_src.submit(list(range(3, 3 + n)), max_new_tokens=4)
    eng_src.step()                           # admit 3, leave 2 queued
    assert len(eng_src.queue) == 2
    report = migrate(src, dst, 0)
    assert report.n_queued == 2
    new_rid = eng_dst.submit(list(range(3, 9)), max_new_tokens=4)
    adopted = ([r.rid for r in eng_dst.slots if r is not None]
               + [r.rid for r in eng_dst.queue])
    assert new_rid not in adopted[:-1]
    while eng_dst.pending():
        eng_dst.step()
    assert len(eng_dst.completed) == 6       # 5 migrated + 1 new
    assert len({r.rid for r in eng_dst.completed}) == 6
    src.close()
    dst.close()


def test_migrate_capacity_refusal_leaves_source_serving(served):
    """An incoming tenant must FIT: restore never steals a resident
    tenant's pages, and the refused source keeps serving."""
    cfg, params = served
    src = _shell()
    dst = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=2)},
        n_vfpgas=2))
    dst.build()
    eng_src = _engine(cfg, params, src)
    ServingEngine(cfg, params, dst.services.get("mmu"), max_batch=3,
                  max_len=128, shell=dst, slot=0, tenant="gold")
    eng_src.submit(list(range(3, 60)), max_new_tokens=8)   # 4 pages
    eng_src.step()
    with pytest.raises(MigrationError, match="free pages"):
        migrate(src, dst, "gold")
    assert src.attach(0).state is PortState.ACTIVE
    while eng_src.pending():
        eng_src.step()
    assert len(eng_src.completed) == 1
    src.close()
    dst.close()


def test_migrate_geometry_mismatch_leaves_source_serving(served):
    cfg, params = served
    src = _shell()
    dst = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE * 2, n_pages=POOL)},
        n_vfpgas=2))
    dst.build()
    eng_src = _engine(cfg, params, src)
    _engine(cfg, params, dst)
    eng_src.submit(list(range(3, 12)), max_new_tokens=8)
    eng_src.step()
    with pytest.raises(MigrationError, match="geometry mismatch"):
        migrate(src, dst, "gold")
    # source untouched and still serving
    assert src.attach(0).state is PortState.ACTIVE
    while eng_src.pending():
        eng_src.step()
    assert len(eng_src.completed) == 1
    src.close()
    dst.close()


# ===================================================== snapshot format =====
def test_snapshot_version_and_corruption_rejected(served):
    cfg, params = served
    src = _shell()
    eng = _engine(cfg, params, src)
    eng.submit(list(range(3, 12)), max_new_tokens=6)
    eng.step()
    src.attach(0).quiesce(timeout=10.0)
    from repro.core.migrate import snapshot_tenant
    header, arrays = snapshot_tenant(src, 0)
    blob = encode_snapshot(header, arrays)
    # round-trip is fine
    h2, a2 = decode_snapshot(blob)
    assert h2["geometry"] == eng.geometry()
    # version-mismatched state container
    tampered = blob.replace(b'"state_version": 2', b'"state_version": 9', 1)
    with pytest.raises(BitstreamError, match="state version"):
        decode_snapshot(tampered)
    # wrong kind refuses before any state is touched
    wrong = blob.replace(b'"kind": "migration"', b'"kind": "app"', 1)
    with pytest.raises(BitstreamError):
        decode_snapshot(wrong)
    # bit-rot in the npz payload region
    import zipfile
    with pytest.raises((BitstreamError, zipfile.BadZipFile)):
        decode_snapshot(blob[: len(blob) // 2])
    # a pickle blob is refused outright
    import pickle
    with pytest.raises(BitstreamError, match="bad magic"):
        decode_snapshot(pickle.dumps({"kind": "migration"}))
    src.close()


# ==================================================== evict-with-copy ======
def test_evict_with_copy_restores_exact_kv_bytes(served):
    """Real KV migration on evict: the pager copies page payloads to the
    host store before the device page is recycled, and fault-back-in
    restores the exact bytes into the fresh page."""
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=8, n_pages=8, host_pool_pages=64))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=80)
    eng.submit(list(range(3, 30)), max_new_tokens=30)
    for _ in range(3):
        eng.step()
    se = mmu._seqs[1]
    pre = {p.vpage: eng._pager_gather(p.ppage)
           for p in se.pages if not p.on_host}
    mmu.alloc_seq(99, 8 * (len(mmu._free) + 2))   # pressure -> eviction
    evicted = [p.vpage for p in se.pages if p.on_host]
    assert evicted
    for v in evicted:
        stored = mmu.host_page_data(1, v)
        assert stored is not None
        np.testing.assert_array_equal(stored["k"], pre[v]["k"])
        np.testing.assert_array_equal(stored["v"], pre[v]["v"])
    assert mmu.migrations_out >= len(evicted)
    mmu.free_seq(99)                              # room to fault back in
    for v in evicted:
        ppage, _ = mmu.translate(1, v * 8)
        flat = flat_page_indices([ppage], cfg.n_layers, mmu.config.n_pages)
        back = {k: np.asarray(x)
                for k, x in gather_kv_pages(eng.pools, flat).items()}
        np.testing.assert_array_equal(back["k"], pre[v]["k"])
        np.testing.assert_array_equal(back["v"], pre[v]["v"])
        assert mmu.host_page_data(1, v) is None   # store drained
    assert mmu.migrations_in >= len(evicted)


def test_evicted_pages_ride_migration(served):
    """A tenant with host-evicted pages migrates whole: preserved
    payloads land device-resident on the destination, byte-exact."""
    cfg, params = served
    src = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=8, n_pages=8,
                                   host_pool_pages=64)}, n_vfpgas=1))
    src.build()
    dst = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=8, n_pages=32,
                                   host_pool_pages=64)}, n_vfpgas=1))
    dst.build()
    eng_src = ServingEngine(cfg, params, src.services.get("mmu"),
                            max_batch=2, max_len=80, shell=src, slot=0,
                            tenant="gold")
    eng_dst = ServingEngine(cfg, params, dst.services.get("mmu"),
                            max_batch=2, max_len=80, shell=dst, slot=0,
                            tenant="gold")
    eng_src.submit(list(range(3, 30)), max_new_tokens=30)
    for _ in range(3):
        eng_src.step()
    mmu = src.services.get("mmu")
    se = mmu._seqs[1]
    pre = {p.vpage: eng_src._pager_gather(p.ppage)
           for p in se.pages if not p.on_host}
    mmu.alloc_seq(99, 8 * (len(mmu._free) + 1))   # evict one page of seq 1
    evicted = [p.vpage for p in se.pages if p.on_host]
    assert evicted
    migrate(src, dst, "gold")
    dse = dst.services.get("mmu")._seqs[1]
    assert all(not p.on_host for p in dse.pages)  # fully device-resident
    for p in dse.pages:
        if p.vpage not in pre:
            continue
        flat = flat_page_indices([p.ppage], cfg.n_layers,
                                 dst.services.get("mmu").config.n_pages)
        got = {k: np.asarray(x)
               for k, x in gather_kv_pages(eng_dst.pools, flat).items()}
        np.testing.assert_array_equal(got["k"], pre[p.vpage]["k"])
        np.testing.assert_array_equal(got["v"], pre[p.vpage]["v"])
    src.close()
    dst.close()


# ============================================================ plumbing =====
def test_second_engine_on_shared_mmu_refused(served):
    """One paged-pool owner per MMU, enforced at construction: a second
    engine would gather/scatter evicted pages through the wrong pools."""
    cfg, params = served
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=POOL))
    eng = ServingEngine(cfg, params, mmu, max_batch=2, max_len=64)
    with pytest.raises(RuntimeError, match="pager"):
        ServingEngine(cfg, params, mmu, max_batch=2, max_len=64,
                      rid_base=1000)
    mmu.unregister_pager(eng)                 # owner may hand off
    ServingEngine(cfg, params, mmu, max_batch=2, max_len=64,
                  rid_base=1000)


def test_restore_held_replays_at_source_exactly_once(served):
    """The failed-replay fallback: invocations handed back via
    restore_held() rejoin the source's held FIFO and resolve exactly
    once on resume()."""
    cfg, params = served
    shell = _shell()
    _engine(cfg, params, shell)
    port = shell.attach(0)
    assert port.quiesce(timeout=10.0)
    futs = [port.submit(Invocation.io(128, tenant="gold"))
            for _ in range(4)]
    held = port.take_held()
    assert port.held() == 0
    port.restore_held(held)                   # the migration-abort path
    assert port.held() == 4
    replayed = port.resume()
    assert replayed == 4
    comps = [f.result(timeout=30.0) for f in futs]
    assert all(c.ok for c in comps)
    assert port.stats()["submitted"] == port.stats()["completed"] == 4
    shell.close()


def test_take_held_requires_quiesce(served):
    cfg, params = served
    shell = _shell()
    _engine(cfg, params, shell)
    port = shell.attach(0)
    with pytest.raises(PortError, match="quiesce"):
        port.take_held()
    shell.close()


def test_drain_tenant_is_tenant_scoped():
    shell = _shell()
    assert shell.scheduler.drain_tenant("nobody") is True
    shell.register_tenant("a", 1.0, slots=(0,))
    shell.load_app(0, AppArtifact(name="echo", fn=lambda i, v, x: x))
    port = shell.attach(0)
    futs = [port.submit(Invocation.from_sg(SgEntry(
        src=np.zeros(64, np.uint8), length=64,
        opcode=Oper.LOCAL_TRANSFER))) for _ in range(20)]
    assert shell.scheduler.drain_tenant("a", timeout=30.0)
    assert shell.scheduler.tenant_pending("a") == 0
    assert all(f.done() for f in futs)
    shell.close()
