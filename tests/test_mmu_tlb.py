"""Unit coverage for the MMU's set-associative TLB.

The TLB was previously exercised only through one end-to-end property
test; CoW remaps make stale-TLB bugs live (a shared page remapped by a
copy-on-write fault MUST NOT keep serving the old translation), so the
class gets direct coverage: insert/lookup, LRU eviction within a set,
``invalidate(seq_id)`` scoping, and the hit-rate accounting.
"""
import pytest

from repro.core.services.mmu import MMU, MMUConfig, TLB


# ------------------------------------------------------- basic mapping ----
def test_lookup_miss_then_insert_then_hit():
    tlb = TLB(entries=16, assoc=4)
    assert tlb.lookup(1, 0) is None
    tlb.insert(1, 0, 7)
    assert tlb.lookup(1, 0) == 7
    assert (tlb.hits, tlb.misses) == (1, 1)


def test_insert_same_key_updates_in_place():
    tlb = TLB(entries=16, assoc=4)
    tlb.insert(1, 0, 7)
    tlb.insert(1, 0, 9)                    # remap (e.g. CoW moved the page)
    assert tlb.lookup(1, 0) == 9
    # update, not duplicate: one entry total across all sets
    assert sum(len(s) for s in tlb._sets) == 1


def test_distinct_keys_do_not_alias():
    tlb = TLB(entries=64, assoc=4)
    for sid in range(4):
        for vp in range(4):
            tlb.insert(sid, vp, sid * 100 + vp)
    for sid in range(4):
        for vp in range(4):
            assert tlb.lookup(sid, vp) == sid * 100 + vp


# --------------------------------------------------------- assoc / LRU ----
def test_lru_eviction_within_a_set():
    # entries == assoc -> a single set: insertion order is eviction order
    tlb = TLB(entries=4, assoc=4)
    for vp in range(4):
        tlb.insert(1, vp, vp)
    assert tlb.lookup(1, 0) == 0           # touch vp0: vp1 is now LRU
    tlb.insert(1, 99, 99)                  # overflows the set
    assert tlb.lookup(1, 1) is None        # LRU victim
    assert tlb.lookup(1, 0) == 0           # recently-used survivor
    assert tlb.lookup(1, 99) == 99


def test_assoc_clamped_to_entries():
    tlb = TLB(entries=2, assoc=8)
    assert tlb.assoc == 2
    assert tlb.n_sets == 1
    tlb = TLB(entries=8, assoc=0)          # degenerate assoc -> direct-mapped
    assert tlb.assoc == 1
    assert tlb.n_sets == 8


def test_capacity_never_exceeded():
    tlb = TLB(entries=8, assoc=2)
    for vp in range(64):
        tlb.insert(3, vp, vp)
    assert sum(len(s) for s in tlb._sets) <= 8
    for s in tlb._sets:
        assert len(s) <= tlb.assoc


# ----------------------------------------------------------- invalidate ----
def test_invalidate_scopes_to_one_sequence():
    tlb = TLB(entries=32, assoc=4)
    for vp in range(4):
        tlb.insert(1, vp, vp)
        tlb.insert(2, vp, 100 + vp)
    n = tlb.invalidate(1)
    assert n == 4
    for vp in range(4):
        assert tlb.lookup(1, vp) is None   # seq 1 fully dropped
        assert tlb.lookup(2, vp) == 100 + vp   # seq 2 untouched


def test_invalidate_missing_seq_is_noop():
    tlb = TLB(entries=16, assoc=4)
    tlb.insert(1, 0, 5)
    assert tlb.invalidate(42) == 0
    assert tlb.lookup(1, 0) == 5


# -------------------------------------------------------------- hit rate ----
def test_hit_rate_accounting():
    tlb = TLB(entries=16, assoc=4)
    assert tlb.hit_rate == 1.0             # no traffic yet
    tlb.lookup(1, 0)                       # miss
    tlb.insert(1, 0, 3)
    tlb.lookup(1, 0)                       # hit
    tlb.lookup(1, 0)                       # hit
    assert tlb.hits == 2 and tlb.misses == 1
    assert tlb.hit_rate == pytest.approx(2 / 3)


# ------------------------------------------- integration: CoW remap path ----
def test_cow_remap_invalidates_stale_translation():
    """A copy-on-write fault remaps the faulting sequence's page; the TLB
    must serve the NEW physical page immediately afterwards."""
    mmu = MMU(MMUConfig(page_size=4, n_pages=16, host_pool_pages=16))
    store = {}
    mmu.register_pager(lambda pp: store.get(pp),
                       lambda pp, d: store.__setitem__(pp, d), owner="t")
    prompt = list(range(8))
    mmu.alloc_seq(1, 8, prompt_tokens=prompt)
    assert mmu.alloc_seq(2, 8, prompt_tokens=prompt) == 8
    shared = mmu.translate(2, 0)[0]        # warms the TLB for (2, vpage 0)
    new_pp = mmu.translate(2, 0, for_write=True)[0]
    assert new_pp != shared
    # post-CoW reads translate to the private copy, not the stale entry
    assert mmu.translate(2, 0)[0] == new_pp
    assert mmu.translate(1, 0)[0] == shared
