"""Fig 10 reproduction: multi-threaded AES-CBC pipeline filling.

(a) single-cThread throughput vs message size (saturates — the chain
    dependency leaves the 10-stage pipeline mostly idle);
(b) throughput vs number of cThreads at fixed 32 KB messages (scales
    ~linearly — TID-tagged streams fill the bubbles, Fig 9).

Derived column ``pipeline_fill`` estimates occupied pipeline stages
(min(T, 10)/10): the paper's "7x idle-time reduction" is the T=8 row.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.services import encryption as E


def _throughput_cbc(n_streams: int, msg_kb: int, trials: int = 3) -> float:
    blocks_per = (msg_kb << 10) // 16
    rng = np.random.RandomState(0)
    data = rng.randint(0, 255, size=(n_streams, blocks_per, 16),
                       dtype=np.uint8)
    ivs = jnp.zeros((n_streams, 16), jnp.uint8)
    key = np.arange(16, dtype=np.uint8)
    rk = jnp.asarray(E.expand_key(key))
    xb = jnp.asarray(data)
    E.aes_cbc_multistream(xb, ivs, rk).block_until_ready()   # warm
    t0 = time.perf_counter()
    for _ in range(trials):
        E.aes_cbc_multistream(xb, ivs, rk).block_until_ready()
    dt = (time.perf_counter() - t0) / trials
    return n_streams * blocks_per * 16 / dt


def run():
    rows = []
    for kb in (1, 4, 16, 32, 64):
        bps = _throughput_cbc(1, kb)
        rows.append({"bench": "10a_msg_size", "cthreads": 1,
                     "msg_kb": kb, "mbps": bps / 1e6,
                     "pipeline_fill": 0.1})
    base = None
    for t in (1, 2, 4, 8, 16):
        bps = _throughput_cbc(t, 32)
        base = base or bps
        rows.append({"bench": "10b_threads", "cthreads": t, "msg_kb": 32,
                     "mbps": bps / 1e6,
                     "pipeline_fill": min(t, 10) / 10,
                     "scaling_vs_1thread": bps / base})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 10: AES CBC cThread scaling")
