"""Prefix sharing: prefill cost at 90% shared prompts + KV capacity.

Two paper-style claims for the copy-on-write prefix-sharing MMU, both
HARD-ASSERTED here (the suite fails CI if either regresses):

* prefill — a wave of requests whose prompts are 90% covered by a
  resident shared prefix must prefill in <= 0.5x the wall-clock of the
  same wave with sharing disabled (the engine only computes the
  uncovered suffix; shorter padded token buckets do the rest);
* capacity — under templated traffic a fixed page pool must admit
  >= 2x the concurrent sequences of a private-pages engine, because
  admission charges page credits only for the uncovered suffix.

Writes ``BENCH_prefix.json`` (via benchmarks.run).  Trend metrics:
``mean_s`` on the timing rows and the ``prefill_speedup_x`` /
``capacity_x`` ratios (both higher-is-better, registered in
``scripts/bench_history.py``).  The ratio rows are the gate metrics —
ratios of same-host timings are far quieter than the raw ms cells.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)

PAGE = 16
POOL = 192
SHARED_PAGES = 18                # 288-token shared prefix
TAIL = 2 * PAGE                  # 32-token unique tail: 90% shared
WAVE = 4                         # requests per prefill wave
TRIALS = 9


def _prefix() -> List[int]:
    return list(range(3, 3 + SHARED_PAGES * PAGE))


def _tail(uid: int) -> List[int]:
    return [(17 * uid + 5 * j + 7) % 500 for j in range(TAIL)]


def _mk_engine(cfg, params, *, sharing: bool, n_pages: int = POOL,
               max_batch: int = WAVE + 1):
    from repro.core.services import MMUConfig
    from repro.core.services.mmu import MMU
    from repro.serve.engine import ServingEngine
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=n_pages,
                        prefix_sharing=sharing))
    return ServingEngine(cfg, params, mmu, max_batch=max_batch,
                         max_len=512, seed=7)


def _prefill_wave_times(cfg, params, *, sharing: bool) -> List[float]:
    """Wall-clock of the admission+prefill step for repeated waves of
    90%-shared prompts.  An anchor request keeps the shared prefix
    resident (and the prefix index warm) across waves; each wave is
    drained before the next so every trial prefills from the queue."""
    eng = _mk_engine(cfg, params, sharing=sharing)
    eng.submit(_prefix() + _tail(0), max_new_tokens=200)   # anchor
    eng.step()                                             # anchor resident
    uid = 1
    times: List[float] = []
    for trial in range(TRIALS + 1):                        # +1 warmup
        for _ in range(WAVE):
            eng.submit(_prefix() + _tail(uid), max_new_tokens=2)
            uid += 1
        t0 = time.perf_counter()
        eng.step()                                         # prefill wave
        dt = time.perf_counter() - t0
        if trial > 0:                                      # drop compile
            times.append(dt)
        while eng.active > 1:                              # drain wave
            eng.step()
    return times


def _concurrent_admitted(cfg, params, *, sharing: bool, n_pages: int,
                         shared_pages: int = SHARED_PAGES) -> int:
    """How many templated sequences one admission pass fits into a
    fixed pool: private pages pay full freight, shared pages only the
    uncovered suffix.  ``shared_pages`` sets the prefix-hit rate —
    every prompt is SHARED_PAGES + 2 pages long, the first
    ``shared_pages`` of them drawn from the common template and the
    rest unique per request."""
    eng = _mk_engine(cfg, params, sharing=sharing, n_pages=n_pages,
                     max_batch=8)
    unique = (SHARED_PAGES - shared_pages) * PAGE + TAIL
    for uid in range(8):
        head = _prefix()[:shared_pages * PAGE]
        body = [(13 * uid + 3 * j + 11) % 500 for j in range(unique)]
        eng.submit(head + body, max_new_tokens=16)
    eng.step()                                             # one admission
    return eng.active


def run() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

    shared = _prefill_wave_times(cfg, params, sharing=True)
    private = _prefill_wave_times(cfg, params, sharing=False)
    # best-of-trials: the least-noise estimator of each wave's true
    # cost on a shared CI host (medians flap under scheduler jitter)
    t_shared = float(np.min(shared))
    t_private = float(np.min(private))
    speedup = t_private / max(t_shared, 1e-9)
    assert speedup >= 2.0, (
        f"90%-shared prefill must cost <= 0.5x unshared "
        f"(got {t_shared * 1e3:.2f}ms vs {t_private * 1e3:.2f}ms, "
        f"{speedup:.2f}x)")

    # pool sized so private traffic fits ~2 sequences (21 pages each);
    # sweep the prefix-hit rate: shared prefix covering 0/50/90% of
    # every prompt's pages
    pool = 45
    cap_rows = []
    capacity_x = 0.0
    for shared_pages in (0, SHARED_PAGES // 2, SHARED_PAGES):
        base = _concurrent_admitted(cfg, params, sharing=False,
                                    n_pages=pool,
                                    shared_pages=shared_pages)
        cap = _concurrent_admitted(cfg, params, sharing=True,
                                   n_pages=pool,
                                   shared_pages=shared_pages)
        hit_pct = round(100 * shared_pages / (SHARED_PAGES + 2))
        capacity_x = cap / max(base, 1)
        cap_rows.append({"config": f"capacity/hit{hit_pct:02d}_pool45",
                         "capacity_x": capacity_x,
                         "admitted_private": base,
                         "admitted_shared": cap})
    assert capacity_x >= 2.0, (
        f"effective KV capacity must be >= 2x at high hit-rate "
        f"(pool {pool}: {cap_rows[-1]})")

    wave_tokens = WAVE * (SHARED_PAGES * PAGE + TAIL)
    return [
        {"config": "prefill/shared_90pct", "mean_s": t_shared,
         "tokens_per_s": wave_tokens / max(t_shared, 1e-9),
         "wave_tokens": wave_tokens,
         "min_ms": float(np.min(shared)) * 1e3,
         "max_ms": float(np.max(shared)) * 1e3},
        {"config": "prefill/private", "mean_s": t_private,
         "tokens_per_s": wave_tokens / max(t_private, 1e-9),
         "wave_tokens": wave_tokens,
         "min_ms": float(np.min(private)) * 1e3,
         "max_ms": float(np.max(private)) * 1e3},
        {"config": "prefill/speedup", "prefill_speedup_x": speedup,
         "shared_ms": t_shared * 1e3, "private_ms": t_private * 1e3},
    ] + cap_rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "prefix sharing: 90%-shared prefill + KV capacity")
