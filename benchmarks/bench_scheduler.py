"""Multi-tenant scheduler QoS sweep: tenants × weights × packet sizes.

Every tenant drives one vFPGA slot with identical demand through the
shell scheduler; the weighted DWRR arbiter divides the link.  Reported per
cell: the contended byte-share ratio of the heaviest vs lightest tenant
against its configured target, weighted Jain's index over the contended
window, coalesced-batch count, and cumulative virtual link throughput.

"Contended" = the window in which every tenant still has backlog (up to
the first tenant's final byte) — after that the survivors inherit idle
bandwidth, which is not a QoS signal.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import Alloc, Oper, SgEntry, Shell, ShellConfig
from repro.core.credits import jains_index, weighted_jains_index

WEIGHT_SETS: Dict[str, Tuple[float, ...]] = {
    "1:1": (1.0, 1.0),
    "3:1": (3.0, 1.0),
    "4:2:1": (4.0, 2.0, 1.0),
    "8:1": (8.0, 1.0),
}


def _run_cell(weights: Tuple[float, ...], packet_bytes: int,
              buf_kb: int, n_bufs: int) -> Dict[str, float]:
    n = len(weights)
    # executor lanes OFF: this suite measures DWRR *arbitration* shares
    # on the deterministic virtual clock.  Lanes release credits from
    # real threads, coupling the virtual-time share measurement to host
    # scheduling jitter; lane execution latency has its own suite
    # (bench_multislot), so here we keep the instrument deterministic.
    shell = Shell(ShellConfig.make(services={}, n_vfpgas=n,
                                   packet_bytes=packet_bytes,
                                   executor_lanes=False))
    shell.build()
    names = [f"t{i}w{weights[i]:g}" for i in range(n)]
    for i, name in enumerate(names):
        shell.register_tenant(name, weights[i], slots=(i,))
    events: List[Tuple[float, str, int]] = []
    shell.static.pcie.on_event(
        lambda ev: events.append((ev.t, ev.src.split("/", 1)[0], ev.nbytes)))
    threads = [shell.attach_thread(i, pid=100 + i) for i in range(n)]
    shell.scheduler.pause()                     # saturate before moving bytes
    for ct in threads:
        for _ in range(n_bufs):
            buf = ct.getMem((Alloc.REG, buf_kb << 10))
            ct.invoke(Oper.LOCAL_TRANSFER,
                      SgEntry(src=ct.vaddr_of(buf), length=buf.size),
                      wait=False)
    shell.scheduler.resume()
    shell.drain()

    finish: Dict[str, float] = {}
    for t, ten, _ in events:
        finish[ten] = t
    t_star = min(finish.values())
    got = {name: 0 for name in names}
    for t, ten, nbytes in events:
        if t <= t_star:
            got[ten] += nbytes
    total = sum(got.values()) or 1
    shares = {k: v / total for k, v in got.items()}
    wmap = dict(zip(names, weights))
    heavy, light = names[0], names[-1]
    target = weights[0] / weights[-1]
    measured = got[heavy] / max(got[light], 1)
    sched = shell.scheduler.stats()
    clock = shell.static.pcie.clock
    shell.close()
    return {
        "config": f"w{':'.join(f'{w:g}' for w in weights)}-pkt{packet_bytes >> 10}k",
        "tenants": n,
        "weights": ":".join(f"{w:g}" for w in weights),
        "packet_kb": packet_bytes >> 10,
        "target_ratio": target,
        "measured_ratio": measured,
        "ratio_err_pct": 100.0 * abs(measured - target) / target,
        "jain_weighted": weighted_jains_index(shares, wmap),
        "jain_unweighted": jains_index(shares),
        "batches": sched["batches"],
        "coalesced_entries": sched["entries_coalesced"],
        "link_gbps": shell.static.pcie.bytes_moved / max(clock, 1e-12) / 1e9,
    }


def run(packet_kb=(1, 4, 16), buf_kb: int = 64,
        n_bufs: int = 24) -> List[Dict[str, float]]:
    rows = []
    for wname, weights in WEIGHT_SETS.items():
        for pkb in packet_kb:
            rows.append(_run_cell(weights, pkb << 10, buf_kb, n_bufs))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Scheduler QoS: weighted shares under saturation")
