"""Fig 7b reproduction: nested build flow — shell flow vs app flow.

Three shell configurations of increasing synthesis complexity (pass-through
/ vector-add + memory / RDMA + AES), built two ways:

  shell flow: synthesize services AND the app from scratch;
  app flow:   link ONLY the app against the routed-and-locked shell (the
              service executables hit the compile cache).

The reproduced claim is the 15-20% (or better) build-time reduction of the
app flow.  "Synthesis" here is XLA lower+compile of real executables.
"""
from __future__ import annotations

import jax

from repro.apps.aes import make_aes_artifact
from repro.apps.vector_add import make_passthrough_artifact, make_vector_add_artifact
from repro.core.reconfig import app_flow, shell_flow
from repro.core.shell import ShellConfig
from repro.core.services import (AESConfig, CollectiveConfig,
                                 CompressionConfig, MMUConfig)


def _configs():
    return [
        ("passthrough_hostonly",
         ShellConfig.make(services={}, n_vfpgas=2),
         make_passthrough_artifact()),
        ("vectoradd_cardmem",
         ShellConfig.make(services={"mmu": MMUConfig(page_size=256,
                                                     n_pages=512)},
                          n_vfpgas=2),
         make_vector_add_artifact()),
        ("rdma_aes",
         ShellConfig.make(services={
             "mmu": MMUConfig(page_size=256, n_pages=512),
             "collectives": CollectiveConfig(),
             "encryption": AESConfig(),
             "compression": CompressionConfig(),
         }, n_vfpgas=2),
         make_aes_artifact("cbc")),
    ]


def run():
    rows = []
    for name, cfg, art in _configs():
        jax.clear_caches()
        # shell flow: everything fresh
        shell, t_shell = shell_flow(cfg)
        _, t_app0 = app_flow(shell, 0, art)
        shell_total = t_shell.build_s + t_app0.build_s
        # app flow: swap in a different app against the SAME routed shell
        art2 = make_passthrough_artifact() if art.name != "passthrough" \
            else make_vector_add_artifact()
        _, t_app = app_flow(shell, 1, art2)
        # and relink the original app (cache hit on everything)
        _, t_relink = app_flow(shell, 0, art)
        rows.append({
            "config": name,
            "shell_flow_s": shell_total,
            "app_flow_s": t_app.build_s,
            "relink_s": t_relink.build_s,
            "reduction_pct": 100 * (1 - t_app.build_s / max(shell_total,
                                                            1e-9)),
            "svc_cache_hits": t_shell.cache_hits,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 7b: shell flow vs app flow build times")
