"""Mesh-sharded serving: decode throughput vs TP degree + wire bytes.

Each TP degree runs in a SUBPROCESS with 4 forced host CPU devices (the
parent, like every bench, must keep seeing 1 device).  Per degree we
measure steady-state fused-decode steps on a full batch and collect the
GREEDY token streams; ``run()`` HARD-ASSERTS that every degree produced
token-for-token identical streams — the bench doubles as the sharding
acceptance gate, so a silent TP numerics regression fails CI, not just a
parity test someone has to run.

All-reduce traffic is modeled, not sniffed: ``TPContext`` reports the
global psum payload per step (2 sites x n_layers x B x d_model x 4B) and
:meth:`CollectiveService.wire_bytes` converts it to per-device wire bytes
for the flat schedule the TP path uses (tiny latency-bound activations —
see collectives.all_reduce).  CPU wall-clock does NOT improve with TP (4
fake devices share the same cores and XLA:CPU collectives are memcpys);
the quantity to watch is tokens/s holding roughly flat while wire bytes
grow — compute is actually being partitioned.  The flat-vs-hierarchical
schedule story for gradient-sized payloads lives in
tests/test_collectives_multidev.py and docs/sharding.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.core.services.collectives import CollectiveService

TP_DEGREES = (1, 2, 4)
BATCH = 4
DECODE_STEPS = 24

_WORKER = textwrap.dedent("""
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.services.mmu import MMU, MMUConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.serve.engine import ServingEngine

    tp = int(sys.argv[1]); batch = int(sys.argv[2]); steps = int(sys.argv[3])
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    mesh = make_host_mesh(1, tp) if tp > 1 else None
    eng = ServingEngine(cfg, params, MMU(MMUConfig(page_size=16,
                                                   n_pages=256)),
                        max_batch=batch, max_len=256, seed=0, mesh=mesh)
    prompts = [list(range(3 + i, 11 + i)) for i in range(batch)]

    # parity pass: short greedy generations, run to completion
    for p in prompts:
        eng.submit(p, max_new_tokens=12, temperature=0.0)
    while eng.pending():
        eng.step()
    greedy = {r.rid: list(r.out_tokens) for r in eng.completed}

    # throughput pass: same shapes (no recompile), long decode tail
    for p in prompts:
        eng.submit(p, max_new_tokens=steps + 8, temperature=0.0)
    for _ in range(4):                       # admit + prefill + warmup
        eng.step()
    t0 = time.perf_counter()
    emitted = sum(eng.step() for _ in range(steps))
    dt = time.perf_counter() - t0
    bytes_step = (eng.tp.allreduce_bytes_per_step(batch)
                  if eng.tp is not None else 0)
    print("RESULT " + json.dumps({
        "tp": tp, "tokens_per_s": emitted / dt, "mean_s": dt / steps,
        "greedy": {str(k): v for k, v in greedy.items()},
        "shard_heads": bool(eng.tp and eng.tp.shard_heads),
        "shard_mlp": bool(eng.tp and eng.tp.shard_mlp),
        "allreduce_bytes_per_step": bytes_step}))
""")


def _measure(tp: int) -> dict:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)               # the worker pins its own
    r = subprocess.run(
        [sys.executable, "-c", _WORKER, str(tp), str(BATCH),
         str(DECODE_STEPS)],
        capture_output=True, text=True, timeout=540, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"tp={tp} worker produced no RESULT\n"
                       f"stdout:{r.stdout}\nstderr:{r.stderr[-3000:]}")


def run():
    results = [_measure(tp) for tp in TP_DEGREES]
    # ---- the acceptance gate: greedy streams identical across degrees ----
    base = results[0]["greedy"]
    for res in results[1:]:
        assert res["greedy"] == base, (
            f"GREEDY PARITY BROKEN: tp={res['tp']} diverged from tp=1 "
            f"({res['greedy']} vs {base})")
    rows = []
    for res in results:
        wire = CollectiveService.wire_bytes(
            "flat", res["allreduce_bytes_per_step"], data=res["tp"],
            pods=1)
        rows.append({
            "config": f"tp{res['tp']}_b{BATCH}",
            "tokens_per_s": res["tokens_per_s"],
            "mean_s": res["mean_s"],
            "tp": res["tp"],
            "shard_heads": res["shard_heads"],
            "shard_mlp": res["shard_mlp"],
            "allreduce_kb_per_step": res["allreduce_bytes_per_step"] / 1e3,
            "wire_kb_per_dev_step": wire["intra"] / 1e3,
            "greedy_parity": "ok",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Mesh-sharded serving: tokens/s vs TP degree (greedy "
                "parity gated)")
