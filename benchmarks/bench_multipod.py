"""Multi-pod collective schedule comparison (the BALBOA/RDMA analogue).

The collective service picks flat ring vs hierarchical (reduce-scatter
intra-pod / all-reduce across pods / all-gather back) at run time.  The
inter-pod links are the scarce resource (data-center fabric vs intra-pod
ICI): the hierarchical schedule crosses the pod boundary with 1/|data| of
the tensor.  Modeled wire bytes per device for a full-gradient all-reduce
on the 2x16x16 production mesh (correctness of the hierarchical schedule
is tested on real devices in tests/test_collectives_multidev.py)."""
from __future__ import annotations

from repro.core.services.collectives import CollectiveService

GRAD_SIZES_GB = {           # bf16 gradient bytes (global)
    "smollm-135m": 0.27,
    "granite-moe-1b-a400m": 2.7,
    "phi3-medium-14b": 28.0,
    "qwen2-72b": 145.0,
}


def run():
    rows = []
    data, pods = 16, 2
    for arch, gb in GRAD_SIZES_GB.items():
        nbytes = gb * 1e9 / (data * pods * 16)   # per-device shard after RS
        per_dev = gb * 1e9 / 256                 # rough per-device payload
        flat = CollectiveService.wire_bytes("flat", per_dev, data, pods)
        hier = CollectiveService.wire_bytes("hierarchical", per_dev, data,
                                            pods)
        # a flat ring over (pod, data) pushes its full wire volume across
        # the pod boundary links on the seam; hierarchical crosses with
        # only the scattered shard
        flat_inter = flat["intra"] + flat["inter"]
        rows.append({
            "arch": arch,
            "grad_gb": gb,
            "flat_total_mb_per_dev": flat_inter / 1e6,
            "hier_intra_mb_per_dev": hier["intra"] / 1e6,
            "hier_inter_mb_per_dev": hier["inter"] / 1e6,
            "interpod_reduction_x": flat_inter / max(hier["inter"], 1e-9),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Multi-pod: flat vs hierarchical all-reduce wire bytes")
