"""Assignment roofline table: every (arch x shape) baseline from the
dry-run cache (experiments/dryrun/), plus hillclimbed variants if present.

Run ``python -m repro.launch.dryrun --mesh both`` first (hours of compiles
are cached incrementally); this bench only reads the JSON records."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(mesh: str = "pod"):
    rows = []
    d = DRYRUN / mesh
    if not d.exists():
        return [{"note": f"no dry-run cache at {d}; run repro.launch.dryrun"}]
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec["status"] == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "tag": rec.get("tag", ""), "status": "skipped",
                         "dominant": "-", "compute_s": 0.0, "memory_s": 0.0,
                         "collective_s": 0.0, "roofline_frac": 0.0,
                         "useful_flops": 0.0, "hbm_gb_per_dev": 0.0})
            continue
        if rec["status"] != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "tag": rec.get("tag", ""), "status": "ERROR",
                         "dominant": rec.get("error", "?")[:40],
                         "compute_s": 0, "memory_s": 0, "collective_s": 0,
                         "roofline_frac": 0, "useful_flops": 0,
                         "hbm_gb_per_dev": 0})
            continue
        r = rec["roofline"]
        mem = rec.get("memory_analysis", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 1e9
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "tag": rec.get("tag", ""), "status": "ok",
            "dominant": r["dominant"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "roofline_frac": r["roofline_fraction"],
            "useful_flops": r["useful_flops_ratio"],
            "hbm_gb_per_dev": hbm,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run("pod"), "Roofline baselines (single pod 16x16)")
    emit(run("multipod"), "Roofline baselines (2 pods, 2x16x16)")
