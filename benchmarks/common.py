"""Shared benchmark helpers: timing, CSV row emission, JSON artifacts.

The JAX_PLATFORMS=cpu pin below makes benchmarks CPU-deterministic unless
the caller overrides it.  jax reads the variable once at import time, so
the pin only covers entrypoints that import this module (or set the env)
*before* importing jax — ``benchmarks.run`` and ``scripts/ci.sh`` do, and
bench modules with a ``__main__`` path must import common first.
"""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Callable, Dict, List

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def timeit(fn: Callable, *, warmup: int = 2, trials: int = 5) -> Dict:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return {"mean_s": statistics.mean(ts),
            "std_s": statistics.stdev(ts) if len(ts) > 1 else 0.0,
            "min_s": min(ts), "trials": trials}


def emit(rows: List[Dict], title: str) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def emit_json(rows: List[Dict], path: str, *, bench: str) -> None:
    """Write machine-readable bench rows.

    Schema: a list of ``{bench, config, tokens_per_s, mean_s}`` records
    (extra per-row keys are carried through under ``extra``).  ``config``
    is taken from the row's "config" key; throughput-style rows without
    one are skipped.
    """
    out = []
    for r in rows:
        if "config" not in r:
            continue
        rec = {
            "bench": bench,
            "config": r["config"],
            "tokens_per_s": float(r.get("tokens_per_s", 0.0)),
            "mean_s": float(r.get("mean_s", 0.0)),
        }
        extra = {k: v for k, v in r.items()
                 if k not in ("config", "tokens_per_s", "mean_s")}
        if extra:
            rec["extra"] = extra
        out.append(rec)
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=str)
        f.write("\n")
    print(f"[bench] wrote {path} ({len(out)} rows)")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
