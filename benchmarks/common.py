"""Shared benchmark helpers: timing, CSV row emission."""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List


def timeit(fn: Callable, *, warmup: int = 2, trials: int = 5) -> Dict:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return {"mean_s": statistics.mean(ts),
            "std_s": statistics.stdev(ts) if len(ts) > 1 else 0.0,
            "min_s": min(ts), "trials": trials}


def emit(rows: List[Dict], title: str) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
