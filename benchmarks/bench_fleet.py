"""Fleet controller: pre-copy vs stop-and-copy downtime + auto-migration.

Two scenarios, both at the LARGEST KV footprint of the BENCH_migrate
sweep (prompts of 120/200/160 tokens):

1. **Downtime A/B** — the same live tenant is moved back and forth
   between two shells ``N_MIGRATIONS`` times, once with stop-and-copy
   (``migrate``) and once with pre-copy (``migrate_precopy``).  Warm
   rounds ship KV pages while the source keeps decoding, so the freeze
   window carries only the dirty delta — the suite HARD-ASSERTS
   ``precopy p99 <= 0.25 x stop-and-copy p99``.
2. **Controller auto-migration** — a hot member (small page pool) and a
   cold member sit under a ``FleetController`` with gateways attached;
   ``sweep()`` (NOT a manual ``migrate`` call) detects the hotspot,
   pre-copy-migrates the tenant and re-homes the live token streams.
   The run asserts token-for-token parity against an undisturbed oracle
   engine and that every stream completes exactly once (none lost, none
   duplicated).

Writes ``BENCH_fleet.json`` (via benchmarks.run); trend metrics are
``mean_s`` = mean downtime for the A/B rows and ``downtime_p99_ms`` /
``precopy_rounds`` (bench_history EXTRA_METRICS) for the controller row.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)

PAGE = 16
POOL = 256                # A/B + cold-member pool
POOL_HOT = 64             # hot member: same tenant => ~0.5 utilization
N_MIGRATIONS = 6          # timed moves per mover (3 round trips)
MAX_ROUNDS = 3            # pre-copy warm rounds per move
# the largest footprint in the BENCH_migrate sweep (keep in sync)
PROMPTS_LARGE = [list(range(3, 3 + n)) for n in (120, 200, 160)]


def _mk_shell(name=None, pool=POOL):
    from repro.core import Shell, ShellConfig
    from repro.core.services import MMUConfig
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=pool)},
        n_vfpgas=2), name=name)
    s.build()
    return s


def _mk_engine(cfg, params, shell, *, rid_base=0, slot=0):
    from repro.serve.engine import ServingEngine
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=4, max_len=512, shell=shell, slot=slot,
                         tenant="gold", rid_base=rid_base)


def _percentiles(xs: List[float]) -> Dict[str, float]:
    a = np.asarray(xs) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


def _downtime_loop(cfg, params, precopy: bool) -> Dict[str, float]:
    """N_MIGRATIONS ping-pong moves of one live tenant; returns the
    downtime distribution plus payload/pre-copy accounting."""
    from repro.core.migrate import migrate, migrate_precopy
    a = _mk_shell("ab-a")
    b = _mk_shell("ab-b")
    eng_a = _mk_engine(cfg, params, a, rid_base=0)
    eng_b = _mk_engine(cfg, params, b, rid_base=1000)
    for p in PROMPTS_LARGE:
        eng_a.submit(p, max_new_tokens=64)
    for _ in range(3):
        eng_a.step()                       # live mid-decode state

    def mover(src, dst):
        if precopy:
            return migrate_precopy(src, dst, "gold",
                                   max_rounds=MAX_ROUNDS)
        return migrate(src, dst, "gold")

    downtimes, rounds, payload = [], [], 0
    pages = delta = 0
    shells = [(a, b, eng_b), (b, a, eng_a)]
    for k in range(2):                     # untimed warmup round trip:
        src, dst, dst_eng = shells[k % 2]  # compiles the gather/scatter
        mover(src, dst)                    # shapes for this footprint
        for _ in range(2):
            dst_eng.step()
    for k in range(N_MIGRATIONS):
        src, dst, dst_eng = shells[k % 2]
        rep = mover(src, dst)
        downtimes.append(rep.downtime_s)
        rounds.append(rep.precopy_rounds)
        payload = rep.payload_bytes
        pages = rep.n_pages
        delta = rep.delta_pages
        for _ in range(2):                 # keep decoding between moves
            dst_eng.step()
    a.close()
    b.close()
    out = {**_percentiles(downtimes), "mean_s": float(np.mean(downtimes)),
           "kv_pages": pages, "payload_mb": payload / 1e6,
           "migrations": N_MIGRATIONS}
    if precopy:
        out.update({"precopy_rounds": float(np.mean(rounds)),
                    "delta_pages": delta})
    return out


def _controller_scenario(cfg, params) -> Dict[str, float]:
    """Hotspot auto-migration through ``FleetController.sweep()`` with
    gateway re-routing; asserts oracle parity + exactly-once streams."""
    from repro.fleet import FleetController
    from repro.serve.gateway import ServingGateway

    hot = _mk_shell("hot", pool=POOL_HOT)
    cold = _mk_shell("cold", pool=POOL)
    oracle_shell = _mk_shell("oracle")
    eng_hot = _mk_engine(cfg, params, hot, rid_base=0)
    eng_cold = _mk_engine(cfg, params, cold, rid_base=1000)
    oracle = _mk_engine(cfg, params, oracle_shell, rid_base=2000)
    gw_hot = ServingGateway(eng_hot, admission="fifo")
    gw_cold = ServingGateway(eng_cold, admission="fifo")

    # the ramp prompts share prefixes, so CoW dedup keeps the hot member
    # at ~15 unique pages (util ~0.23 of its 64-page pool) — the
    # threshold sits just under that so the sweep flags it
    fc = FleetController(precopy=True, hot_util=0.20, cold_util=0.50)
    fc.add_shell(hot)
    fc.add_shell(cold)
    fc.attach_gateway(hot, gw_hot)
    fc.attach_gateway(cold, gw_cold)

    streams = [gw_hot.submit(p, max_new_tokens=48) for p in PROMPTS_LARGE]
    oracle_rids = [oracle.submit(p, max_new_tokens=48)
                   for p in PROMPTS_LARGE]
    for _ in range(4):                     # mid-decode on the hot member
        gw_hot.step()
        oracle.step()

    decisions = fc.sweep()                 # the controller decides
    moved = [d for d in decisions if d.action == "migrate" and d.ok]
    assert moved, f"sweep did not auto-migrate: {decisions}"
    rep = moved[0].report
    assert moved[0].src == "hot" and moved[0].dst == "cold", moved[0]

    gw_cold.drain()
    while oracle.pending():
        oracle.step()

    # exactly-once: every submitted stream finished, clean, on the cold
    # gateway, and the hot gateway retained nothing in flight
    assert all(s.done and s.error is None for s in streams), streams
    assert not gw_hot.streams and not gw_hot.queue
    done_ids = [id(s) for s in gw_cold.completed]
    assert sorted(done_ids) == sorted(id(s) for s in streams), \
        "streams lost or duplicated across the auto-migration"
    # token-for-token parity with the undisturbed oracle
    oracle_out = {r.rid: r.out_tokens for r in oracle.completed}
    for s, orid in zip(streams, oracle_rids):
        assert s.tokens == oracle_out[orid], \
            f"token divergence across auto-migration (rid {s.rid})"

    hot.close()
    cold.close()
    oracle_shell.close()
    return {"downtime_ms": rep.downtime_s * 1e3,
            "downtime_p99_ms": rep.downtime_s * 1e3,
            "precopy_rounds": rep.precopy_rounds,
            "precopy_pages": rep.precopy_pages,
            "delta_pages": rep.delta_pages,
            "streams_moved": len(streams),
            "parity": "ok"}


def run() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

    stop = _downtime_loop(cfg, params, precopy=False)
    pre = _downtime_loop(cfg, params, precopy=True)
    speedup = stop["p99_ms"] / max(pre["p99_ms"], 1e-9)
    # ISSUE acceptance gate: the freeze window must carry only the dirty
    # delta, so pre-copy downtime p99 <= 0.25 x stop-and-copy p99 at the
    # largest BENCH_migrate footprint
    assert pre["p99_ms"] <= 0.25 * stop["p99_ms"], (
        f"pre-copy p99 {pre['p99_ms']:.1f}ms > 0.25 x stop-and-copy "
        f"p99 {stop['p99_ms']:.1f}ms")
    rows = [
        {"config": "downtime/stopcopy_large", **stop},
        {"config": "downtime/precopy_large", **pre,
         "downtime_p99_ms": pre["p99_ms"], "speedup_x": speedup},
        {"config": "controller/auto_migration",
         **_controller_scenario(cfg, params)},
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "fleet: pre-copy downtime + controller auto-migration")
