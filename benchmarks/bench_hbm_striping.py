"""Fig 7a reproduction: per-app throughput vs number of HBM channels.

The MMU stripes pages round-robin across channels; a pass-through app
reads/writes through the virtual-memory path.  Modeled on v5e constants
(819 GB/s aggregate over 32 channel-equivalents): per-channel links are
virtual-clock models, while the translation cost per page access is the
real measured MMU/TLB lookup time — so the taper the paper attributes to
"memory virtualization overhead" comes out of the actual TLB code.  The
MMU-bypass row reproduces the paper's "expose channels directly" note.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.services.mmu import MMU, MMUConfig

HBM_BW = 819e9
N_CHAN_MAX = 32
CHAN_BW = HBM_BW / N_CHAN_MAX


def _translate_rate(mmu: MMU, accesses: int = 20000) -> float:
    """Measured MMU translations/second (the virtualization overhead)."""
    mmu.alloc_seq(1, mmu.config.page_size * 64)
    pos = np.random.RandomState(0).randint(
        0, mmu.config.page_size * 64, size=accesses)
    t0 = time.perf_counter()
    for p in pos:
        mmu.translate(1, int(p))
    dt = time.perf_counter() - t0
    mmu.free_seq(1)
    return accesses / dt


def run(buffer_mb: int = 64):
    """Sweep channels x page size.  Small pages expose the paper's taper
    (translation-rate bound); the 2 MB 'huge page' row stays channel-bound
    to 32 channels — the quantitative case for variable page size."""
    rows = []
    for page_bytes, label in ((64 << 10, "64K"), (2 << 20, "2M_huge")):
        for n_chan in (1, 2, 4, 8, 16, 32):
            mmu = MMU(MMUConfig(page_size=256, n_pages=1024,
                                n_channels=n_chan, tlb_entries=64,
                                tlb_assoc=4))
            rate = _translate_rate(mmu)
            # pages/s the MMU translates vs pages/s the channels move
            link_pages = n_chan * CHAN_BW / page_bytes
            mmu_pages = rate                  # one translation per page
            eff_pages = min(link_pages, mmu_pages)
            rows.append({
                "page": label,
                "hbm_channels": n_chan,
                "gbps_virtualized": eff_pages * page_bytes / 1e9,
                "gbps_bypass": link_pages * page_bytes / 1e9,
                "mmu_translations_per_s": rate,
                "bound": "mmu" if mmu_pages < link_pages else "channels",
                "tlb_hit_rate": mmu.tlb.hit_rate,
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 7a: throughput scaling with HBM channels")
