"""Table 2 reproduction: reconfiguration/migration controller throughput.

AXI HWICAP (19 MB/s, word writes) -> word-granular synchronous path;
PCAP/MCAP (128/145 MB/s)          -> mid-size synchronous chunks;
Coyote v2 ICAP (800 MB/s, stream) -> large chunks through async dispatch.

We report measured MB/s per path on the same payload; the *ordering and
ratios* are the reproduced claim (absolute numbers are CPU-container I/O).
"""
from __future__ import annotations

import numpy as np

from repro.core.static_layer import TransferEngine


def run(payload_mb: int = 32):
    eng = TransferEngine()
    data = np.random.RandomState(0).randint(
        0, 255, size=payload_mb << 20, dtype=np.uint8)
    rows = []

    out, st = eng.upload_word_granular(data[: 2 << 20], word_bytes=4096)
    rows.append({"controller": "hwicap_word4k", "interface": "AXI-Lite-ish",
                 "payload_mb": 2, "mbps": st.mbps, "chunks": st.chunks})

    for name, chunk in (("pcap_256k", 256 << 10), ("mcap_1m", 1 << 20)):
        # synchronous mid-size chunks: block after every chunk
        import time
        import jax.numpy as jnp
        import jax
        flat = data.view(np.uint8)
        t0 = time.perf_counter()
        dst = jnp.zeros((flat.size,), jnp.uint8)
        off = 0
        n = 0
        while off < flat.size:
            end = min(off + chunk, flat.size)
            piece = jnp.asarray(flat[off:end])
            dst = eng._write_at(dst, piece, off)
            dst.block_until_ready()
            off = end
            n += 1
        dt = time.perf_counter() - t0
        rows.append({"controller": name, "interface": "AXI",
                     "payload_mb": payload_mb,
                     "mbps": flat.size / dt / 1e6, "chunks": n})

    out, st = eng.upload(data, chunk_bytes=16 << 20)
    rows.append({"controller": "coyote_icap_stream", "interface": "AXI-Stream",
                 "payload_mb": payload_mb, "mbps": st.mbps,
                 "chunks": st.chunks})
    out, st = eng.upload_whole(data)
    rows.append({"controller": "upper_bound_dma", "interface": "-",
                 "payload_mb": payload_mb, "mbps": st.mbps, "chunks": 1})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Table 2: reconfiguration controller throughput")
