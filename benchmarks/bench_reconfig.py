"""Table 3 reproduction: shell reconfiguration latency, three scenarios.

  #1 pass-through kernel; MMU 2 MB pages  -> same kernel, 1 GB-page MMU
  #2 RDMA + traffic-writer kernel         -> two numerical kernels, no net
  #3 RDMA + traffic sniffer               -> RDMA only (sniffer off)

For each: Coyote kernel latency (in-memory reconfiguration), Coyote total
latency (+ bitstream read from disk), and the full-reprogramming analogue
(cold restart: drop every executable + service, clear XLA caches, rebuild,
reload weights).  Reproduced claim: kernel << total << cold (~10x).
"""
from __future__ import annotations

import statistics
import tempfile
from pathlib import Path

from repro.apps.vector_add import make_passthrough_artifact, make_vector_add_artifact
from repro.core.reconfig import save_shell_bitstream
from repro.core.shell import Shell, ShellConfig
from repro.core.services import (AESConfig, CollectiveConfig, MMUConfig,
                                 SnifferConfig)

SCENARIOS = [
    ("s1_mmu_pagesize",
     ShellConfig.make(services={"mmu": MMUConfig(page_size=256,
                                                 n_pages=256)}),
     ShellConfig.make(services={"mmu": MMUConfig(page_size=4096,
                                                 n_pages=16)})),
    ("s2_drop_rdma_add_kernels",
     ShellConfig.make(services={"collectives": CollectiveConfig(),
                                "mmu": MMUConfig()}),
     ShellConfig.make(services={"mmu": MMUConfig()}, n_vfpgas=4)),
    ("s3_toggle_sniffer",
     ShellConfig.make(services={"collectives": CollectiveConfig(),
                                "sniffer": SnifferConfig()}),
     ShellConfig.make(services={"collectives": CollectiveConfig()})),
]


def run(trials: int = 5):
    rows = []
    tmp = Path(tempfile.mkdtemp(prefix="coyote_bs_"))
    for name, cfg_a, cfg_b in SCENARIOS:
        kernel, total, warm, cold = [], [], [], []
        for t in range(trials):
            shell = Shell(cfg_a)
            shell.build()
            shell.load_app(0, make_passthrough_artifact())
            bs = tmp / f"{name}_{t}.bin"
            save_shell_bitstream(str(bs), cfg_b)
            lat = shell.reconfigure_shell(cfg_b, bitstream_path=str(bs))
            kernel.append(lat["kernel_s"] * 1e3)
            total.append(lat["total_s"] * 1e3)
            # warm path (paper: keep frequent shell bitstreams resident):
            # swap back and forth — every executable now cache-hits
            shell.reconfigure_shell(cfg_a)
            lat_w = shell.reconfigure_shell(cfg_b)
            warm.append(lat_w["kernel_s"] * 1e3)
            c = shell.cold_restart()
            cold.append(c["total_s"] * 1e3)
        rows.append({
            "scenario": name,
            "kernel_ms": statistics.mean(kernel),
            "kernel_std": statistics.stdev(kernel),
            "total_ms": statistics.mean(total),
            "total_std": statistics.stdev(total),
            "warm_kernel_ms": statistics.mean(warm),
            "cold_restart_ms": statistics.mean(cold),
            "cold_std": statistics.stdev(cold),
            "speedup_vs_cold": statistics.mean(cold)
            / max(statistics.mean(total), 1e-9),
            "warm_speedup_vs_cold": statistics.mean(cold)
            / max(statistics.mean(warm), 1e-9),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Table 3: shell reconfiguration latency")
