"""Fig 8 reproduction: multi-tenant AES-ECB bandwidth fairness.

N vFPGA slots each run the AES-ECB app and stream data over the shared
host link; the shell packetizes (4 KB), credits, and round-robins.
Reported: per-tenant share of link bytes (should be ~1/N each), Jain's
fairness index (→1.0), and cumulative virtual-link throughput (should stay
constant as N grows — no arbitration overhead)."""
from __future__ import annotations

import numpy as np

from repro.apps.aes import make_aes_artifact
from repro.core import Oper, SgEntry, Shell, ShellConfig
from repro.core.credits import jains_index
from repro.core.services import AESConfig, MMUConfig


def run(buf_kb: int = 256, tenants=(1, 2, 4, 8)):
    rows = []
    for n in tenants:
        cfg = ShellConfig.make(services={"encryption": AESConfig(),
                                         "mmu": MMUConfig()},
                               n_vfpgas=n)
        shell = Shell(cfg)
        shell.build()
        threads = []
        for slot in range(n):
            shell.load_app(slot, make_aes_artifact("ecb"))
            threads.append(shell.attach_thread(slot, pid=1000 + slot))
        # every tenant submits the same volume; the arbiter interleaves
        from repro.core.cthread import Alloc
        for ct in threads:
            src = ct.getMem((Alloc.HPF, buf_kb << 10))
            src[:] = np.random.RandomState(ct.tid).randint(
                0, 255, size=src.size, dtype=np.uint8)
            dst = ct.getMem((Alloc.HPF, buf_kb << 10))
            ct.invoke(Oper.LOCAL_TRANSFER,
                      SgEntry(src=ct.vaddr_of(src), dst=ct.vaddr_of(dst),
                              length=src.size),
                      wait=False)
        shell.drain()
        shares = shell.arbiter.fairness()
        clock = shell.static.pcie.clock
        moved = shell.static.pcie.bytes_moved
        rows.append({
            "tenants": n,
            "jain_index": jains_index(shares),
            "min_share": min(shares.values()) if shares else 0,
            "max_share": max(shares.values()) if shares else 0,
            "cumulative_gbps": moved / max(clock, 1e-12) / 1e9,
            "per_tenant_mb": (moved / n) / 1e6,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 8: multi-tenant AES ECB fair sharing")
