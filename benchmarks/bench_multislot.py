"""Two-tenant multi-slot A/B: executor lanes vs serialized execution.

One tenant ("batch") occupies slot 0 with long-running invocations — a
stand-in for an lm_serving serve loop or a streaming NN predict, i.e.
tens of milliseconds of checkpointed work per invocation.  A second
tenant ("latency") drives short closed-loop invocations on slot 1 and
measures submit→completion latency.

  * ``lanes=off`` — the pre-PR-4 baseline: one scheduler worker executes
    every slot's work serially, so each latency-tenant completion waits
    out whatever long batch is in flight (p99 ≈ the long-invocation
    duration).
  * ``lanes=on``  — granted work executes on per-slot lanes; slot 1's
    completions never queue behind slot 0's serve loop.

A third cell exercises same-slot preemption: high-priority invocations
against the busy slot complete inside the long batch's checkpoint holds
instead of waiting for the whole lane FIFO.

The workload is identical in both modes, so per-tenant billed bytes must
match exactly — lanes move WHERE execution happens, never what is billed.
Writes ``BENCH_multislot.json`` (via benchmarks.run) with the p99
speedup as the trend metric.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)
from repro.core import AppArtifact, Invocation, Oper, SgEntry, Shell, \
    ShellConfig

N_LONG = 40              # long invocations by the batch tenant
LONG_ITEMS = 10          # checkpointed units per long invocation
ITEM_S = 0.002           # seconds per unit  (one "decode step")
N_LAT = 40               # closed-loop latency-tenant requests
N_HI = 30                # high-priority same-slot requests


def _long_or_fast(vf_checkpoint=True):
    """Slot logic: payload byte 0 == 0 -> long checkpointed loop,
    anything else -> fast return (the tag scheme lets one slot serve
    both the background batch and the high-priority probes)."""
    def fn(iface, vf, x):
        data = np.asarray(x)
        if data.size and data.flat[0] == 0:
            for _ in range(LONG_ITEMS):
                time.sleep(ITEM_S)
                if vf_checkpoint:
                    vf.checkpoint()
        return x
    return fn


def _sg(nbytes: int, fill: int, stream: int = 0) -> SgEntry:
    return SgEntry(src=np.full(nbytes, fill, np.uint8), length=nbytes,
                   src_stream=stream, opcode=Oper.LOCAL_TRANSFER)


def _percentiles(lat_s: List[float]) -> Dict[str, float]:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


def _run_two_tenant(lanes: bool) -> Dict[str, float]:
    shell = Shell(ShellConfig.make(services={}, n_vfpgas=2,
                                   executor_lanes=lanes))
    shell.build()
    shell.register_tenant("batch", 1.0, slots=(0,))
    shell.register_tenant("latency", 1.0, slots=(1,))
    shell.load_app(0, AppArtifact(name="serve_loop", fn=_long_or_fast()))
    shell.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    p0, p1 = shell.attach(0), shell.attach(1)

    started = threading.Event()

    def batch_driver():
        futs = []
        for k in range(N_LONG):
            futs.append(p0.submit(Invocation.from_sg(_sg(4096, 0))))
            if k == 0:
                started.set()
        for f in futs:
            f.result(timeout=120.0)

    th = threading.Thread(target=batch_driver)
    th.start()
    started.wait(timeout=10.0)
    time.sleep(0.01)                       # slot 0 busy before we probe

    lats = []
    for _ in range(N_LAT):
        t0 = time.perf_counter()
        comp = p1.submit(Invocation.from_sg(_sg(256, 7))).result(
            timeout=120.0)
        assert comp.ok
        lats.append(time.perf_counter() - t0)
    th.join()
    shell.drain()
    stats = shell.scheduler.stats()["tenants"]
    out = {**_percentiles(lats),
           "billed_bytes_batch": stats["batch"]["bytes"],
           "billed_bytes_latency": stats["latency"]["bytes"],
           "completions_batch": stats["batch"]["completions"],
           "completions_latency": stats["latency"]["completions"]}
    shell.close()
    return out


def _run_same_slot(priority: int) -> Dict[str, float]:
    """Same-slot contention, lanes on: probes at ``priority`` against a
    slot running long checkpointed batches.  Probes ride their own
    stream (per-stream FIFO is inviolable — priority reorders only
    ACROSS streams): priority>0 preempts the in-flight long batch at
    its checkpoints; priority 0 waits out the lane FIFO."""
    shell = Shell(ShellConfig.make(services={}, n_vfpgas=1,
                                   executor_lanes=True))
    shell.build()
    shell.register_tenant("batch", 1.0, slots=(0,))
    shell.load_app(0, AppArtifact(name="serve_loop", fn=_long_or_fast()))
    port = shell.attach(0)
    started = threading.Event()

    def batch_driver():
        futs = []
        for k in range(N_LONG // 4):
            futs.append(port.submit(Invocation.from_sg(_sg(4096, 0))))
            if k == 0:
                started.set()
        for f in futs:
            f.result(timeout=120.0)

    th = threading.Thread(target=batch_driver)
    th.start()
    started.wait(timeout=10.0)
    time.sleep(0.01)

    lats = []
    for _ in range(N_HI):
        t0 = time.perf_counter()
        comp = port.submit(Invocation.from_sg(_sg(256, 7, stream=1),
                                              priority=priority)).result(
            timeout=120.0)
        assert comp.ok
        lats.append(time.perf_counter() - t0)
    th.join()
    shell.drain()
    lane = shell.scheduler.stats()["lanes"].get("0", {})
    out = {**_percentiles(lats),
           "preempt_runs": lane.get("preempt_runs", 0),
           "preemptions": shell.vfpgas[0].preemptions}
    shell.close()
    return out


def run() -> List[Dict]:
    off = _run_two_tenant(lanes=False)
    on = _run_two_tenant(lanes=True)
    billing_match = float(
        off["billed_bytes_batch"] == on["billed_bytes_batch"]
        and off["billed_bytes_latency"] == on["billed_bytes_latency"])
    speedup = off["p99_ms"] / max(on["p99_ms"], 1e-9)
    fifo = _run_same_slot(priority=0)
    hi = _run_same_slot(priority=5)
    rows = [
        {"config": "lat_tenant/lanes=off", **off},
        {"config": "lat_tenant/lanes=on", **on, "billing_match":
            billing_match},
        {"config": "p99_speedup", "p99_speedup_x": speedup,
         "p99_off_ms": off["p99_ms"], "p99_on_ms": on["p99_ms"],
         "billing_match": billing_match},
        {"config": "preempt/sameslot_fifo", **fifo},
        {"config": "preempt/sameslot_hiprio", **hi,
         "hiprio_speedup_x": fifo["p99_ms"] / max(hi["p99_ms"], 1e-9)},
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "multislot: executor lanes A/B")
