"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Suites listed in ``JSON_ARTIFACTS`` additionally write machine-readable
``BENCH_<name>.json`` files (schema: rows of ``{bench, config,
tokens_per_s, mean_s}``) for trend tracking across PRs.  The
``benchmarks.common`` import pins JAX_PLATFORMS=cpu for every suite.
A ``module:attr`` suite entry calls that attribute instead of ``run``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import emit, emit_json

SUITES = [
    ("table2_migration", "bench_migration",
     "Table 2: reconfiguration controller throughput"),
    ("fig7a_hbm_striping", "bench_hbm_striping",
     "Fig 7a: throughput scaling with HBM channels"),
    ("fig7b_build_flow", "bench_build_flow",
     "Fig 7b: shell flow vs app flow build times"),
    ("table3_reconfig", "bench_reconfig",
     "Table 3: shell reconfiguration latency"),
    ("fig8_multitenant", "bench_multitenant",
     "Fig 8: multi-tenant AES ECB fair sharing"),
    ("scheduler_qos", "bench_scheduler",
     "Scheduler QoS: weighted shares under saturation"),
    ("fig10_cthreads", "bench_cthreads",
     "Fig 10: AES CBC cThread scaling"),
    ("fig11_hll", "bench_hll",
     "Fig 11: HLL with on-demand reconfiguration"),
    ("fig12_nn", "bench_nn_inference",
     "Fig 12: NN inference Coyote vs staged-copy"),
    ("kernel_microbench", "bench_kernels",
     "Kernel microbench: paged attention ref vs pallas"),
    ("llm_serving", "bench_serving",
     "LLM serving: decode tokens/s vs batch x page x kernel"),
    ("llm_serving_scaling", "bench_serving:run_scaling",
     "LLM serving: decode throughput vs concurrency (Fig 10b shape)"),
    ("multislot_lanes", "bench_multislot",
     "Multi-slot executor lanes: two-tenant p50/p99 A/B + preemption"),
    ("live_migrate", "bench_migrate",
     "Live tenant migration: downtime vs KV footprint + bystander p99"),
    ("prefix_sharing", "bench_prefix",
     "Prefix sharing: 90%-shared prefill cost + effective KV capacity"),
    ("fault_storm", "bench_faults",
     "Fault storm: recovery downtime + bystander p99"),
    ("serving_gateway", "bench_gateway",
     "Serving gateway: open-arrival goodput, TTFT SLOs, admission"),
    ("multipod_collectives", "bench_multipod",
     "Mesh-sharded serving: tokens/s vs TP degree (greedy-parity gated)"),
    ("fleet_controller", "bench_fleet",
     "Fleet controller: pre-copy downtime gate + auto-migration parity"),
    ("roofline", "bench_roofline",
     "Assignment roofline table (from dry-run cache)"),
]

# suite name -> (json path, bench id) for machine-readable artifacts
JSON_ARTIFACTS = {
    "llm_serving": ("BENCH_serving.json", "bench_serving"),
    "scheduler_qos": ("BENCH_scheduler.json", "bench_scheduler"),
    "kernel_microbench": ("BENCH_kernels.json", "bench_kernels"),
    "multislot_lanes": ("BENCH_multislot.json", "bench_multislot"),
    "live_migrate": ("BENCH_migrate.json", "bench_migrate"),
    "prefix_sharing": ("BENCH_prefix.json", "bench_prefix"),
    "fault_storm": ("BENCH_faults.json", "bench_faults"),
    "serving_gateway": ("BENCH_gateway.json", "bench_gateway"),
    "multipod_collectives": ("BENCH_multipod.json", "bench_multipod"),
    "fleet_controller": ("BENCH_fleet.json", "bench_fleet"),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substring filters")
    args = ap.parse_args(argv)
    filters = [f for f in args.only.split(",") if f]

    failures = 0
    for name, module, title in SUITES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.perf_counter()
        try:
            module, _, attr = module.partition(":")
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            rows = getattr(mod, attr or "run")()
            emit(rows, f"{title}  [{time.perf_counter()-t0:.1f}s]")
            if name in JSON_ARTIFACTS:
                path, bench = JSON_ARTIFACTS[name]
                emit_json(rows, path, bench=bench)
        except Exception:
            failures += 1
            print(f"\n## {title}\nFAILED:", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
