"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import emit

SUITES = [
    ("table2_migration", "bench_migration",
     "Table 2: reconfiguration controller throughput"),
    ("fig7a_hbm_striping", "bench_hbm_striping",
     "Fig 7a: throughput scaling with HBM channels"),
    ("fig7b_build_flow", "bench_build_flow",
     "Fig 7b: shell flow vs app flow build times"),
    ("table3_reconfig", "bench_reconfig",
     "Table 3: shell reconfiguration latency"),
    ("fig8_multitenant", "bench_multitenant",
     "Fig 8: multi-tenant AES ECB fair sharing"),
    ("scheduler_qos", "bench_scheduler",
     "Scheduler QoS: weighted shares under saturation"),
    ("fig10_cthreads", "bench_cthreads",
     "Fig 10: AES CBC cThread scaling"),
    ("fig11_hll", "bench_hll",
     "Fig 11: HLL with on-demand reconfiguration"),
    ("fig12_nn", "bench_nn_inference",
     "Fig 12: NN inference Coyote vs staged-copy"),
    ("llm_serving", "bench_serving",
     "LLM serving: continuous batching on paged KV"),
    ("multipod_collectives", "bench_multipod",
     "Multi-pod: flat vs hierarchical all-reduce schedules"),
    ("roofline", "bench_roofline",
     "Assignment roofline table (from dry-run cache)"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)

    failures = 0
    for name, module, title in SUITES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["run"])
            rows = mod.run()
            emit(rows, f"{title}  [{time.perf_counter()-t0:.1f}s]")
        except Exception:
            failures += 1
            print(f"\n## {title}\nFAILED:", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
