"""Fault storm + self-healing: recovery downtime and bystander SLOs.

One shell serves a GOLD paged LM tenant on slot 0 while a BRONZE echo
tenant drives slot 1 closed-loop.  A seeded fault storm rotates through
the taxonomy — lane crash, IO error, dispatch failure, page-fault storm,
service-call fault, mid-migration abort — and after each faulted round
the slot is recovered in place (``Shell.recover_slot``: quiesce,
snapshot through the migration container, cold-reset, KV-intact
restore).  Reported:

  * recovery downtime p50/p99 over the rounds (``recovery_p99_ms`` is
    the trend metric — the self-healing latency budget);
  * the bystander's closed-loop p99 during the storm vs a storm-free
    baseline (``bystander_p99_ms`` — graceful degradation: faults on one
    tenant must not blow up another's tail).

HARD-ASSERTED inside the run (CI fails on violation): zero lost and
zero duplicated completions on the recovered port, and the recovered
tenant's decoded tokens are token-for-token identical to a fault-free
oracle — greedy AND sampled rows.

Writes ``BENCH_faults.json`` via benchmarks.run.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)

PAGE = 16
POOL = 256
N_PROBE = 60              # bystander closed-loop requests
MAX_NEW = 48              # gold decode budget (outlasts every round)


def _mk_shell(n_vfpgas=2):
    from repro.core import Shell, ShellConfig
    from repro.core.services import MMUConfig
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL,
                                   host_pool_pages=POOL)},
        n_vfpgas=n_vfpgas))
    s.build()
    return s


def _mk_engine(cfg, params, shell, slot=0):
    from repro.serve.engine import ServingEngine
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=4, max_len=512, shell=shell, slot=slot,
                         tenant="gold")


def _percentiles(xs: List[float]) -> Dict[str, float]:
    a = np.asarray(xs) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


REQS = [(list(range(3, 3 + 40)), 0.0), (list(range(3, 3 + 80)), 0.0),
        (list(range(50, 50 + 60)), 1.3), (list(range(7, 7 + 24)), 0.8)]


def _oracle_tokens(cfg, params) -> Dict[int, List[int]]:
    """The fault-free truth: same requests, no shell, no faults."""
    from repro.core.services import MMUConfig
    from repro.core.services.mmu import MMU
    from repro.serve.engine import ServingEngine
    eng = ServingEngine(cfg, params,
                        MMU(MMUConfig(page_size=PAGE, n_pages=POOL)),
                        max_batch=4, max_len=512)
    for prompt, temp in REQS:
        eng.submit(prompt, max_new_tokens=MAX_NEW, temperature=temp)
    while eng.pending():
        eng.step()
    return {r.rid: r.out_tokens for r in eng.completed}


def _storm(cfg, params, *, bystander: bool) -> Dict[str, float]:
    from repro.core import (AppArtifact, FaultKind, FaultPlan, Invocation,
                            MigrationError, Oper, SgEntry, migrate)
    shell = _mk_shell()
    dst = _mk_shell()                     # abort-round migration target
    # the echo app loads BEFORE the engine: loading an app unbinds any
    # engine already on the slot (the logic it wrapped is gone)
    shell.load_app(0, AppArtifact(name="echo", fn=lambda i, v, x: x))
    eng = _mk_engine(cfg, params, shell)
    _mk_engine(cfg, params, dst)
    shell.health.quarantine_after = 10 ** 6   # the storm faults gold on
    # purpose; quarantine policy is exercised in tests, not timed here
    for prompt, temp in REQS:
        eng.submit(prompt, max_new_tokens=MAX_NEW, temperature=temp)
    for _ in range(2):
        eng.step()

    probe_lat: List[float] = []
    stop = threading.Event()
    th = None
    if bystander:
        shell.register_tenant("bronze", 1.0, slots=(1,))
        shell.load_app(1, AppArtifact(name="echo2", fn=lambda i, v, x: x))
        bport = shell.attach(1)

        def probe():
            while not stop.is_set() and len(probe_lat) < N_PROBE:
                t0 = time.perf_counter()
                comp = bport.submit(Invocation.from_sg(SgEntry(
                    src=np.zeros(256, np.uint8), length=256,
                    opcode=Oper.LOCAL_TRANSFER))).result(timeout=60.0)
                assert comp.ok
                probe_lat.append(time.perf_counter() - t0)
        th = threading.Thread(target=probe)
        th.start()

    port = shell.attach(0)
    mmu_port = shell.attach("mmu")
    # one spec per round; filters keep the bystander clean (gold-tenant
    # IO/dispatch, slot-0 lanes) while the storm and service faults need
    # none (the bystander neither allocates pages nor calls services)
    from repro.core import FaultSpec
    specs = [
        FaultSpec(FaultKind.IO_ERROR, count=2, tenant="gold"),
        FaultSpec(FaultKind.DISPATCH, count=2, tenant="gold"),
        FaultSpec(FaultKind.LANE_CRASH, count=2, slot=0),
        FaultSpec(FaultKind.PAGE_FAULT_STORM, count=8),
        FaultSpec(FaultKind.SERVICE_CALL, count=2),
        FaultSpec(FaultKind.MIGRATION_FAIL, count=1),
    ]
    downtimes: List[float] = []
    faults_fired = 0
    # warm the recovery path once (compiles the snapshot gather/scatter
    # shapes) before anything is timed
    shell.recover_slot(0)
    for k, spec in enumerate(specs):
        plan = FaultPlan([spec], seed=k)
        shell.set_fault_plan(plan)
        if spec.kind is FaultKind.MIGRATION_FAIL:
            try:
                migrate(shell, dst, "gold")
                raise AssertionError("armed migration abort did not fire")
            except MigrationError:
                pass                      # source keeps serving — proven
        else:                             # by the parity assert below
            # the storm round decodes across a page boundary on every
            # live row so the allocator actually probes its site
            steps = (PAGE + 2 if spec.kind is FaultKind.PAGE_FAULT_STORM
                     else 2)
            for i in range(steps):
                inv = Invocation.from_sg(SgEntry(
                    src=np.full(128, k, np.uint8), length=128,
                    opcode=Oper.LOCAL_TRANSFER))
                inv.max_retries = 1       # lane faults: one bounded retry
                port.submit(inv)
                mmu_port.submit(Invocation.call("utilization"))
                eng.step()
        faults_fired += plan.stats()["fired_total"]
        shell.set_fault_plan(None)
        rep = shell.recover_slot(0)       # the self-healing verb, timed
        downtimes.append(rep.downtime_s)
        eng.step()

    while eng.pending():
        eng.step()
    if th is not None:
        stop.set()
        th.join()
    shell.drain()

    # -- hard gates ---------------------------------------------------------
    got = {r.rid: r.out_tokens for r in eng.completed}
    want = _storm.oracle
    assert got == want, "recovered tenant diverged from fault-free oracle"
    st = shell.attach(0).stats()
    assert st["submitted"] == st["completed"] + st["failed"], \
        f"lost/dup completions on the recovered port: {st}"
    assert st["inflight"] == 0 and st["held"] == 0, st
    mmu = shell.services.get("mmu")
    assert mmu.page_faults >= 1           # the page-fault storm churned
    assert shell.health.recoveries == len(downtimes) + 1

    out = {**_percentiles(downtimes),
           "mean_s": float(np.mean(downtimes)),
           "rounds": len(downtimes), "faults_fired": faults_fired,
           "retried": st["retried"], "typed_failures": st["failed"]}
    if probe_lat:
        bp = _percentiles(probe_lat)
        out.update({"bystander_p50_ms": bp["p50_ms"],
                    "bystander_p99_ms": bp["p99_ms"],
                    "probes": len(probe_lat)})
    shell.close()
    dst.close()
    return out


def _bystander_baseline() -> Dict[str, float]:
    """The probe alone (no fault storm, no recoveries)."""
    from repro.core import AppArtifact, Invocation, Oper, SgEntry
    shell = _mk_shell()
    shell.register_tenant("bronze", 1.0, slots=(1,))
    shell.load_app(1, AppArtifact(name="echo2", fn=lambda i, v, x: x))
    port = shell.attach(1)
    lats = []
    for _ in range(N_PROBE):
        t0 = time.perf_counter()
        comp = port.submit(Invocation.from_sg(SgEntry(
            src=np.zeros(256, np.uint8), length=256,
            opcode=Oper.LOCAL_TRANSFER))).result(timeout=60.0)
        assert comp.ok
        lats.append(time.perf_counter() - t0)
    shell.drain()
    shell.close()
    p = _percentiles(lats)
    return {"mean_s": p["p99_ms"] / 1e3,
            "bystander_p99_ms": p["p99_ms"], **p, "probes": N_PROBE}


def run() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    _storm.oracle = _oracle_tokens(cfg, params)

    rows = []
    storm = _storm(cfg, params, bystander=True)
    # mean_s = mean recovery downtime; recovery_p99_ms is the headline
    rows.append({"config": "recovery/downtime",
                 "mean_s": storm["mean_s"],
                 "recovery_p50_ms": storm["p50_ms"],
                 "recovery_p99_ms": storm["p99_ms"],
                 "rounds": storm["rounds"],
                 "faults_fired": storm["faults_fired"],
                 "retried": storm["retried"],
                 "typed_failures": storm["typed_failures"]})
    rows.append({"config": "bystander/during_faults",
                 "mean_s": storm["bystander_p99_ms"] / 1e3,
                 "bystander_p50_ms": storm["bystander_p50_ms"],
                 "bystander_p99_ms": storm["bystander_p99_ms"],
                 "probes": storm.get("probes", 0)})
    rows.append({"config": "bystander/baseline", **_bystander_baseline()})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "fault storm: recovery downtime + bystander p99")
