"""Beyond-paper: LLM continuous batching = the AES-CBC story at LM scale.

Token-by-token decode is the sequential-dependence pipeline the paper names
explicitly ("LLMs, where each token depends on the previously generated
token").  The serving engine fills decode bubbles with concurrent requests
through the paged-KV MMU.

Two sweeps:

  * decode throughput vs (batch x page_size x use_pallas) on the
    device-resident hot path — donated pools, fused on-device sampling,
    cached block tables.  Rows carry the machine-readable schema
    (``config``/``tokens_per_s``/``mean_s``) and land in
    ``BENCH_serving.json`` via ``benchmarks.run``.
  * the paper-shaped concurrency scaling curve (Fig 10b's shape).

Reproduce: PYTHONPATH=src python -m benchmarks.run --only llm_serving
"""
from __future__ import annotations

import time

import numpy as np

# must precede the jax import: common.py pins JAX_PLATFORMS=cpu, which
# jax reads once at import time
from benchmarks.common import emit_json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine

# (batch, page_size, use_pallas) — pallas runs in interpret mode on CPU,
# so it gets one small config; the jnp oracle carries the wide sweep.
SWEEP = [
    (1, 16, False),
    (4, 16, False),
    (8, 16, False),
    (16, 16, False),
    (8, 4, False),
    (8, 64, False),
    (2, 16, True),
]


def _decode_once(cfg, params, *, batch: int, page: int,
                 use_pallas: bool, new_tokens: int) -> dict:
    rng = np.random.RandomState(0)
    mmu = MMU(MMUConfig(page_size=page, n_pages=2048))
    eng = ServingEngine(cfg, params, mmu, max_batch=batch, max_len=256,
                        use_pallas=use_pallas)
    for _ in range(batch):
        plen = int(rng.randint(8, 24))
        eng.submit(rng.randint(3, cfg.vocab_size, plen).tolist(),
                   max_new_tokens=new_tokens)
    eng.step()                       # warm the decode executable
    toks0, steps0 = eng.tokens_out, eng.steps
    t0 = time.perf_counter()
    while eng.pending():
        eng.step()
    dt = time.perf_counter() - t0
    decode_toks = eng.tokens_out - toks0
    steps = eng.steps - steps0
    return {
        "config": f"b{batch}_p{page}_pallas{int(use_pallas)}",
        "tokens_per_s": decode_toks / max(dt, 1e-9),
        "mean_s": dt / max(steps, 1),
        "decode_tokens": decode_toks,
        "steps": steps,
        "tlb_hit_rate": mmu.tlb.hit_rate,
        "block_table_uploads": eng.block_table.row_uploads,
        "block_table_hits": eng.block_table.hits,
    }


def _decode_throughput(cfg, params, *, batch: int, page: int,
                       use_pallas: bool, new_tokens: int = 32,
                       trials: int = 3) -> dict:
    """Best-of-N decode cell: single-shot engine runs on a shared CPU are
    ±20% noisy, which drowns the cross-PR trend signal the JSON artifact
    exists for.  The interpret-mode Pallas cell runs once (it is slow and
    its absolute number is not a trend metric)."""
    if use_pallas:
        trials = 1
    best = None
    for _ in range(trials):
        row = _decode_once(cfg, params, batch=batch, page=page,
                           use_pallas=use_pallas, new_tokens=new_tokens)
        if best is None or row["tokens_per_s"] > best["tokens_per_s"]:
            best = row
    return best


def run(new_tokens: int = 32):
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rows = []
    for batch, page, use_pallas in SWEEP:
        nt = 8 if use_pallas else new_tokens      # interpret mode is slow
        rows.append(_decode_throughput(cfg, params, batch=batch, page=page,
                                       use_pallas=use_pallas,
                                       new_tokens=nt))
    return rows


def run_scaling(new_tokens: int = 12):
    """Paper-shaped curve: throughput vs concurrency (Fig 10b)."""
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.RandomState(0)
    rows = []
    base = None
    for streams in (1, 2, 4, 8):
        mmu = MMU(MMUConfig(page_size=16, n_pages=512))
        eng = ServingEngine(cfg, params, mmu, max_batch=streams,
                            max_len=256)
        for _ in range(streams):
            plen = int(rng.randint(8, 24))
            eng.submit(rng.randint(3, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=new_tokens)
        eng.step()                   # warm the decode executable
        stats = eng.run()
        tps = stats["tokens_per_s"]
        base = base or tps
        rows.append({
            "concurrent_streams": streams,
            "decode_tokens_per_s": tps,
            "scaling_vs_1": tps / base,
            "engine_steps": stats["engine_steps"],
            "tlb_hit_rate": mmu.tlb.hit_rate,
            "page_faults": mmu.page_faults,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    rows = run()
    emit(rows, "LLM serving: decode tokens/s vs batch x page x kernel")
    emit_json(rows, "BENCH_serving.json", bench="bench_serving")
    emit(run_scaling(), "LLM serving: decode throughput vs concurrency")
