"""Beyond-paper: LLM continuous batching = the AES-CBC story at LM scale.

Token-by-token decode is the sequential-dependence pipeline the paper names
explicitly ("LLMs, where each token depends on the previously generated
token").  The serving engine fills decode bubbles with concurrent requests
through the paged-KV MMU; throughput should scale with concurrency until
compute saturates — Fig 10b's shape, produced by an LM."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.engine import ServingEngine


def run(new_tokens: int = 12):
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.RandomState(0)
    rows = []
    base = None
    for streams in (1, 2, 4, 8):
        mmu = MMU(MMUConfig(page_size=16, n_pages=512))
        eng = ServingEngine(cfg, params, mmu, max_batch=streams,
                            max_len=256)
        for i in range(streams):
            plen = int(rng.randint(8, 24))
            eng.submit(rng.randint(3, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=new_tokens)
        # warm the decode executable at this batch size
        eng.step()
        stats = eng.run()
        tps = stats["tokens_per_s"]
        base = base or tps
        rows.append({
            "concurrent_streams": streams,
            "decode_tokens_per_s": tps,
            "scaling_vs_1": tps / base,
            "engine_steps": stats["engine_steps"],
            "tlb_hit_rate": mmu.tlb.hit_rate,
            "page_faults": mmu.page_faults,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "LLM serving: decode throughput vs concurrency (paged KV)")
