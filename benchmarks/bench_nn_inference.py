"""Fig 12 reproduction: NN inference — CoyoteAccelerator vs staged-copy.

The hls4ml intrusion-detection MLP served two ways (see
repro/apps/nn_inference.py).  Reproduced claim: the streamed, AOT path is
~an order of magnitude faster at small batch (latency-bound) and the gap
narrows at large batch (compute-bound), at equal 'resource' (device
memory) cost."""
from __future__ import annotations

import time

import numpy as np

from repro.apps.nn_inference import CoyoteOverlay, StagedCopyBaseline
from repro.core import Shell, ShellConfig
from repro.core.services import MMUConfig


def run(n: int = 8192, trials: int = 3):
    shell = Shell(ShellConfig.make(services={"mmu": MMUConfig()},
                                   n_vfpgas=1))
    shell.build()
    ov = CoyoteOverlay(shell, 0)
    X = np.random.RandomState(0).randn(n, ov.cfg.d_in).astype(np.float32)

    rows = []
    for batch in (32, 256, 2048):
        ov.program_fpga(warm_batch=batch)
        base = StagedCopyBaseline(ov.params)
        y_c = ov.predict(X, batch_size=batch)          # warm both
        y_b = base.predict(X, batch_size=batch)
        assert np.allclose(y_c, y_b, atol=1e-5)

        t0 = time.perf_counter()
        for _ in range(trials):
            ov.predict(X, batch_size=batch)
        t_coyote = (time.perf_counter() - t0) / trials

        t0 = time.perf_counter()
        for _ in range(trials):
            base.predict(X, batch_size=batch)
        t_staged = (time.perf_counter() - t0) / trials

        rows.append({
            "batch": batch,
            "coyote_us_per_sample": t_coyote / n * 1e6,
            "staged_us_per_sample": t_staged / n * 1e6,
            "speedup": t_staged / t_coyote,
            "coyote_sps": n / t_coyote,
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 12: NN inference Coyote vs staged-copy baseline")
