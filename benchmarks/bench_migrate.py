"""Live tenant migration: downtime vs KV footprint + bystander impact.

A paged LM-serving tenant is migrated back and forth between two shells
mid-decode (``repro.core.migrate.migrate``).  For each tenant KV
footprint the suite reports the migration downtime distribution
(p50/p99 over repeated moves — intake hold at the source to held-replay
done at the destination) and the snapshot payload size.  A final pair of
rows measures a BYSTANDER tenant's closed-loop latency on the
destination shell with and without a migration storm running — the
paper-style non-interference claim: migrating one tenant must not
disturb another's p99.

Writes ``BENCH_migrate.json`` (via benchmarks.run); the trend metric is
``mean_s`` = mean downtime (lower is better).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)

PAGE = 16
POOL = 256
N_MIGRATIONS = 6          # moves per footprint (3 round trips)
N_PROBE = 60              # bystander closed-loop requests


def _mk_shell(n_vfpgas=2):
    from repro.core import Shell, ShellConfig
    from repro.core.services import MMUConfig
    s = Shell(ShellConfig.make(
        services={"mmu": MMUConfig(page_size=PAGE, n_pages=POOL)},
        n_vfpgas=n_vfpgas))
    s.build()
    return s


def _mk_engine(cfg, params, shell):
    from repro.serve.engine import ServingEngine
    return ServingEngine(cfg, params, shell.services.get("mmu"),
                         max_batch=4, max_len=512, shell=shell, slot=0,
                         tenant="gold")


def _percentiles(xs: List[float]) -> Dict[str, float]:
    a = np.asarray(xs) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


def _migrate_loop(cfg, params, prompts: List[List[int]],
                  bystander: bool = False) -> Dict[str, float]:
    """Run N_MIGRATIONS moves of a live tenant between two shells;
    optionally probe a bystander tenant's latency on shell B meanwhile."""
    from repro.core import (AppArtifact, Invocation, Oper, SgEntry,
                            migrate)
    a, b = _mk_shell(), _mk_shell()
    eng_a, eng_b = _mk_engine(cfg, params, a), _mk_engine(cfg, params, b)
    for p in prompts:
        eng_a.submit(p, max_new_tokens=64)
    for _ in range(3):
        eng_a.step()                       # live mid-decode state

    probe_lat: List[float] = []
    stop = threading.Event()
    if bystander:
        b.register_tenant("bronze", 1.0, slots=(1,))
        b.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
        port = b.attach(1)

        def probe():
            while not stop.is_set() and len(probe_lat) < N_PROBE:
                t0 = time.perf_counter()
                comp = port.submit(Invocation.from_sg(SgEntry(
                    src=np.zeros(256, np.uint8), length=256,
                    opcode=Oper.LOCAL_TRANSFER))).result(timeout=60.0)
                assert comp.ok
                probe_lat.append(time.perf_counter() - t0)
        th = threading.Thread(target=probe)
        th.start()

    downtimes, payload = [], 0
    pages = 0
    shells = [(a, b, eng_b), (b, a, eng_a)]
    for k in range(2):                     # untimed warmup round trip:
        src, dst, dst_eng = shells[k % 2]  # compiles the gather/scatter
        migrate(src, dst, "gold")          # shapes for this footprint
        for _ in range(2):
            dst_eng.step()
    for k in range(N_MIGRATIONS):
        src, dst, dst_eng = shells[k % 2]
        rep = migrate(src, dst, "gold")
        downtimes.append(rep.downtime_s)
        payload = rep.payload_bytes
        pages = rep.n_pages
        for _ in range(2):                 # keep decoding between moves
            dst_eng.step()
    if bystander:
        stop.set()
        th.join()
        b.drain()
    a.close()
    b.close()
    out = {**_percentiles(downtimes), "mean_s": float(np.mean(downtimes)),
           "kv_pages": pages, "payload_mb": payload / 1e6,
           "migrations": N_MIGRATIONS}
    if probe_lat:
        bp = _percentiles(probe_lat)
        out.update({"bystander_p50_ms": bp["p50_ms"],
                    "bystander_p99_ms": bp["p99_ms"],
                    "bystander_mean_ms": bp["mean_ms"],
                    "probes": len(probe_lat)})
    return out


def _bystander_baseline() -> Dict[str, float]:
    """The probe alone (no migration storm) — the comparison point."""
    from repro.core import AppArtifact, Invocation, Oper, SgEntry
    b = _mk_shell()
    b.register_tenant("bronze", 1.0, slots=(1,))
    b.load_app(1, AppArtifact(name="echo", fn=lambda i, v, x: x))
    port = b.attach(1)
    lats = []
    for _ in range(N_PROBE):
        t0 = time.perf_counter()
        comp = port.submit(Invocation.from_sg(SgEntry(
            src=np.zeros(256, np.uint8), length=256,
            opcode=Oper.LOCAL_TRANSFER))).result(timeout=60.0)
        assert comp.ok
        lats.append(time.perf_counter() - t0)
    b.drain()
    b.close()
    p = _percentiles(lats)
    # mean_s = p99, matching the during-migration row's gate metric
    return {"mean_s": p["p99_ms"] / 1e3, **p, "probes": N_PROBE}


def run() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)

    rows = []
    footprints = {
        "small": [list(range(3, 3 + n)) for n in (12, 20)],
        "large": [list(range(3, 3 + n)) for n in (120, 200, 160)],
    }
    for name, prompts in footprints.items():
        r = _migrate_loop(cfg, params, prompts)
        rows.append({"config": f"downtime/kv_{name}", **r})
    storm = _migrate_loop(cfg, params, footprints["large"],
                          bystander=True)
    # mean_s carries the p99 (the non-interference gate metric: a
    # migration storm must not blow up a bystander's tail latency)
    rows.append({"config": "bystander/during_migration",
                 "mean_s": storm["bystander_p99_ms"] / 1e3,
                 "p50_ms": storm["bystander_p50_ms"],
                 "p99_ms": storm["bystander_p99_ms"],
                 "mean_ms": storm["bystander_mean_ms"],
                 "probes": storm.get("probes", 0)})
    rows.append({"config": "bystander/baseline", **_bystander_baseline()})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "live migration: downtime + bystander p99")
