"""Fig 11 reproduction: HyperLogLog under the shell vs direct baseline,
plus on-demand partial reconfiguration (the background-daemon deployment).

Coyote v1 analogue = calling the jitted sketch directly; Coyote v2 path =
the same kernel behind the vFPGA interface (streams, credits, interrupts).
Claim: comparable throughput (interface overhead ~0) and a fast app-load
(the paper's 57 ms on-demand reconfiguration)."""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.apps.hll import hll_count, hll_sketch, make_hll_artifact
from repro.core import Oper, SgEntry, Shell, ShellConfig
from repro.core.cthread import Alloc
from repro.core.services import MMUConfig


def run(n_items: int = 1 << 20, trials: int = 3):
    rows = []
    rng = np.random.RandomState(0)
    items = rng.randint(0, 1 << 30, size=n_items).astype(np.uint32)
    raw = items.view(np.uint8)
    nbytes = n_items * 4

    # direct (Coyote v1-ish baseline: same kernel, no shell; same
    # bytes-in -> uint32 view as the app sees)
    hll_sketch(jnp.asarray(raw.view(np.uint32)), p=12).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(trials):
        hll_sketch(jnp.asarray(raw.view(np.uint32)),
                   p=12).block_until_ready()
    direct = nbytes * trials / (time.perf_counter() - t0)

    # through the shell (vFPGA app + cThread + credits)
    shell = Shell(ShellConfig.make(services={"mmu": MMUConfig()},
                                   n_vfpgas=1))
    shell.build()

    t0 = time.perf_counter()
    load = shell.load_app(0, make_hll_artifact())
    load_ms = (time.perf_counter() - t0) * 1e3       # on-demand reconfig
    ct = shell.attach_thread(0, pid=1)
    buf = ct.getMem((Alloc.HPF, nbytes))
    buf[:] = raw[:nbytes]
    comp = ct.invoke(Oper.LOCAL_TRANSFER,
                     SgEntry(src=ct.vaddr_of(buf), length=nbytes))  # warm
    t0 = time.perf_counter()
    for _ in range(trials):
        comp = ct.invoke(Oper.LOCAL_TRANSFER,
                         SgEntry(src=ct.vaddr_of(buf), length=nbytes))
    shelled = nbytes * trials / (time.perf_counter() - t0)

    est = comp.result
    true = len(np.unique(items))
    rows.append({
        "path": "direct_baseline", "mbps": direct / 1e6,
        "rel_err_pct": 0.0, "app_load_ms": 0.0})
    rows.append({
        "path": "coyote_v2_shell", "mbps": shelled / 1e6,
        "rel_err_pct": 100 * abs(est - true) / true,
        "app_load_ms": load_ms})
    rows.append({
        "path": "overhead_ratio", "mbps": shelled / direct,
        "rel_err_pct": 0.0, "app_load_ms": load_ms})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Fig 11: HLL with on-demand reconfiguration")
