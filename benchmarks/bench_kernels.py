"""Kernel microbenchmarks: paged-attention decode, Pallas vs jnp oracle.

One row per (batch, pages-per-seq, kernel, pages_per_block) cell; rows
carry a ``config`` key and a tokens/s figure so the suite lands in the
machine-readable ``BENCH_kernels.json`` artifact and can be diffed across
PRs by ``scripts/diff_bench.py``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.ops import paged_decode

HEADS, KV_HEADS, HEAD_DIM = 8, 4, 64
PAGE = 16


def _cell(b: int, seq_pages: int, kern: str,
          ppb: int | None) -> Dict[str, float]:
    rng = np.random.RandomState(b * 131 + seq_pages)
    n_pages = b * seq_pages + 8
    q = jnp.asarray(rng.randn(b, HEADS, HEAD_DIM), jnp.float32)
    kp = jnp.asarray(rng.randn(n_pages, PAGE, KV_HEADS, HEAD_DIM) * 0.3,
                     jnp.float32)
    vp = jnp.asarray(rng.randn(n_pages, PAGE, KV_HEADS, HEAD_DIM) * 0.3,
                     jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages)[:b * seq_pages].reshape(b, seq_pages)
        .astype(np.int32))
    lens = jnp.full((b,), seq_pages * PAGE, jnp.int32)
    use_pallas = kern == "pallas"

    def step():
        paged_decode(q, kp, vp, tables, lens, use_pallas=use_pallas,
                     pages_per_block=ppb).block_until_ready()

    t = timeit(step, warmup=2, trials=5)
    ppb_tag = f"-ppb{ppb}" if ppb is not None else ""
    return {
        "config": f"b{b}-p{seq_pages}-{kern}{ppb_tag}",
        "batch": b,
        "seq_pages": seq_pages,
        "kernel": kern,
        # best-of-trials: the gated trend metric must be robust to the
        # dispatch/GC spikes that give the interpret-mode pallas cells
        # std ~ mean (mean-based tokens/s swung >2x run-to-run, which no
        # sane CI floor survives; min-of-5 is stable)
        "tokens_per_s": b / max(t["min_s"], 1e-12),
        "mean_s": t["mean_s"],
        "std_s": t["std_s"],
        "min_s": t["min_s"],
    }


def run() -> List[Dict[str, float]]:
    rows = []
    for b in (4, 8):
        for seq_pages in (4, 8):
            rows.append(_cell(b, seq_pages, "ref", None))
            rows.append(_cell(b, seq_pages, "pallas", None))
            rows.append(_cell(b, seq_pages, "pallas", 2))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "Kernel microbench: paged attention ref vs pallas")
