"""Serving gateway: open-arrival Poisson load, goodput and latency SLOs.

Three claims, each measured closed-loop against the same arrival trace
and HARD-ASSERTED where they are correctness rather than speed:

  * **Continuous batching buys goodput.**  A saturating Poisson stream
    of mixed short/long requests with per-tier deadlines is served by
    the same engine in ``mode="continuous"`` (completed rows backfilled
    every step) and ``mode="wave"`` (admit only when idle — the classic
    static-batch baseline).  Continuous must deliver >= 1.3x the wave
    goodput (deadline-met completions per second); measured ratios are
    ~2-4x because a wave holding one long request strands its finished
    slots.
  * **Chunked prefill protects TTFT.**  Short prompts co-arriving with
    one long prompt are served with one-shot prefill (the whole wave
    pays the long padded forward before anyone's first token) vs
    ``prefill_chunk=32`` (the long prefill streams chunk-by-chunk,
    shorts interleave).  Chunking must cut the shorts' TTFT p99 by
    >= 2x.
  * **Admission control loses nothing.**  Under SLO churn — infeasible
    deadlines typed-rejected at the door, a queued deadline expiring,
    priorities aging — every accepted request completes exactly once
    and token-for-token equal to a clean-engine oracle run (sampled,
    temperature 0.8): the counter-based sampling keys make streams
    invariant to gateway scheduling.

Writes ``BENCH_gateway.json`` via benchmarks.run; the trend metrics are
``goodput_x``/``ttft_speedup_x`` (ratio rows) plus raw ``goodput`` and
``ttft_p99_ms`` per mode.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks import common  # noqa: F401  (JAX_PLATFORMS pin)

PAGE = 16
POOL = 256
SEED = 5


def _model():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("smollm-135m").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _engine(cfg, params, *, max_batch=4, max_len=256, chunk=None,
            seed=SEED):
    from repro.core.services import MMUConfig
    from repro.core.services.mmu import MMU
    from repro.serve.engine import ServingEngine
    mmu = MMU(MMUConfig(page_size=PAGE, n_pages=POOL))
    return ServingEngine(cfg, params, mmu, max_batch=max_batch,
                         max_len=max_len, seed=seed, prefill_chunk=chunk)


def _warm(cfg, params, *, max_len=256, chunk=None, plen=33,
          waves=(4, 2, 1)) -> float:
    """Compile every (batch, suffix) prefill bucket and the decode shape
    the timed runs will hit, on a throwaway engine; returns the measured
    warm decode step time (the unit the SLO deadlines are scaled in, so
    the A/B saturates on any host)."""
    eng = _engine(cfg, params, max_len=max_len, chunk=chunk)
    rng = np.random.RandomState(0)
    for n in waves:
        for _ in range(n):
            eng.submit(rng.randint(0, cfg.vocab_size, size=plen).tolist(),
                       max_new_tokens=16, temperature=0.8, top_k=5)
        eng.run()
    return float(eng.ewma_decode_step_s)


# ------------------------------------------------- open-arrival driver ----
def _drive(gw, arrivals):
    """Closed-loop pump of a pre-drawn arrival trace: submit each
    request when its arrival offset passes, step the gateway otherwise.
    Typed rejections are recorded by the gateway itself."""
    from repro.core.port import PortError
    t0 = time.perf_counter()
    i = 0
    while i < len(arrivals) or gw.pending():
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            try:
                gw.submit(**arrivals[i][1])
            except PortError:
                pass
            i += 1
        if i < len(arrivals) and not gw.pending():
            time.sleep(max(0.0,
                           arrivals[i][0] - (time.perf_counter() - t0)))
            continue
        gw.step()
    gw.drain()


def _poisson_trace(cfg, step_s: float, n=32, seed=11):
    """Mixed short/long tiers with per-tier deadlines, Poisson gaps.

    Everything is scaled in measured decode-step units.  The load runs
    just under engine capacity (arrivals ~20 steps apart vs ~14 steps
    of work each), so continuous mode keeps its queue near-empty and a
    short request's completion latency is a few dozen steps — inside
    its 120-step SLO.  Wave mode admits only when the engine fully
    drains, so any wave holding a 192-step long request quantizes every
    queued arrival's wait by that long tail: the shorts blow their
    deadline while the lax long-tier SLO (1200 steps) is met either
    way.  Goodput — deadline-met completions per second — is what the
    gateway exists to maximize, and the A/B isolates the scheduling
    policy: same engine, same trace, same deadlines."""
    rng = np.random.RandomState(seed)
    t = 0.0
    arrivals = []
    for k in range(n):
        t += float(rng.exponential(20.0 * step_s))
        prompt = rng.randint(0, cfg.vocab_size, size=17).tolist()
        if k % 4 == 0:           # long tier: 192 decode steps, lax SLO
            spec = dict(prompt=prompt, max_new_tokens=192,
                        deadline_s=max(1200 * step_s, 1.0))
        else:                    # short tier: 8 steps, tight SLO
            spec = dict(prompt=prompt, max_new_tokens=8,
                        deadline_s=max(120 * step_s, 0.1))
        arrivals.append((t, spec))
    return arrivals


def _run_mode(cfg, params, mode: str, step_s: float) -> Dict[str, float]:
    from repro.serve.gateway import ServingGateway
    eng = _engine(cfg, params)
    gw = ServingGateway(eng, mode=mode, admission="fifo")
    _drive(gw, _poisson_trace(cfg, step_s))
    st = gw.stats()
    assert st["completed"] == st["submitted"], \
        f"{mode}: lost completions ({st['completed']}/{st['submitted']})"
    return st


# ------------------------------------------------- chunked TTFT A/B -------
def _short_ttfts(cfg, params, chunk) -> List[float]:
    """One long prompt co-arrives with six shorts; return the shorts'
    TTFTs (seconds from arrival)."""
    from repro.serve.gateway import ServingGateway
    rng = np.random.RandomState(23)
    # 8 slots: the long and all six shorts co-admit, so the A/B isolates
    # prefill scheduling (one-shot: every short's first token waits for
    # the 256-token padded forward; chunked: shorts prefill in their own
    # small batch while the long streams 32 tokens per step)
    eng = _engine(cfg, params, max_batch=8, max_len=384, chunk=chunk)
    gw = ServingGateway(eng, admission="fifo")
    gw.submit(rng.randint(0, cfg.vocab_size, size=256).tolist(),
              max_new_tokens=16)
    shorts = [gw.submit(rng.randint(0, cfg.vocab_size, size=15).tolist(),
                        max_new_tokens=8) for _ in range(6)]
    gw.drain()
    assert all(s.done for s in shorts)
    return [s.ttft() for s in shorts]


# --------------------------------------------- SLO churn + oracle parity --
def _slo_churn(cfg, params) -> Dict[str, float]:
    from repro.core.port import PortError
    from repro.serve.gateway import ServingGateway
    rng = np.random.RandomState(31)
    eng = _engine(cfg, params)
    gw = ServingGateway(eng, min_obs=1, aging_window_s=30.0)
    prompts = [rng.randint(0, cfg.vocab_size, size=33).tolist()
               for _ in range(10)]
    # warm the timing model through the gateway itself
    for p in prompts[:4]:
        gw.submit(p, max_new_tokens=8, temperature=0.8, top_k=5)
    gw.drain()
    est = gw._service_estimate(33, 8)
    assert est is not None
    # infeasible deadline: typed rejection at the door
    infeasible = 0
    try:
        gw.submit(prompts[4], max_new_tokens=8, deadline_s=0.2 * est)
    except PortError:
        infeasible = 1
    # feasible-but-doomed: passes the door, expires while we stall
    doom = gw.submit(prompts[5], max_new_tokens=8, temperature=0.8,
                     top_k=5, deadline_s=gw.headroom * est * 1.5 + 0.05)
    time.sleep(gw.headroom * est * 1.5 + 0.08)
    # survivors with deadlines inside the aging window, mixed priorities
    live = [gw.submit(p, max_new_tokens=8, temperature=0.8, top_k=5,
                      priority=k % 2, deadline_s=20.0)
            for k, p in enumerate(prompts[6:])]
    gw.drain()
    assert doom.rejected and doom.error.kind == "slo_expired", \
        "queued past-deadline request must expire typed"
    assert all(s.done for s in live)
    aged = max(s.eff_priority - s.priority for s in live)
    assert aged >= 1, "deadlined survivors must age inside the window"
    st = gw.stats()
    assert st["submitted"] == st["completed"] + st["expired"] \
        + st["rejected_infeasible"], "gateway accounting must balance"
    assert infeasible == 1 and st["rejected_infeasible"] == 1
    # oracle: a clean engine fed the dispatched prompts in rid order
    # must reproduce every sampled stream token for token
    done = sorted(gw.completed, key=lambda s: s.rid)
    gid2prompt = {}
    for k, p in enumerate(prompts[:4]):
        gid2prompt[k] = (p, 8)
    gid2prompt[doom.gid] = (prompts[5], 8)
    for k, s in enumerate(live):
        gid2prompt[s.gid] = (prompts[6 + k], 8)
    oracle = _engine(cfg, params)
    for s in done:
        p, mnt = gid2prompt[s.gid]
        oracle.submit(p, max_new_tokens=mnt, temperature=0.8, top_k=5)
    oracle.run()
    ref = {r.rid: r.out_tokens for r in oracle.completed}
    for s in done:
        assert s.tokens == ref[s.rid], \
            f"gateway stream rid={s.rid} diverged from the oracle"
    return {"completed": st["completed"], "expired": st["expired"],
            "rejected_infeasible": st["rejected_infeasible"],
            "aged_boost_max": aged, "oracle_parity": 1.0}


def run() -> List[Dict]:
    cfg, params = _model()
    rows: List[Dict] = []

    # -- continuous vs wave goodput under the same Poisson trace --------
    step_s = _warm(cfg, params, plen=17)
    cont = _run_mode(cfg, params, "continuous", step_s)
    wave = _run_mode(cfg, params, "wave", step_s)
    goodput_x = cont["goodput"] / max(wave["goodput"], 1e-9)
    assert goodput_x >= 1.3, \
        f"continuous batching must buy >=1.3x goodput (got {goodput_x:.2f}x)"
    for mode, st in (("continuous", cont), ("wave", wave)):
        rows.append({"config": f"open_poisson_{mode}",
                     "goodput": round(st["goodput"], 3),
                     "throughput": round(st["throughput"], 3),
                     "met_deadline": int(st["met_deadline"]),
                     "completed": int(st["completed"]),
                     "ttft_p50_ms": round(st["ttft_p50_ms"], 1),
                     "ttft_p99_ms": round(st["ttft_p99_ms"], 1),
                     "tpot_p50_ms": round(st["tpot_p50_ms"], 1),
                     "tpot_p99_ms": round(st["tpot_p99_ms"], 1)})
    rows.append({"config": "continuous_vs_wave",
                 "goodput_x": round(goodput_x, 2)})

    # -- chunked prefill vs one-shot: co-arriving shorts' TTFT ----------
    for chunk in (None, 32):     # warm both variants' shapes untimed
        _short_ttfts(cfg, params, chunk)
    oneshot = _short_ttfts(cfg, params, None)
    chunked = _short_ttfts(cfg, params, 32)
    p99_1 = float(np.percentile(oneshot, 99))
    p99_c = float(np.percentile(chunked, 99))
    ttft_x = p99_1 / max(p99_c, 1e-9)
    assert ttft_x >= 2.0, \
        f"chunked prefill must cut short-TTFT p99 >=2x (got {ttft_x:.2f}x)"
    rows.append({"config": "oneshot_short_ttft",
                 "ttft_p99_ms": round(p99_1 * 1e3, 1),
                 "ttft_p50_ms": round(
                     float(np.percentile(oneshot, 50)) * 1e3, 1)})
    rows.append({"config": "chunked_short_ttft",
                 "ttft_p99_ms": round(p99_c * 1e3, 1),
                 "ttft_p50_ms": round(
                     float(np.percentile(chunked, 50)) * 1e3, 1)})
    rows.append({"config": "chunked_vs_oneshot",
                 "ttft_speedup_x": round(ttft_x, 2)})

    # -- SLO churn: typed rejections, aging, exactly-once, oracle -------
    rows.append({"config": "slo_churn", **_slo_churn(cfg, params)})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), "serving gateway: goodput, TTFT SLOs, admission control")
