"""JAX version-drift shims, centralized.

Two drifts bite this repo on older/newer JAX installs:

  * ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist on newer JAX.  :func:`make_mesh` passes
    ``axis_types`` through when the install supports it and silently omits
    it otherwise — Auto is the default axis type anyway, so behaviour is
    identical where it matters.
  * ``Compiled.cost_analysis()`` returns a dict on some versions and a
    one-element *list* of dicts on others.  :func:`normalize_cost_analysis`
    flattens both shapes to a plain dict so call sites can ``.get()``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax

try:  # newer JAX
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPES = True
except ImportError:  # older JAX: every mesh axis is implicitly Auto
    AxisType = None
    HAS_AXIS_TYPES = False


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None (omit kwarg)."""
    if not HAS_AXIS_TYPES:
        return None
    return (AxisType.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None):
    """``jax.make_mesh`` that tolerates installs without ``axis_types``."""
    kw: Dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    if HAS_AXIS_TYPES and axis_types is not None:
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def normalize_cost_analysis(ca: Any) -> Dict[str, float]:
    """Flatten ``Compiled.cost_analysis()`` output to one dict.

    Handles: dict (new), [dict] per-device list (old), None/[] (no data).
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        for item in ca:
            if isinstance(item, dict):
                return item
        return {}
    if isinstance(ca, dict):
        return ca
    return {}
