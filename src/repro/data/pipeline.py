"""Data pipeline: deterministic synthetic corpus + prefetching loader.

Production-shaped: document sampling -> packing into fixed-length rows ->
sharded host batches -> background prefetch thread overlapping host->device
transfer with compute, plus straggler simulation/mitigation hooks used by
the trainer (skip-batch dispatch when a host is slow).

Determinism contract: batch(step) is a pure function of (seed, step) — a
restart resumes bit-identically, which the checkpoint tests rely on.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

import jax


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    mean_doc_len: int = 512        # documents are packed into rows
    bos_id: int = 1
    eos_id: int = 2
    with_frames: bool = False      # audio stub (whisper): emit frames too
    frame_len: int = 0
    d_model: int = 0


class SyntheticCorpus:
    """Zipf-ish random documents, packed: batch(step) is pure in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.RandomState((c.seed * 1_000_003 + step) % (2**31))
        rows = np.empty((c.global_batch, c.seq_len), np.int32)
        for i in range(c.global_batch):
            toks = []
            while len(toks) < c.seq_len:
                dlen = max(int(rng.exponential(c.mean_doc_len)), 8)
                doc = rng.zipf(1.3, size=dlen) % (c.vocab_size - 3) + 3
                toks.extend([c.bos_id, *doc.tolist(), c.eos_id])
            rows[i] = np.asarray(toks[:c.seq_len], np.int32)
        out = {"tokens": rows}
        if c.with_frames:
            out["frames"] = rng.randn(
                c.global_batch, c.frame_len, c.d_model).astype(np.float32)
        return out


class Prefetcher:
    """Background thread staging batch(step+1..step+depth) onto device.

    ``straggler_sim`` optionally injects host delays; ``get`` takes a
    timeout so the trainer can *skip* a straggling batch (the data-dispatch
    mitigation: training proceeds with the next ready batch, the skipped
    step id is logged for exactly-once accounting)."""

    def __init__(self, corpus: SyntheticCorpus, *, depth: int = 2,
                 device_put: Optional[Callable[[Any], Any]] = None,
                 straggler_sim: Optional[Callable[[int], float]] = None,
                 start_step: int = 0):
        self.corpus = corpus
        self.depth = depth
        self.device_put = device_put or jax.device_put
        self.straggler_sim = straggler_sim
        self._q: "queue.Queue[tuple[int, Any]]" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self.skipped: list[int] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            step = self._next
            self._next += 1
            if self.straggler_sim is not None:
                delay = self.straggler_sim(step)
                if delay > 0:
                    time.sleep(delay)
            host = self.corpus.batch(step)
            dev = self.device_put(host)
            while not self._stop.is_set():
                try:
                    self._q.put((step, dev), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, timeout: Optional[float] = None):
        """Next ready (step, batch); None on timeout (caller may skip)."""
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
