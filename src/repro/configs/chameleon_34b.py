"""chameleon-34b — early-fusion VLM; VQ image tokens share the text vocab.
[arXiv:2405.09818; unverified]
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536

The modality frontend (VQ-GAN image tokenizer) is a STUB per assignment:
`input_specs()` provides precomputed token ids (image tokens are ordinary
vocab entries in early-fusion models, so the backbone is a standard LM).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    rope_theta=10_000.0,
    frontend="vq_tokens",
    source="arXiv:2405.09818",
)
