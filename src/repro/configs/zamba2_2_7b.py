"""zamba2-2.7b — hybrid: Mamba2 blocks + shared attention block every 6th.
[arXiv:2411.15242; hf]
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,          # MHA in the shared attention block
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    # 5 mamba blocks then one shared attention(+MLP) block, cycled (54 = 9*6)
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    source="arXiv:2411.15242",
)
