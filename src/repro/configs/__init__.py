"""Architecture config registry: ``get_config("qwen2-72b")`` etc."""
from __future__ import annotations

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K, MoEConfig,
                                ModelConfig, PREFILL_32K, SHAPES_BY_NAME,
                                ShapeConfig, SSMConfig, TRAIN_4K,
                                shape_applicable)

from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.mamba2_1_3b import CONFIG as _mamba2

ARCHS = {
    c.arch_id: c
    for c in (_smollm, _danube, _qwen2, _phi3, _chameleon, _whisper,
              _granite, _llama4, _zamba2, _mamba2)
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def all_cells():
    """Yield every (arch, shape, applicable, why) assignment cell."""
    for arch_id in sorted(ARCHS):
        cfg = ARCHS[arch_id]
        for shape in ALL_SHAPES:
            ok, why = shape_applicable(cfg, shape)
            yield cfg, shape, ok, why


__all__ = [
    "ARCHS", "ALL_SHAPES", "ModelConfig", "MoEConfig", "SSMConfig",
    "ShapeConfig", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_config", "get_shape", "all_cells", "shape_applicable",
]
