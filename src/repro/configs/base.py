"""Configuration system for Coyote-JAX.

Every assigned architecture is described by a `ModelConfig`; every assigned
input shape by a `ShapeConfig`.  Configs are plain frozen dataclasses so they
hash cleanly into the shell's compile cache (the "routed & locked checkpoint"
analogue from the paper).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # layers that are MoE (None -> all); e.g. llama4 interleaves dense/MoE
    moe_layer_period: int = 1  # every k-th layer is MoE
    n_shared_experts: int = 0
    # Switch-style capacity factor; reduced() raises it so tiny smoke
    # batches never drop tokens (decode must match forward exactly)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (exact values from the assignment table)."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int          # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0     # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    swa_window: int = 0   # 0 -> full attention; >0 -> sliding window
    norm_eps: float = 1e-5
    act: str = "silu"     # silu (SwiGLU) | gelu (plain MLP, used by whisper)
    pos_embed: str = "rope"  # rope | absolute (whisper)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer pattern for hybrids: tuple of block kinds cycled over layers
    # e.g. zamba2: 5x mamba + 1 shared attention block
    block_pattern: Tuple[str, ...] = ("attn",)
    # encoder-decoder (whisper): encoder layer count; 0 -> decoder-only
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0  # fixed encoder frames (whisper: 1500)
    # modality frontend stub: "none" | "audio_frames" | "vq_tokens"
    frontend: str = "none"
    dtype: str = "bfloat16"
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (MXU lane alignment)."""
        return _round_up(self.vocab_size, 128)

    @property
    def is_attention_free(self) -> bool:
        return all(k == "mamba" for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)/O(window) in sequence length."""
        return self.ssm is not None or (self.swa_window > 0)

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    def n_params(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        kinds = self.layer_kinds()
        for k in kinds:
            if k in ("attn", "shared_attn"):
                n += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                n += (self.n_heads * hd) * d
                if self.qkv_bias:
                    n += (self.n_heads + 2 * self.n_kv_heads) * hd
                n += 2 * d  # norms
            if k == "mamba":
                assert self.ssm is not None
                di = self.ssm.d_inner(d)
                nh = self.ssm.n_heads(d)
                n += d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
                n += di * self.ssm.d_conv + di * d + 2 * nh + d
            # ffn
            if k != "mamba":
                if self.moe is not None and (kinds.index(k) % self.moe.moe_layer_period == 0):
                    pass  # handled below per-layer
                else:
                    pass
        # FFN counted per layer explicitly:
        for i, k in enumerate(kinds):
            if k == "mamba":
                continue
            if self.moe is not None and (i % self.moe.moe_layer_period == 0):
                e = self.moe
                n += e.n_experts * 3 * d * e.d_ff_expert + d * e.n_experts
                n += e.n_shared_experts * 3 * d * e.d_ff_expert
            else:
                mult = 3 if self.act == "silu" else 2
                n += mult * d * self.d_ff
        n += d  # final norm
        if self.n_encoder_layers:
            # encoder layers: attn + ffn
            per = d * (self.n_heads * hd) * 2 + 2 * d * (self.n_kv_heads * hd)
            per += (3 if self.act == "silu" else 2) * d * self.d_ff + 2 * d
            n += self.n_encoder_layers * per
            # decoder cross-attention blocks
            n += self.n_layers * (2 * d * (self.n_heads * hd) +
                                  2 * d * (self.n_kv_heads * hd) + d)
        return n

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        total = self.n_params()
        kinds = self.layer_kinds()
        inactive = 0
        for i, k in enumerate(kinds):
            if k == "mamba":
                continue
            if i % e.moe_layer_period == 0:
                inactive += (e.n_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return total - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * len(self.block_pattern)),
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.n_heads else 0,
            swa_window=min(self.swa_window, 64) if self.swa_window else 0,
            encoder_seq_len=min(self.encoder_seq_len, 32) if self.encoder_seq_len else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                moe_layer_period=self.moe.moe_layer_period,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                capacity_factor=8.0)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                                  n_groups=1, chunk_size=32)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """Assigned input shape.  kind: train | prefill | decode."""
    name: str
    kind: str
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, per assignment rules."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{model.arch_id} is full-attention (see DESIGN.md §5)")
    return True, ""
