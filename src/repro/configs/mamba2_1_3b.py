"""mamba2-1.3b — attention-free SSD (state-space duality) LM.
[arXiv:2405.21060; unverified]
48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,                 # no FFN: mamba blocks only
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    block_pattern=("mamba",),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
