"""whisper-medium — encoder-decoder speech model; conv frontend stubbed.
[arXiv:2212.04356; unverified]
24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865

Backbone only: the conv1d/log-mel frontend is a STUB — `input_specs()`
provides precomputed frame embeddings of shape (batch, enc_seq, d_model).
Decoder nominal context is 448 tokens; the assigned 32k decode cells lower
structurally (noted in DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # whisper uses MHA (kv == q heads)
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    pos_embed="absolute",
    encoder_seq_len=1500,
    frontend="audio_frames",
    source="arXiv:2212.04356",
)
