"""Pallas TPU flash-attention backward kernels.

Standard two-kernel decomposition with the forward's logsumexp residual:

  * ``_dq_kernel``  — grid (b, h, q_blocks, k_blocks), k sequential:
                      dq += (p ∘ (dp − D)) @ k · scale, dq in VMEM scratch;
  * ``_dkv_kernel`` — grid (b, kv_head, k_blocks, q_blocks), q sequential:
                      dk += (pᵀ ∘ (dp − D)ᵀ) @ q · scale, dv += pᵀ @ do,
                      GQA accumulated by looping the group's q heads in-block;

where p = exp(q kᵀ·scale − lse) and D = rowsum(do ∘ o) (computed inline).
The forward (``flash_attention.py``) is extended to emit lse.  All
accumulation fp32.  ``ops.mha_vjp`` wires fwd+bwd into a jax.custom_vjp;
tests sweep against jax.grad of the jnp oracle.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _mask(s, q_start, k_start, block_q, block_k, seq_len, causal, window):
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
    m = kpos < seq_len
    if causal:
        m = jnp.logical_and(m, kpos <= qpos)
    if window > 0:
        m = jnp.logical_and(m, kpos > qpos - window)
    return jnp.where(m, s, NEG_INF)


# ================================================================== dq =====
def _dq_kernel(q_ref, k_ref, v_ref, o_ref, do_ref, lse_ref, dq_ref,
               acc, *, sm_scale, causal, window, block_q, block_k, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        o = o_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)          # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = _mask(s * sm_scale, q_start, k_start, block_q, block_k,
                  seq_len, causal, window)
        p = jnp.exp(s - lse[:, None])                    # (bq, bk)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        dcap = jnp.sum(do * o, axis=1, keepdims=True)    # D (bq,1)
        ds = p * (dp - dcap)
        acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale

    conds = []
    if causal:
        conds.append(k_start <= q_start + block_q - 1)
    if window > 0:
        conds.append(k_start + block_k - 1 > q_start - window)
    if conds:
        pl.when(functools.reduce(jnp.logical_and, conds))(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _done():
        dq_ref[0, 0] = acc[...].astype(dq_ref.dtype)


# ================================================================= dkv =====
def _dkv_kernel(q_ref, k_ref, v_ref, o_ref, do_ref, lse_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                sm_scale, causal, window, block_q, block_k, seq_len,
                group):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        for gi in range(group):                          # q heads of group
            q = q_ref[0, 0, gi].astype(jnp.float32)      # (bq, d)
            o = o_ref[0, 0, gi].astype(jnp.float32)
            do = do_ref[0, 0, gi].astype(jnp.float32)
            lse = lse_ref[0, 0, gi].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = _mask(s * sm_scale, q_start, k_start, block_q, block_k,
                      seq_len, causal, window)
            p = jnp.exp(s - lse[:, None])                # (bq, bk)
            dv_acc[...] += jax.lax.dot_general(
                p, do, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)      # (bk, d)
            dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            dcap = jnp.sum(do * o, axis=1, keepdims=True)
            ds = p * (dp - dcap)                         # (bq, bk)
            dk_acc[...] += jax.lax.dot_general(
                ds, q, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) * sm_scale

    conds = []
    if causal:
        conds.append(k_start <= q_start + block_q - 1)
    if window > 0:
        conds.append(k_start + block_k - 1 > q_start - window)
    if conds:
        pl.when(functools.reduce(jnp.logical_and, conds))(_body)
    else:
        _body()

    @pl.when(qi == nq - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


# ============================================================== wrappers ====
def _pad_seq(x, block, axis=2):
    pad = (-x.shape[axis]) % block
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    return x


def flash_attention_bwd(q, k, v, o, do, lse, *, causal=True, window=0,
                        sm_scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q/o/do (B,H,Sq,D); k/v (B,K,Sk,D); lse (B,H,Sq) -> (dq, dk, dv)."""
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))

    q_, o_, do_ = (_pad_seq(x, block_q) for x in (q, o, do))
    lse_ = _pad_seq(lse[..., None], block_q)[..., 0] + 0.0
    k_, v_ = (_pad_seq(x, block_k) for x in (k, v))
    nq = q_.shape[2] // block_q
    nk = k_.shape[2] // block_k

    scr = ([pltpu.VMEM((block_q, d), jnp.float32)] if pltpu else [])
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, sm_scale=sm_scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          seq_len=sk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q_.shape, q.dtype),
        scratch_shapes=scr,
        interpret=interpret,
    )(q_, k_, v_, o_, do_, lse_)[:, :, :sq]

    # q-side tensors grouped per kv head for the dkv kernel
    qg = q_.reshape(b, kh, group, q_.shape[2], d)
    og = o_.reshape(b, kh, group, q_.shape[2], d)
    dog = do_.reshape(b, kh, group, q_.shape[2], d)
    lseg = lse_.reshape(b, kh, group, q_.shape[2])

    scr2 = ([pltpu.VMEM((block_k, d), jnp.float32),
             pltpu.VMEM((block_k, d), jnp.float32)] if pltpu else [])
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, sm_scale=sm_scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          seq_len=sk, group=group),
        grid=(b, kh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, group, block_q, d),
                         lambda bi, hi, ki, qi: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, group, block_q, d),
                         lambda bi, hi, ki, qi: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, group, block_q, d),
                         lambda bi, hi, ki, qi: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, group, block_q),
                         lambda bi, hi, ki, qi: (bi, hi, 0, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(k_.shape, k.dtype),
                   jax.ShapeDtypeStruct(v_.shape, v.dtype)],
        scratch_shapes=scr2,
        interpret=interpret,
    )(qg, k_, v_, og, dog, lseg)
    return dq, dk[:, :, :sk], dv[:, :, :sk]
