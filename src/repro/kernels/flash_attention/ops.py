"""Jitted public wrapper for flash attention.

``mha(...)`` takes the model-layout tensors (B, S, H, D) and dispatches to
the Pallas kernel (TPU) or the jnp oracle (CPU / debugging).  On this
container the kernel runs under interpret=True for validation; real
deployments flip ``use_pallas`` on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_pallas", "interpret",
                                             "block_q", "block_k"))
def mha(q, k, v, *, causal: bool = True, window: int = 0,
        use_pallas: bool = False, interpret: bool = True,
        block_q: int = 128, block_k: int = 128):
    """q (B, Sq, H, D); k, v (B, Sk, K, D) -> (B, Sq, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        ot = flash_attention(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    else:
        ot = attention_ref(qt, kt, vt, causal=causal, window=window)
    return ot.transpose(0, 2, 1, 3)


# ------------------------------------------------------------- custom vjp --
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def mha_fused(q, k, v, causal: bool = True, window: int = 0,
              interpret: bool = True):
    """Differentiable fused attention: Pallas fwd + Pallas bwd kernels.

    Layout (B, H, S, D).  Use inside training code on TPU; interpret mode
    validates on CPU (tests/test_kernels.py)."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)


def _mha_fwd(q, k, v, causal, window, interpret):
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             interpret=interpret, return_lse=True)
    return o, (q, k, v, o, lse)


def _mha_bwd(causal, window, interpret, res, do):
    from repro.kernels.flash_attention.flash_attention_bwd import (
        flash_attention_bwd)
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, do, lse, causal=causal,
                                     window=window, interpret=interpret)
    return dq, dk, dv


mha_fused.defvjp(_mha_fwd, _mha_bwd)
