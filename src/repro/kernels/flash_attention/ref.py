"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  sm_scale: Optional[float] = None):
    """q (B, H, Sq, D); k, v (B, K, Sk, D) -> (B, H, Sq, D).  Exact softmax
    attention with GQA + optional causal/sliding-window masking."""
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, kh, group, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * sm_scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, sq, d).astype(q.dtype)
