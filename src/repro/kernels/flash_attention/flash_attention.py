"""Pallas TPU flash-attention forward kernel (training/prefill hot spot).

TPU adaptation of the blockwise-softmax algorithm:

  * grid = (batch, q_heads, q_blocks, k_blocks); the k axis is innermost and
    sequential ("arbitrary"), so the m/l/acc scratch carries across k blocks
    in VMEM — scores never round-trip to HBM;
  * BlockSpecs tile q/o as (block_q, head_dim) and k/v as (block_k,
    head_dim): head_dim is MXU-lane aligned (128) and the default 128/128
    tiles keep q+k+v+acc well under the ~16 MB v5e VMEM budget;
  * GQA happens in the index_map (kv head = q head // group) — repeated KV
    is never materialized;
  * causal / sliding-window tiles that are fully masked exit via pl.when
    without touching the MXU.

Accumulation is fp32 regardless of input dtype.  Oracle: ``ref.py``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU backend bits (absent on some CPU-only installs)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
               m_scratch, l_scratch, acc_scratch, *,
               sm_scale: float, causal: bool, window: int,
               block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scratch[...] = alpha * l_scratch[...] + jnp.sum(
            p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scratch[...] = acc_scratch[...] * alpha + pv
        m_scratch[...] = m_new

    # block-level short-outs: skip fully-masked tiles entirely
    conds = []
    if causal:
        conds.append(k_start <= q_start + block_q - 1)
    if window > 0:
        conds.append(k_start + block_k - 1 > q_start - window)
    if conds:
        run = functools.reduce(jnp.logical_and, conds)
        pl.when(run)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _done():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0, 0] = (m_scratch[..., 0]
                             + jnp.log(l[..., 0])).astype(lse_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    sm_scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    return_lse: bool = False):
    """q (B, H, Sq, D); k, v (B, K, Sk, D) -> (B, H, Sq, D).

    H must be a multiple of K (GQA).  Sequence dims are padded to block
    multiples internally (masked out of the softmax)."""
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    assert h % kh == 0, f"GQA requires H % K == 0, got {h} % {kh}"
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    if not return_lse:
        def kernel_nolse(q_ref, k_ref, v_ref, o_ref, m_s, l_s, a_s):
            _fa_kernel(q_ref, k_ref, v_ref, o_ref, None, m_s, l_s, a_s,
                       sm_scale=sm_scale, causal=causal, window=window,
                       block_q=block_q, block_k=block_k, seq_len=sk)
        kernel = kernel_nolse
        out_specs = pl.BlockSpec((1, 1, block_q, d),
                                 lambda bi, hi, qi, ki: (bi, hi, qi, 0))
        out_shape = jax.ShapeDtypeStruct((b, h, q.shape[2], d), q.dtype)
    else:
        kernel = functools.partial(
            _fa_kernel, sm_scale=sm_scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, seq_len=sk)
        out_specs = [
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda bi, hi, qi, ki: (bi, hi, qi)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, h, q.shape[2], d), q.dtype),
            jax.ShapeDtypeStruct((b, h, q.shape[2]), jnp.float32),
        ]

    if pltpu is not None:
        scratch = [pltpu.VMEM((block_q, 1), jnp.float32),
                   pltpu.VMEM((block_q, 1), jnp.float32),
                   pltpu.VMEM((block_q, d), jnp.float32)]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY((block_q, 1), jnp.float32)] * 2

    res = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    if return_lse:
        out, lse = res
        if pq:
            out, lse = out[:, :, :sq], lse[:, :, :sq]
        return out, lse
    out = res
    if pq:
        out = out[:, :, :sq]
    return out
