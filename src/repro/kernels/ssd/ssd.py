"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

The SSD recurrence (state-space duality, arXiv:2405.21060) splits the
sequence into chunks: within a chunk the output is an attention-like
(L x L)-masked matmul (MXU work); across chunks a tiny (head_dim x d_state)
state carries the recurrence.  TPU mapping:

  * grid = (batch, heads, n_chunks); the chunk axis is sequential, the
    (P x N) fp32 state lives in VMEM scratch between chunk steps — the
    recurrence never round-trips HBM;
  * each chunk step runs three MXU matmuls: C·Bᵀ (L x L scores), scores·x
    (diagonal term), Cₛ·state (off-diagonal term) and one xᵀ·B state update;
  * chunk length defaults to 256 and L, N, P are 128-multiples-friendly.

Inputs are pre-activation (dt already softplus'ed, A negative).  Grouped
B/C (G < H) is resolved in the index_map like GQA.  Oracle: ``ref.py``
(also the pure-jnp path used by the model).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref,
                state, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (L,)
    a = a_ref[0]                                    # scalar A_h (negative)
    bm = b_ref[0, :, 0].astype(jnp.float32)         # (L, N)
    cm = c_ref[0, :, 0].astype(jnp.float32)         # (L, N)

    adt = dt * a                                    # (L,)
    cum = jnp.cumsum(adt)                           # (L,)
    seg = cum[-1]

    # ---- intra-chunk (diagonal) term --------------------------------------
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L,L)
    li = cum[:, None]
    lj = cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(mask, jnp.exp(li - lj), 0.0) * dt[None, :]
    w = scores * decay                              # (L, L)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (L,P)

    # ---- inter-chunk (off-diagonal) term -----------------------------------
    c_scaled = cm * jnp.exp(cum)[:, None]           # (L, N)
    y = y + jax.lax.dot_general(c_scaled, state[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (L,P)
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # ---- state update -------------------------------------------------------
    dstate = jnp.exp(seg - cum) * dt                # (L,)
    xw = x * dstate[:, None]                        # (L, P)
    upd = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (P,N)
    state[...] = jnp.exp(seg) * state[...] + upd

    @pl.when(ci == nc - 1)
    def _done():
        st_out_ref[0, 0] = state[...]


def ssd_chunked_pallas(x, dt, A, Bm, C, *, chunk: int = 256,
                       interpret: bool = False
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,); Bm/C (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32).  S is padded to a
    chunk multiple (dt=0 padding is exact: zero dt means identity decay and
    zero input contribution)."""
    b, s_len, h, pd = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-s_len) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    scratch = [pltpu.VMEM((pd, n), jnp.float32)] if pltpu is not None else []

    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, pd),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, pd),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, pd, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s_pad, h, pd), x.dtype),
            jax.ShapeDtypeStruct((b, h, pd, n), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, dt, A, Bm, C)
    return y[:, :s_len], st
