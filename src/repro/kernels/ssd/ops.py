"""Jitted public wrapper for the SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.ref import ssd_chunked, ssd_sequential
from repro.kernels.ssd.ssd import ssd_chunked_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd(x, dt, A, Bm, C, *, chunk: int = 256, use_pallas: bool = False,
        interpret: bool = True):
    """Dispatch: Pallas kernel (TPU target) or chunked-jnp reference."""
    if use_pallas:
        return ssd_chunked_pallas(x, dt, A, Bm, C, chunk=chunk,
                                  interpret=interpret)
    return ssd_chunked(x, dt, A, Bm, C, chunk=chunk)
