"""Oracles for the SSD kernel.

Two references: the chunked pure-jnp implementation the model uses
(``repro.models.ssm.ssd_chunked``) and a fully sequential O(S) recurrence
(``ssd_sequential``) that is trivially correct — the chunked path and the
Pallas kernel must both match it."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked  # re-export: the model-path oracle

__all__ = ["ssd_chunked", "ssd_sequential"]


def ssd_sequential(x, dt, A, Bm, C):
    """Token-by-token recurrence.  x (B,S,H,P); dt (B,S,H); A (H,);
    Bm/C (B,S,G,N).  Returns (y, final_state (B,H,P,N))."""
    b, s_len, h, pd = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P) (B,H) (B,G,N)*2
        bh = jnp.repeat(bt, rep, axis=1).astype(jnp.float32)
        ch = jnp.repeat(ct, rep, axis=1).astype(jnp.float32)
        da = jnp.exp(dtt * A[None, :])              # (B,H)
        upd = (dtt[..., None, None] * bh[:, :, None, :]
               * xt.astype(jnp.float32)[..., None])
        state = da[..., None, None] * state + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ch)
        return state, y

    init = jnp.zeros((b, h, pd, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
