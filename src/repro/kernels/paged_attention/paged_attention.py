"""Pallas TPU paged-attention decode kernel — the MMU service's datapath.

This is the paper-technique kernel: decode attention that reads KV through
the MMU's page tables (the "TLB lookup" in hardware).  TPU adaptation:

  * KV lives in a paged pool ``(n_pages, page_size, kv_heads, head_dim)``
    (HBM); sequences own scattered page lists;
  * the grid is (batch, kv_heads, max_pages); the page axis is sequential,
    carrying the online-softmax state (m/l/acc) in VMEM scratch;
  * the block table arrives via ``PrefetchScalarGridSpec`` — it is consumed
    by the *index_map*, so the page fetch address is computed from SMEM
    before the DMA issues: that is precisely a hardware TLB walk,
    reshaped for the MXU;
  * GQA: all ``group = H // KV`` query heads of one kv head are processed
    together as the (group, head_dim) q tile — KV is fetched once per page
    regardless of group size;
  * out-of-range pages (beyond seq_len) are masked, and invalid table
    entries (-1, e.g. host-swapped pages) index page 0 but stay masked.

Oracle: ``ref.py``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _pa_kernel(tables_ref, lens_ref,           # scalar prefetch (SMEM)
               q_ref, k_ref, v_ref, o_ref,
               m_scratch, l_scratch, acc_scratch, *,
               page_size: int, sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    seq_len = lens_ref[b]
    valid_page = (pi * page_size < seq_len) & (tables_ref[b, pi] >= 0)

    @pl.when(valid_page)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # (group, d)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (group, page)
        pos = pi * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)

        m_prev = m_scratch[...]                          # (group, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scratch[...] = alpha * l_scratch[...] + jnp.sum(
            p, axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (page, d)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new

    @pl.when(pi == np_ - 1)
    def _done():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    sm_scale: Optional[float] = None,
                    interpret: bool = False):
    """Decode attention through page tables.

    q            (B, H, D)         — one new token per sequence
    k/v_pages    (P, page, K, D)   — the MMU's device page pool
    block_tables (B, max_pages)    int32 physical page ids (-1 = unmapped)
    seq_lens     (B,)              int32 valid tokens per sequence
    -> (B, H, D)
    """
    b, h, d = q.shape
    n_pages, page_size, kh, _ = k_pages.shape
    group = h // kh
    max_pages = block_tables.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    # (B, K, group, D) query tile per (batch, kv head)
    qg = q.reshape(b, kh, group, d)

    kernel = functools.partial(_pa_kernel, page_size=page_size,
                               sm_scale=sm_scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda bi, ki, pi, tables, lens: (bi, ki, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, ki, pi, tables, lens:
                         (jnp.maximum(tables[bi, pi], 0), 0, ki, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, ki, pi, tables, lens:
                         (jnp.maximum(tables[bi, pi], 0), 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda bi, ki, pi, tables, lens:
                               (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
