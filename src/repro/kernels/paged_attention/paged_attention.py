"""Pallas TPU paged-attention decode kernel — the MMU service's datapath.

This is the paper-technique kernel: decode attention that reads KV through
the MMU's page tables (the "TLB lookup" in hardware).  TPU adaptation:

  * KV lives in a paged pool ``(n_pages, page_size, kv_heads, head_dim)``
    (HBM); sequences own scattered page lists;
  * the grid is (batch, kv_heads, page_groups); the group axis is
    sequential, carrying the online-softmax state (m/l/acc) in VMEM
    scratch;
  * the block table arrives via ``PrefetchScalarGridSpec`` — it is consumed
    by the *index_map*, so the page fetch address is computed from SMEM
    before the DMA issues: that is precisely a hardware TLB walk,
    reshaped for the MXU;
  * ``pages_per_block`` pages are fetched per grid step (one BlockSpec per
    page in the group, since pages are scattered in the pool) and
    concatenated into a single (pages_per_block * page_size, d) KV tile,
    so small page sizes stop starving the MXU with tiny matmuls;
  * GQA: all ``group = H // KV`` query heads of one kv head are processed
    together as the (group, head_dim) q tile — KV is fetched once per page
    regardless of group size;
  * out-of-range pages (beyond seq_len) are masked, and invalid table
    entries (-1, e.g. host-swapped pages or empty batch slots) index
    page 0 but stay masked; a page group that is entirely masked
    contributes nothing (the online-softmax update is where-guarded).

Oracle: ``ref.py``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _pa_kernel(tables_ref, lens_ref,           # scalar prefetch (SMEM)
               q_ref, *refs, page_size: int, sm_scale: float,
               pages_per_block: int):
    ppb = pages_per_block
    k_refs = refs[:ppb]
    v_refs = refs[ppb:2 * ppb]
    o_ref = refs[2 * ppb]
    m_scratch, l_scratch, acc_scratch = refs[2 * ppb + 1:]

    b = pl.program_id(0)
    gi = pl.program_id(2)
    ng = pl.num_programs(2)

    @pl.when(gi == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    seq_len = lens_ref[b]
    start = gi * ppb * page_size

    @pl.when(start < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # (group, d)
        k = jnp.concatenate(
            [k_refs[j][0, :, 0] for j in range(ppb)],
            axis=0).astype(jnp.float32)                  # (ppb*page, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (group, ppb*pg)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        page_ok = jnp.concatenate(
            [jnp.broadcast_to(tables_ref[b, gi * ppb + j] >= 0,
                              (page_size,)) for j in range(ppb)], axis=0)
        s = jnp.where((pos < seq_len) & page_ok[None, :], s, NEG_INF)

        m_prev = m_scratch[...]                          # (group, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # a fully-masked group leaves m_new at NEG_INF: exp(s - m_new)
        # would be exp(0)=1 there, so zero the weights explicitly.
        p = jnp.where(m_new > NEG_INF / 2, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scratch[...] = alpha * l_scratch[...] + jnp.sum(
            p, axis=1, keepdims=True)
        v = jnp.concatenate(
            [v_refs[j][0, :, 0] for j in range(ppb)],
            axis=0).astype(jnp.float32)                  # (ppb*page, d)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new

    @pl.when(gi == ng - 1)
    def _done():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def default_pages_per_block(page_size: int, max_pages: int,
                            target: int = 128) -> int:
    """Enough pages per grid step for a ~``target``-row KV tile."""
    return max(1, min(max_pages, -(-target // page_size)))


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    sm_scale: Optional[float] = None,
                    pages_per_block: Optional[int] = None,
                    interpret: bool = False):
    """Decode attention through page tables.

    q            (B, H, D)         — one new token per sequence
    k/v_pages    (P, page, K, D)   — the MMU's device page pool
    block_tables (B, max_pages)    int32 physical page ids (-1 = unmapped)
    seq_lens     (B,)              int32 valid tokens per sequence
    pages_per_block                pages fetched/processed per grid step
                                   (None = auto-size toward a 128-row tile)
    -> (B, H, D)
    """
    b, h, d = q.shape
    n_pages, page_size, kh, _ = k_pages.shape
    group = h // kh
    max_pages = block_tables.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if pages_per_block is None:
        pages_per_block = default_pages_per_block(page_size, max_pages)
    ppb = max(1, min(int(pages_per_block), max_pages))
    ng = -(-max_pages // ppb)
    if ng * ppb != max_pages:                # pad width to a group multiple
        pad = ng * ppb - max_pages
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad)),
                               constant_values=-1)

    # (B, K, group, D) query tile per (batch, kv head)
    qg = q.reshape(b, kh, group, d)

    kernel = functools.partial(_pa_kernel, page_size=page_size,
                               sm_scale=sm_scale, pages_per_block=ppb)

    def _page_spec(j):
        return pl.BlockSpec(
            (1, page_size, 1, d),
            lambda bi, ki, gi, tables, lens, j=j:
            (jnp.maximum(tables[bi, gi * ppb + j], 0), 0, ki, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, ng),
        in_specs=(
            [pl.BlockSpec((1, 1, group, d),
                          lambda bi, ki, gi, tables, lens: (bi, ki, 0, 0))]
            + [_page_spec(j) for j in range(ppb)]          # k page group
            + [_page_spec(j) for j in range(ppb)]          # v page group
        ),
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda bi, ki, gi, tables, lens:
                               (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg,
      *([k_pages] * ppb), *([v_pages] * ppb))
    return out.reshape(b, h, d)
