"""Jitted public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode(q, k_pages, v_pages, block_tables, seq_lens, *,
                 use_pallas: bool = False, interpret: bool = True):
    """q (B, H, D); pages (P, page, K, D); tables (B, maxp); lens (B,)."""
    if use_pallas:
        return paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens)
