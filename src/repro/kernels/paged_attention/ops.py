"""Jitted public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "pages_per_block"))
def paged_decode(q, k_pages, v_pages, block_tables, seq_lens, *,
                 use_pallas: bool = False, interpret: bool = True,
                 pages_per_block=None):
    """q (B, H, D); pages (P, page, K, D); tables (B, maxp); lens (B,).

    ``pages_per_block`` widens the Pallas grid step to process that many
    pages at once (None = auto-size toward a 128-row KV tile)."""
    if use_pallas:
        return paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               pages_per_block=pages_per_block,
                               interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens)
