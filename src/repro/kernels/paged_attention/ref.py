"""Pure-jnp oracle for paged attention: gather pages, dense softmax."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens, *,
                        sm_scale: Optional[float] = None):
    """Same contract as the kernel; gathers the paged KV into dense
    (B, max_len, K, D) buffers and runs exact masked attention."""
    b, h, d = q.shape
    n_pages, page_size, kh, _ = k_pages.shape
    group = h // kh
    max_pages = block_tables.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    safe = jnp.maximum(block_tables, 0)                 # (B, maxp)
    k = jnp.take(k_pages, safe.reshape(-1), axis=0)     # (B*maxp, page, K, D)
    v = jnp.take(v_pages, safe.reshape(-1), axis=0)
    k = k.reshape(b, max_pages * page_size, kh, d)
    v = v.reshape(b, max_pages * page_size, kh, d)

    qf = q.reshape(b, kh, group, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(max_pages * page_size)[None]
    page_ok = jnp.repeat(block_tables >= 0, page_size, axis=1)
    mask = (pos < seq_lens[:, None]) & page_ok
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    # rows with no valid position (empty batch slots) attend to nothing
    any_valid = jnp.any(mask, axis=1)                   # (B,)
    o = jnp.where(any_valid[:, None, None, None], o, 0.0)
    return o.reshape(b, h, d).astype(q.dtype)
