"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp oracle).
Validated in interpret mode on CPU; TPU is the deployment target.
"""
