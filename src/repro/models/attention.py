"""Attention: GQA projections + chunked (memory-bounded) attention.

Three execution paths share one set of weights:
  * ``attend_chunked``   — training / prefill; query-chunked exact softmax so
    the score matrix never materialises beyond (B, H, cq, S) (flash-attention
    memory behaviour in pure jnp — the Pallas kernel in
    ``repro.kernels.flash_attention`` is the TPU hot-spot version).
  * ``attend_decode``    — one new token against a dense KV cache (the
    Pallas ``paged_attention`` kernel is the paged/TPU version).
  * ``attend_decode_swa``— one new token against a ring-buffer window cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import MeshRules

NEG_INF = -1e30


import contextlib


def _null_scope():
    return contextlib.nullcontext()


# ------------------------------------------------------------- weights ----
def attn_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    r = jax.random.split(rng, 5)
    p = {
        "wq": layers.dense_init(r[0], d, h * hd, dtype=dtype),
        "wk": layers.dense_init(r[1], d, k * hd, dtype=dtype),
        "wv": layers.dense_init(r[2], d, k * hd, dtype=dtype),
        "wo": layers.dense_init(r[3], h * hd, d, dtype=dtype,
                                scale=1.0 / (h * hd) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = layers.bias_init(h * hd, dtype=dtype)
        p["bk"] = layers.bias_init(k * hd, dtype=dtype)
        p["bv"] = layers.bias_init(k * hd, dtype=dtype)
    return p


def attn_specs(cfg: ModelConfig, rules: MeshRules) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    # Shard the flattened head dim on `model` only when whole heads divide,
    # so per-head softmax stays device-local.
    q_tp = rules.tp_axis if (rules.tp_size and h % rules.tp_size == 0) else None
    kv_tp = rules.tp_axis if (rules.tp_size and k % rules.tp_size == 0) else None
    s = {
        "wq": P(rules.fsdp(d), q_tp),
        "wk": P(rules.fsdp(d), kv_tp),
        "wv": P(rules.fsdp(d), kv_tp),
        "wo": P(q_tp, rules.fsdp(d)),
    }
    if cfg.qkv_bias:
        s["bq"] = P(q_tp)
        s["bk"] = P(kv_tp)
        s["bv"] = P(kv_tp)
    return s


def qkv_proj(params, cfg: ModelConfig, x):
    """x: (B, S, D) -> q (B,S,H,hd), k,v (B,S,K,hd)."""
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.n_kv_heads, hd),
            v.reshape(b, s, cfg.n_kv_heads, hd))


def out_proj(params, cfg: ModelConfig, att):
    b, s = att.shape[:2]
    return att.reshape(b, s, -1) @ params["wo"].astype(att.dtype)


# ----------------------------------------------------- chunked attention ---
def _chunk_scores(q, k, scale):
    """q (B,cq,K,G,hd), k (B,Sk,K,hd) -> scores (B,K,G,cq,Sk) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, chunk: int = 512,
                   fused: bool = False):
    """Exact attention, query-chunked.  q (B,Sq,H,hd); k,v (B,Sk,K,hd).

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill with a
    pre-existing cache).  ``window`` > 0 applies a sliding window (SWA).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    scale = hd ** -0.5
    chunk = min(chunk, sq)
    # pad sq to a multiple of chunk
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk
    qs = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)

    def one_chunk(carry, inp):
        ci, qc = inp
        # Under the fused contract this region executes as the Pallas
        # flash-attention kernel on TPU (repro.kernels.flash_attention);
        # the scope marker tells the HLO cost walker its interior never
        # touches HBM (boundary bytes are added back analytically).
        scope = (jax.named_scope("vmem_fused_flash") if fused
                 else _null_scope())
        with scope:
            # FLAT-HEAD einsums: factoring H into (K, G) breaks the TP
            # head sharding (the mesh axis cannot split either factor
            # evenly for e.g. 8 kv heads on 16 shards) and makes XLA
            # partial-sum full activations per chunk.  Expanding KV to H
            # heads keeps every einsum head-local; the expansion itself
            # is kernel-interior (the Pallas kernel indexes KV by
            # h // group without materializing it).
            if g > 1:
                ke = jnp.repeat(k, g, axis=2)          # (B,Sk,H,hd)
                ve = jnp.repeat(v, g, axis=2)
            else:
                ke, ve = k, v
            scores = jnp.einsum("bqhd,bshd->bhqs", qc, ke,
                                preferred_element_type=jnp.float32) * scale
            qpos = q_offset + ci * chunk + jnp.arange(chunk)
            mask = jnp.ones((chunk, sk), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > (qpos[:, None] - window)
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            att = jax.nn.softmax(scores, axis=-1).astype(ve.dtype)
            out = jnp.einsum("bhqs,bshd->bqhd", att, ve)
        return carry, out

    _, outs = jax.lax.scan(one_chunk, None,
                           (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * chunk, h, hd)
    return out[:, :sq]


# -------------------------------------------------------------- decode ----
def attend_decode(q, k_cache, v_cache, cache_len, *, fused: bool = False):
    """q (B,1,H,hd); caches (B,Smax,K,hd); cache_len (B,) valid entries
    (including the token written this step)."""
    b, _, h, hd = q.shape
    smax, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qc = q.reshape(b, 1, kh, g, hd)
    # fused contract: runs as the paged/flash decode Pallas kernel on TPU
    scope = (jax.named_scope("vmem_fused_decode") if fused
             else _null_scope())
    with scope:
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k_cache,
                            preferred_element_type=jnp.float32) * scale
        pos = jnp.arange(smax)
        mask = pos[None, :] < cache_len[:, None]      # (B,Smax)
        scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
        att = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", att, v_cache)
    return out.reshape(b, 1, h, hd)


def attend_decode_cp(q, k_cache, v_cache, cache_len, mesh, *,
                     seq_axis: str = "model", batch_axes=("data",),
                     fused: bool = False):
    """Context-parallel decode attention: the KV cache stays SEQUENCE-
    sharded on the `model` axis and the softmax is computed distributed
    (pmax/psum of per-shard stats) instead of letting the partitioner
    all-gather the cache — 10.8 GB/step -> ~100 MB/step of ICI traffic for
    qwen2-72b decode_32k (EXPERIMENTS.md §Perf, hillclimb #3).

    q (B,1,H,hd) replicated over `model`; caches (B,KL,K,hd) KL-sharded on
    `model`; cache_len (B,).  Inside shard_map the local block is the
    paged/flash decode Pallas kernel region (fused contract scope).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, _, h, hd = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = hd ** -0.5
    n_seq = mesh.shape[seq_axis]
    bax = batch_axes[0] if b % mesh.shape[batch_axes[0]] == 0 else None

    def local(qb, kc, vc, clen):
        s_local = kc.shape[1]
        idx = jax.lax.axis_index(seq_axis)
        scope = (jax.named_scope("vmem_fused_decode") if fused
                 else _null_scope())
        with scope:
            qc = qb.reshape(qb.shape[0], 1, kh, g, hd)
            scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc,
                                preferred_element_type=jnp.float32) * scale
            pos = idx * s_local + jnp.arange(s_local)
            mask = pos[None, :] < clen[:, None]
            scores = jnp.where(mask[:, None, None, None, :], scores,
                               NEG_INF)
            m_loc = jnp.max(scores, axis=-1, keepdims=True)
            m = jax.lax.pmax(m_loc, seq_axis)
            p = jnp.exp(scores - m)
            l = jax.lax.psum(jnp.sum(p, axis=-1, keepdims=True), seq_axis)
            part = jnp.einsum("bkgqs,bskh->bqkgh", p, vc,
                              preferred_element_type=jnp.float32)
            out = jax.lax.psum(part, seq_axis)
        out = out / jnp.maximum(l.transpose(0, 3, 1, 2, 4), 1e-30)
        return out.reshape(qb.shape[0], 1, h, hd).astype(qb.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bax, None, None, None), P(bax, seq_axis, None, None),
                  P(bax, seq_axis, None, None), P(bax)),
        out_specs=P(bax, None, None, None),
        check_rep=False,
    )(q, k_cache, v_cache, cache_len)


def cache_update(k_cache, v_cache, k_new, v_new, cache_len):
    """Write one token at position cache_len (per batch row)."""
    b = k_cache.shape[0]
    idx = cache_len  # (B,)
    k_cache = jax.vmap(
        lambda c, kn, i: jax.lax.dynamic_update_slice(c, kn, (i, 0, 0))
    )(k_cache, k_new, idx)
    v_cache = jax.vmap(
        lambda c, vn, i: jax.lax.dynamic_update_slice(c, vn, (i, 0, 0))
    )(v_cache, v_new, idx)
    return k_cache, v_cache


def cache_update_uniform(k_cache, v_cache, k_new, v_new, pos):
    """All rows write at the SAME position (static-batch decode): one
    in-place dynamic_update_slice instead of a per-row scatter.  Avoids
    XLA's scatter expansion (which converts the full stacked cache) — the
    decode hillclimb's first win (EXPERIMENTS.md §Perf)."""
    upd_k = k_new.astype(k_cache.dtype)
    upd_v = v_new.astype(v_cache.dtype)
    zero = jnp.zeros((), jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(k_cache, upd_k,
                                           (zero, pos, zero, zero))
    v_cache = jax.lax.dynamic_update_slice(v_cache, upd_v,
                                           (zero, pos, zero, zero))
    return k_cache, v_cache


def cache_update_ring(k_cache, v_cache, k_new, v_new, pos):
    """SWA ring buffer of size W: write at pos % W."""
    w = k_cache.shape[1]
    slot = pos % w
    k_cache = jax.vmap(
        lambda c, kn, i: jax.lax.dynamic_update_slice(c, kn, (i, 0, 0))
    )(k_cache, k_new, slot)
    v_cache = jax.vmap(
        lambda c, vn, i: jax.lax.dynamic_update_slice(c, vn, (i, 0, 0))
    )(v_cache, v_new, slot)
    return k_cache, v_cache


def attend_decode_swa(q, k_cache, v_cache, pos, window: int):
    """Decode against a ring-buffer cache of size W=window.

    ``pos`` (B,): absolute position of the current token (already written).
    Valid entries: absolute positions in (pos-W, pos]; slot i holds the most
    recent token with abs_pos % W == i.
    """
    b, _, h, hd = q.shape
    w, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = hd ** -0.5
    qc = q.reshape(b, 1, kh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k_cache,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(w)
    # slot i holds abs position: pos - ((pos - i) mod W)
    abs_pos = pos[:, None] - ((pos[:, None] - slots[None, :]) % w)
    valid = (abs_pos >= 0) & (abs_pos > pos[:, None] - w) & (abs_pos <= pos[:, None])
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    att = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", att, v_cache)
    return out.reshape(b, 1, h, hd)
