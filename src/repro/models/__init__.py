"""Model zoo: functional JAX models for all assigned architectures."""
from repro.models import attention, layers, mlp, moe, ssm, transformer
from repro.models.sharding import MeshRules, constrain, named
from repro.models.transformer import (cache_specs, decode_step, forward,
                                      init_cache, init_params, lm_logits,
                                      loss_fn, param_specs, prefill)

__all__ = [
    "attention", "layers", "mlp", "moe", "ssm", "transformer",
    "MeshRules", "constrain", "named",
    "init_params", "param_specs", "forward", "loss_fn", "lm_logits",
    "init_cache", "cache_specs", "prefill", "decode_step",
]
