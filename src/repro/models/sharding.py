"""Sharding policy: maps logical parameter/activation dims to mesh axes.

The production mesh is ``(data=16, model=16)`` per pod and
``(pod=2, data=16, model=16)`` across pods (see launch/mesh.py).  Parameters
are 2D-sharded: FSDP along ``data`` (+``pod``), tensor-parallel along
``model``.  Every rule degrades to replication when a dim is not divisible by
the axis size, so all ten assigned architectures lower on the same mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class MeshRules:
    """Divisibility-checked logical->mesh axis mapping.

    ``shard_params_fsdp=False`` is SERVING mode: parameters are TP-only
    (no FSDP row-sharding), so decode never all-gathers weights — each
    step reads its local TP shard, which is the decode roofline.  The
    batch keeps sharding on the data axes either way."""

    fsdp_axes: Tuple[str, ...]   # ("data",) or ("pod", "data")
    tp_axis: str                 # "model"
    fsdp_size: int
    tp_size: int
    shard_params_fsdp: bool = True

    # -- parameter dims --
    def fsdp(self, dim: int) -> Axis:
        if not self.shard_params_fsdp:
            return None
        if self.fsdp_size > 0 and dim % self.fsdp_size == 0:
            return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]
        return None

    def tp(self, dim: int) -> Axis:
        if self.tp_size > 0 and dim % self.tp_size == 0:
            return self.tp_axis
        return None

    # -- activation dims --
    def batch(self, dim: int) -> Axis:
        if self.fsdp_size > 0 and dim % self.fsdp_size == 0:
            return self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]
        return None

    def serving(self) -> "MeshRules":
        import dataclasses
        return dataclasses.replace(self, shard_params_fsdp=False)

    @classmethod
    def from_mesh(cls, mesh: Mesh, scheme: str = "2d") -> "MeshRules":
        """scheme='2d':   FSDP rows on (pod, data) x TP columns on model.
        scheme='zero3':   pure FSDP over EVERY axis — no tensor
        parallelism, so no per-block activation all-reduces; parameters
        gather per layer (bf16) and gradients reduce-scatter.  Wins when
        global_batch x seq is large relative to the model (the qwen2
        train hillclimb: 2.6 TB -> ~0.4 TB wire/step)."""
        if scheme not in ("2d", "zero3"):
            raise ValueError(
                f"unknown MeshRules scheme {scheme!r}: expected '2d' "
                "(FSDP rows x TP columns) or 'zero3' (pure FSDP)")
        names = mesh.axis_names
        if scheme == "zero3":
            fsdp_axes = tuple(names)
            fsdp_size = 1
            for a in fsdp_axes:
                fsdp_size *= mesh.shape[a]
            return cls(fsdp_axes=fsdp_axes, tp_axis="model",
                       fsdp_size=fsdp_size, tp_size=0)
        fsdp_axes = tuple(a for a in names if a in ("pod", "data"))
        fsdp_size = 1
        for a in fsdp_axes:
            fsdp_size *= mesh.shape[a]
        tp_size = mesh.shape.get("model", 1)
        return cls(fsdp_axes=fsdp_axes or ("data",), tp_axis="model",
                   fsdp_size=fsdp_size, tp_size=tp_size)

    @classmethod
    def single_device(cls) -> "MeshRules":
        """Degenerate rules: everything replicated (CPU smoke tests)."""
        return cls(fsdp_axes=("data",), tp_axis="model", fsdp_size=0, tp_size=0)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
