"""Dense FFN: SwiGLU (llama-family) or GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import MeshRules


def mlp_init(rng, cfg: ModelConfig, *, d_ff: int = 0, dtype=jnp.float32):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    r = jax.random.split(rng, 3)
    if cfg.act == "silu":  # SwiGLU
        return {
            "w_gate": layers.dense_init(r[0], d, f, dtype=dtype),
            "w_up": layers.dense_init(r[1], d, f, dtype=dtype),
            "w_down": layers.dense_init(r[2], f, d, dtype=dtype),
        }
    return {
        "w_up": layers.dense_init(r[0], d, f, dtype=dtype),
        "b_up": layers.bias_init(f, dtype=dtype),
        "w_down": layers.dense_init(r[1], f, d, dtype=dtype),
        "b_down": layers.bias_init(d, dtype=dtype),
    }


def mlp_specs(cfg: ModelConfig, rules: MeshRules, *, d_ff: int = 0) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act == "silu":
        return {
            "w_gate": P(rules.fsdp(d), rules.tp(f)),
            "w_up": P(rules.fsdp(d), rules.tp(f)),
            "w_down": P(rules.tp(f), rules.fsdp(d)),
        }
    return {
        "w_up": P(rules.fsdp(d), rules.tp(f)),
        "b_up": P(rules.tp(f)),
        "w_down": P(rules.tp(f), rules.fsdp(d)),
        "b_down": P(None),
    }


def mlp_apply(params, cfg: ModelConfig, x):
    if "w_gate" in params:
        g = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
        u = x @ params["w_up"].astype(x.dtype)
        return (g * u) @ params["w_down"].astype(x.dtype)
    h = x @ params["w_up"].astype(x.dtype) + params["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h)
    return h @ params["w_down"].astype(x.dtype) + params["b_down"].astype(x.dtype)
