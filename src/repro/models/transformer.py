"""Model assembly for all ten assigned architectures.

One functional model covers dense / MoE / SSM / hybrid / enc-dec families:

  * ``init_params`` / ``param_specs``  — congruent pytrees (params ↔ P specs)
  * ``forward``                         — full-sequence (train / prefill)
  * ``init_cache`` / ``cache_specs``    — decode state (KV, ring, SSM, x-attn)
  * ``prefill`` / ``decode_step``       — serving path

Layers are stacked along a leading axis and applied with ``lax.scan`` so the
lowered HLO stays O(1) in depth — an 80-layer qwen2-72b lowers as fast as a
2-layer smoke model, which is what makes the 40-cell × 2-mesh dry-run
tractable.  Hybrid patterns (zamba2) scan over *cycles* with one stacked
param tree per pattern slot; the zamba2 attention block is a single shared
param set (closure constant), faithful to the paper's shared-block design.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mlp, moe, ssm
from repro.models.sharding import MeshRules, constrain


# ====================================================================== util
def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _uniform(cfg: ModelConfig) -> bool:
    return len(cfg.block_pattern) == 1


def _n_cycles(cfg: ModelConfig) -> int:
    assert cfg.n_layers % len(cfg.block_pattern) == 0, (
        f"{cfg.arch_id}: n_layers {cfg.n_layers} not divisible by "
        f"pattern {cfg.block_pattern}")
    return cfg.n_layers // len(cfg.block_pattern)


def _is_moe_layer(cfg: ModelConfig) -> bool:
    return cfg.moe is not None


# ============================================================ layer: init
def _attn_layer_init(rng, cfg: ModelConfig, *, dtype, cross: bool = False):
    r = jax.random.split(rng, 4)
    p = {
        "norm1": layers.rmsnorm_init(cfg.d_model, dtype=dtype),
        "attn": attention.attn_init(r[0], cfg, dtype=dtype),
        "norm2": layers.rmsnorm_init(cfg.d_model, dtype=dtype),
    }
    if cfg.act == "gelu":  # whisper uses LayerNorm
        p["norm1"] = layers.layernorm_init(cfg.d_model, dtype=dtype)
        p["norm2"] = layers.layernorm_init(cfg.d_model, dtype=dtype)
    if _is_moe_layer(cfg):
        p["ffn"] = moe.moe_init(r[1], cfg, dtype=dtype)
    else:
        p["ffn"] = mlp.mlp_init(r[1], cfg, dtype=dtype)
    if cross:
        p["norm_x"] = (layers.layernorm_init(cfg.d_model, dtype=dtype)
                       if cfg.act == "gelu"
                       else layers.rmsnorm_init(cfg.d_model, dtype=dtype))
        p["xattn"] = attention.attn_init(r[2], cfg, dtype=dtype)
    return p


def _mamba_layer_init(rng, cfg: ModelConfig, *, dtype):
    return {
        "norm": layers.rmsnorm_init(cfg.d_model, dtype=dtype),
        "mamba": ssm.mamba_init(rng, cfg, dtype=dtype),
    }


def _attn_layer_specs(cfg: ModelConfig, rules: MeshRules,
                      *, cross: bool = False):
    s = {
        "norm1": layers.norm_specs(
            layers.layernorm_init(1) if cfg.act == "gelu"
            else layers.rmsnorm_init(1)),
        "attn": attention.attn_specs(cfg, rules),
        "norm2": layers.norm_specs(
            layers.layernorm_init(1) if cfg.act == "gelu"
            else layers.rmsnorm_init(1)),
    }
    if _is_moe_layer(cfg):
        s["ffn"] = moe.moe_specs(cfg, rules)
    else:
        s["ffn"] = mlp.mlp_specs(cfg, rules)
    if cross:
        s["norm_x"] = s["norm1"]
        s["xattn"] = attention.attn_specs(cfg, rules)
    return s


def _mamba_layer_specs(cfg: ModelConfig, rules: MeshRules):
    return {
        "norm": layers.norm_specs(layers.rmsnorm_init(1)),
        "mamba": ssm.mamba_specs(cfg, rules),
    }


def init_params(rng, cfg: ModelConfig, *, dtype=jnp.bfloat16) -> Dict:
    """Full parameter pytree, layers stacked for lax.scan."""
    keys = jax.random.split(rng, cfg.n_layers + 8)
    p: Dict[str, Any] = {
        "embed": layers.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                   dtype=dtype),
        "final_norm": (layers.layernorm_init(cfg.d_model, dtype=dtype)
                       if cfg.act == "gelu"
                       else layers.rmsnorm_init(cfg.d_model, dtype=dtype)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(keys[1], cfg.d_model,
                                         cfg.padded_vocab, dtype=dtype)

    cross = cfg.n_encoder_layers > 0
    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        per = [(_mamba_layer_init(keys[2 + i], cfg, dtype=dtype)
                if kind == "mamba" else
                _attn_layer_init(keys[2 + i], cfg, dtype=dtype, cross=cross))
               for i in range(cfg.n_layers)]
        p["layers"] = _stack_trees(per)
    else:
        nc = _n_cycles(cfg)
        slots = []
        shared_attn = None
        for si, kind in enumerate(cfg.block_pattern):
            if kind == "shared_attn":
                shared_attn = _attn_layer_init(keys[2 + si], cfg, dtype=dtype)
                slots.append(None)
            else:
                per = [_mamba_layer_init(
                    jax.random.fold_in(keys[2 + si], c), cfg, dtype=dtype)
                    for c in range(nc)]
                slots.append(_stack_trees(per))
        p["slots"] = tuple(s for s in slots if s is not None)
        if shared_attn is not None:
            p["shared_attn"] = shared_attn

    if cross:
        enc = [_attn_layer_init(jax.random.fold_in(keys[-1], i), cfg,
                                dtype=dtype)
               for i in range(cfg.n_encoder_layers)]
        p["encoder"] = {
            "layers": _stack_trees(enc),
            "final_norm": layers.layernorm_init(cfg.d_model, dtype=dtype),
        }
    return p


def param_specs(cfg: ModelConfig, rules: MeshRules) -> Dict:
    """PartitionSpec pytree congruent with init_params output.

    Stacked layer dim is never sharded (it is the scan axis)."""
    def lift(tree):  # prepend None for the stacked layer axis
        return jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), tree,
                            is_leaf=lambda x: isinstance(x, P))

    s: Dict[str, Any] = {
        "embed": layers.embed_specs(rules, cfg.padded_vocab,
                                    cfg.d_model),
        "final_norm": layers.norm_specs(
            layers.layernorm_init(1) if cfg.act == "gelu"
            else layers.rmsnorm_init(1)),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = P(rules.fsdp(cfg.d_model), rules.tp(cfg.padded_vocab))

    cross = cfg.n_encoder_layers > 0
    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        per = (_mamba_layer_specs(cfg, rules) if kind == "mamba"
               else _attn_layer_specs(cfg, rules, cross=cross))
        s["layers"] = lift(per)
    else:
        slots = []
        shared = None
        for kind in cfg.block_pattern:
            if kind == "shared_attn":
                shared = _attn_layer_specs(cfg, rules)
            else:
                slots.append(lift(_mamba_layer_specs(cfg, rules)))
        s["slots"] = tuple(slots)
        if shared is not None:
            s["shared_attn"] = shared

    if cross:
        s["encoder"] = {
            "layers": lift(_attn_layer_specs(cfg, rules)),
            "final_norm": layers.norm_specs(layers.layernorm_init(1)),
        }
    return s


# ====================================================== layer: full-seq fwd
def _attn_block_fwd(p, cfg: ModelConfig, x, *, causal: bool, q_offset: int,
                    enc_out=None, fused: bool = False, rules=None):
    """Self-attn (+optional cross-attn) + FFN with residuals.  Returns
    (x, aux, (k, v)) — k/v pre-RoPE'd, for prefill cache capture."""
    h = layers.norm_apply(p["norm1"], x, cfg.norm_eps)
    q, k, v = attention.qkv_proj(p["attn"], cfg, h)
    if cfg.pos_embed == "rope":
        pos = q_offset + jnp.arange(x.shape[1])
        q = layers.apply_rope(q, pos[None, :], cfg.rope_theta)
        k = layers.apply_rope(k, pos[None, :], cfg.rope_theta)
    att = attention.attend_chunked(q, k, v, causal=causal,
                                   window=cfg.swa_window, q_offset=0,
                                   fused=fused)
    x = x + attention.out_proj(p["attn"], cfg, att)

    xkv = None
    if enc_out is not None:
        hx = layers.norm_apply(p["norm_x"], x, cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"].astype(hx.dtype)).reshape(
            hx.shape[0], hx.shape[1], cfg.n_heads, cfg.resolved_head_dim)
        ek = (enc_out @ p["xattn"]["wk"].astype(enc_out.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
            cfg.resolved_head_dim)
        ev = (enc_out @ p["xattn"]["wv"].astype(enc_out.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
            cfg.resolved_head_dim)
        ax = attention.attend_chunked(qx, ek, ev, causal=False,
                                      fused=fused)
        x = x + attention.out_proj(p["xattn"], cfg, ax)
        xkv = (ek, ev)

    h = layers.norm_apply(p["norm2"], x, cfg.norm_eps)
    if _is_moe_layer(cfg):
        out, aux = moe.moe_apply(p["ffn"], cfg, h, rules=rules)
    else:
        out, aux = mlp.mlp_apply(p["ffn"], cfg, h), jnp.float32(0.0)
    return x + out, aux, (k, v), xkv


def _mamba_block_fwd(p, cfg: ModelConfig, x):
    h = layers.norm_apply(p["norm"], x, cfg.norm_eps)
    out, final_cache = ssm.mamba_apply(p["mamba"], cfg, h)
    return x + out, final_cache


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder: frames (B, enc_seq, D) -> enc_out (B, enc_seq, D)."""
    pe = layers.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames + pe[None].astype(frames.dtype)

    def body(x, lp):
        x, _, _, _ = _attn_block_fwd(lp, cfg, x, causal=False, q_offset=0)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return layers.norm_apply(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _embed_tokens(params, cfg: ModelConfig, tokens, *, offset=0):
    x = layers.embed_lookup(params["embed"], tokens)
    if cfg.pos_embed == "absolute":
        pe = layers.sinusoidal_positions(int(offset) + tokens.shape[1],
                                         cfg.d_model)[int(offset):]
        x = x + pe[None].astype(x.dtype)
    return x


def forward(params, cfg: ModelConfig, tokens, *, encoder_frames=None,
            remat: str = "none", rules: Optional[MeshRules] = None,
            collect_kv: bool = False, compute_dtype=None,
            fused_attention: bool = False):
    """Full-sequence forward.  tokens (B, S) int32.

    Returns (hidden (B,S,D), aux_loss, kv_stack_or_None, enc_out_or_None).
    ``collect_kv``: emit per-layer (k, v) (and cross-attn KV) for prefill.
    ``compute_dtype``: activation dtype (params stay fp32 masters; weights
    cast at use sites) — bf16 in production, None keeps the param dtype.
    """
    x = _embed_tokens(params, cfg, tokens)
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        if encoder_frames is not None:
            encoder_frames = encoder_frames.astype(compute_dtype)
    if rules is not None:
        x = constrain(x, P(rules.batch(tokens.shape[0]), None, None))

    enc_out = None
    if cfg.n_encoder_layers:
        assert encoder_frames is not None, f"{cfg.arch_id} needs frames"
        enc_out = encode(params, cfg, encoder_frames)

    aux_total = jnp.float32(0.0)
    kv_stack = None
    xkv_stack = None

    mamba_states = None
    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        if kind == "mamba":
            def body(x, lp):
                x, fc = _mamba_block_fwd(lp, cfg, x)
                return x, (fc if collect_kv else None)
            x, mamba_states = jax.lax.scan(_remat(body, remat), x,
                                           params["layers"])
        else:
            def body(x, lp):
                x, aux, kv, xkv = _attn_block_fwd(
                    lp, cfg, x, causal=True, q_offset=0, enc_out=enc_out,
                    fused=fused_attention, rules=rules)
                out = (aux, kv if collect_kv else None,
                       xkv if (collect_kv and enc_out is not None) else None)
                return x, out
            x, (auxs, kvs, xkvs) = jax.lax.scan(
                _remat(body, remat), x, params["layers"])
            aux_total = jnp.sum(auxs)
            kv_stack = kvs
            xkv_stack = xkvs
    else:
        shared = params.get("shared_attn")
        pattern = cfg.block_pattern

        def body(x, slot_params):
            kvs = None
            states = []
            si = 0
            for kind in pattern:
                if kind == "shared_attn":
                    x, _, kv, _ = _attn_block_fwd(shared, cfg, x, causal=True,
                                                  q_offset=0,
                                                  fused=fused_attention)
                    kvs = kv if collect_kv else None
                else:
                    x, fc = _mamba_block_fwd(slot_params[si], cfg, x)
                    states.append(fc)
                    si += 1
            if collect_kv:
                ms = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
            else:
                ms = None
            return x, (kvs, ms)
        x, (kvs, mamba_states) = jax.lax.scan(_remat(body, remat), x,
                                              params["slots"])
        kv_stack = kvs

    x = layers.norm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total, kv_stack, (enc_out, xkv_stack, mamba_states)


def lm_logits(params, cfg: ModelConfig, hidden,
              rules: Optional[MeshRules] = None):
    """hidden (..., D) -> logits (..., V) fp32."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(jnp.float32)
        logits = hidden.astype(jnp.float32) @ w.T
    else:
        logits = hidden.astype(jnp.float32) @ params["lm_head"].astype(
            jnp.float32)
    if rules is not None and logits.ndim == 3:
        logits = constrain(logits, P(rules.batch(logits.shape[0]), None,
                                     rules.tp(cfg.padded_vocab)))
    return logits


def xent_loss(params, cfg: ModelConfig, hidden, labels, mask, *,
              rules: Optional[MeshRules] = None, chunk: int = 256):
    """Chunked cross-entropy so (B,S,V) logits never fully materialise.

    hidden (B,S,D); labels/mask (B,S).  Returns (loss, n_tokens)."""
    b, s_len, d = hidden.shape
    chunk = min(chunk, s_len)
    while s_len % chunk:
        chunk //= 2
    nc = s_len // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        h, l, m = inp
        logits = lm_logits(params, cfg, h, rules)          # (B,c,V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(m)), None

    (tot, n), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                               (hc, lc, mc))
    return tot / jnp.maximum(n, 1.0), n


def loss_fn(params, cfg: ModelConfig, batch, *, remat: str = "none",
            rules: Optional[MeshRules] = None, aux_weight: float = 0.01,
            compute_dtype=None, fused_attention: bool = False):
    """batch: {"tokens" (B,S), optional "frames"}.  Next-token LM loss."""
    tokens = batch["tokens"]
    hidden, aux, _, _ = forward(params, cfg, tokens,
                                encoder_frames=batch.get("frames"),
                                remat=remat, rules=rules,
                                compute_dtype=compute_dtype,
                                fused_attention=fused_attention)
    labels = jnp.concatenate([tokens[:, 1:],
                              jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], dtype=jnp.float32),
         jnp.zeros_like(tokens[:, :1], dtype=jnp.float32)], axis=1)
    loss, n = xent_loss(params, cfg, hidden, labels, mask, rules=rules)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": n}


# ================================================================= caches
def decode_cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Physical KV length: SWA archs cap at their window (ring buffer)."""
    if cfg.swa_window:
        return min(max_len, cfg.swa_window)
    if cfg.family == "hybrid":
        # zamba2 shared-attn blocks: windowed KV (DESIGN.md §5)
        return min(max_len, 4096)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               dtype=jnp.bfloat16, enc_seq: int = 0) -> Dict:
    """Decode-state pytree, stacked on the layer axis for lax.scan."""
    hd = cfg.resolved_head_dim
    kl = decode_cache_len(cfg, max_len)
    c: Dict[str, Any] = {}
    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        ln = cfg.n_layers
        if kind == "mamba":
            c["mamba"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (ln,) + x.shape).copy()
                if False else jnp.zeros((ln,) + x.shape, x.dtype),
                ssm.mamba_cache_init(cfg, batch, dtype=dtype))
        else:
            c["k"] = jnp.zeros((ln, batch, kl, cfg.n_kv_heads, hd), dtype)
            c["v"] = jnp.zeros((ln, batch, kl, cfg.n_kv_heads, hd), dtype)
            if cfg.n_encoder_layers:
                c["xk"] = jnp.zeros((ln, batch, enc_seq, cfg.n_kv_heads, hd),
                                    dtype)
                c["xv"] = jnp.zeros((ln, batch, enc_seq, cfg.n_kv_heads, hd),
                                    dtype)
    else:
        nc = _n_cycles(cfg)
        n_mamba = sum(1 for k in cfg.block_pattern if k != "shared_attn")
        base = ssm.mamba_cache_init(cfg, batch, dtype=dtype)
        c["mamba"] = jax.tree.map(
            lambda x: jnp.zeros((nc, n_mamba) + x.shape, x.dtype), base)
        c["k"] = jnp.zeros((nc, batch, kl, cfg.n_kv_heads, hd), dtype)
        c["v"] = jnp.zeros((nc, batch, kl, cfg.n_kv_heads, hd), dtype)
    return c


def cache_specs(cfg: ModelConfig, rules: MeshRules, batch: int,
                max_len: int) -> Dict:
    """Sharding for the decode cache.

    KV heads shard on `model` when divisible; otherwise the *sequence* dim
    shards on `model` (context-parallel decode — softmax reductions become
    collectives, which the roofline analysis accounts for)."""
    kl = decode_cache_len(cfg, max_len)
    bax = rules.batch(batch)
    kv_tp = rules.tp(cfg.n_kv_heads)
    seq_tp = None if kv_tp is not None else rules.tp(kl)
    kv_spec = P(None, bax, seq_tp, kv_tp, None)
    s: Dict[str, Any] = {}
    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        if kind == "mamba":
            ms = ssm.mamba_cache_specs(cfg, rules, batch)
            s["mamba"] = jax.tree.map(
                lambda sp: P(*((None,) + tuple(sp))), ms,
                is_leaf=lambda x: isinstance(x, P))
        else:
            s["k"] = kv_spec
            s["v"] = kv_spec
            if cfg.n_encoder_layers:
                s["xk"] = P(None, bax, None, kv_tp, None)
                s["xv"] = P(None, bax, None, kv_tp, None)
    else:
        ms = ssm.mamba_cache_specs(cfg, rules, batch)
        s["mamba"] = jax.tree.map(
            lambda sp: P(*((None, None) + tuple(sp))), ms,
            is_leaf=lambda x: isinstance(x, P))
        s["k"] = kv_spec
        s["v"] = kv_spec
    return s


# =============================================================== decode ====
def _attn_block_decode(p, cfg: ModelConfig, x, kc, vc, pos, *,
                       xk=None, xv=None, fused: bool = False,
                       uniform_pos: bool = False, cp_mesh=None):
    """One-token attention block.  x (B,1,D); kc/vc (B,KL,K,hd); pos (B,)."""
    kl = kc.shape[1]
    ring = bool(cfg.swa_window) or cfg.family == "hybrid"
    h = layers.norm_apply(p["norm1"], x, cfg.norm_eps)
    q, k, v = attention.qkv_proj(p["attn"], cfg, h)
    if cfg.pos_embed == "rope":
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
    if ring:
        kc, vc = attention.cache_update_ring(kc, vc, k, v, pos)
        att = attention.attend_decode_swa(q, kc, vc, pos,
                                          cfg.swa_window or kl)
    else:
        if uniform_pos:
            kc, vc = attention.cache_update_uniform(kc, vc, k, v, pos[0])
        else:
            kc, vc = attention.cache_update(kc, vc, k, v, pos)
        if cp_mesh is not None:
            att = attention.attend_decode_cp(q, kc, vc, pos + 1, cp_mesh,
                                             fused=fused)
        else:
            att = attention.attend_decode(q, kc, vc, pos + 1, fused=fused)
    x = x + attention.out_proj(p["attn"], cfg, att)

    if xk is not None:
        hx = layers.norm_apply(p["norm_x"], x, cfg.norm_eps)
        b = hx.shape[0]
        qx = (hx @ p["xattn"]["wq"].astype(hx.dtype)).reshape(
            b, 1, cfg.n_heads, cfg.resolved_head_dim)
        ax = attention.attend_decode(
            qx, xk, xv, jnp.full((b,), xk.shape[1], jnp.int32))
        x = x + attention.out_proj(p["xattn"], cfg, ax)

    h = layers.norm_apply(p["norm2"], x, cfg.norm_eps)
    if _is_moe_layer(cfg):
        out, _ = moe.moe_apply(p["ffn"], cfg, h)
    else:
        out = mlp.mlp_apply(p["ffn"], cfg, h)
    return x + out, kc, vc


def _mamba_block_decode(p, cfg: ModelConfig, x, cache):
    h = layers.norm_apply(p["norm"], x, cfg.norm_eps)
    out, cache = ssm.mamba_decode(p["mamba"], cfg, h, cache)
    return x + out, cache


def decode_step(params, cfg: ModelConfig, cache: Dict, tokens, pos,
                *, fused_attention: bool = False,
                uniform_pos: bool = False, cp_mesh=None):
    """One decode step.  tokens (B,1) int32; pos (B,) current positions.

    Returns (logits (B,V) fp32, new_cache).  Cache should be donated."""
    x = _embed_tokens_decode(params, cfg, tokens, pos)

    if _uniform(cfg):
        kind = cfg.block_pattern[0]
        if kind == "mamba":
            def body(x, inp):
                lp, mc = inp
                x, mc = _mamba_block_decode(lp, cfg, x, mc)
                return x, mc
            x, mcache = jax.lax.scan(body, x,
                                     (params["layers"], cache["mamba"]))
            new_cache = {"mamba": mcache}
        else:
            has_x = cfg.n_encoder_layers > 0
            def body(x, inp):
                if has_x:
                    lp, kc, vc, xk, xv = inp
                else:
                    lp, kc, vc = inp
                    xk = xv = None
                x, kc, vc = _attn_block_decode(lp, cfg, x, kc, vc, pos,
                                               xk=xk, xv=xv,
                                               fused=fused_attention,
                                               uniform_pos=uniform_pos,
                                               cp_mesh=cp_mesh)
                return x, (kc, vc)
            xs = ((params["layers"], cache["k"], cache["v"], cache["xk"],
                   cache["xv"]) if has_x
                  else (params["layers"], cache["k"], cache["v"]))
            x, (ks, vs) = jax.lax.scan(body, x, xs)
            new_cache = dict(cache)
            new_cache["k"], new_cache["v"] = ks, vs
    else:
        shared = params.get("shared_attn")
        pattern = cfg.block_pattern

        def body(x, inp):
            slot_params, mc, kc, vc = inp
            si = 0
            new_mc = []
            for kind in pattern:
                if kind == "shared_attn":
                    x, kc, vc = _attn_block_decode(shared, cfg, x, kc, vc,
                                                   pos,
                                                   fused=fused_attention,
                                                   uniform_pos=uniform_pos)
                else:
                    sub = jax.tree.map(lambda a: a[si], mc)
                    x, sub = _mamba_block_decode(slot_params[si], cfg, x, sub)
                    new_mc.append(sub)
                    si += 1
            mc = jax.tree.map(lambda *xs: jnp.stack(xs), *new_mc)
            return x, (mc, kc, vc)

        x, (mcs, ks, vs) = jax.lax.scan(
            body, x, (params["slots"], cache["mamba"], cache["k"],
                      cache["v"]))
        new_cache = {"mamba": mcs, "k": ks, "v": vs}

    x = layers.norm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, new_cache


def _embed_tokens_decode(params, cfg: ModelConfig, tokens, pos):
    x = layers.embed_lookup(params["embed"], tokens)
    if cfg.pos_embed == "absolute":
        # sinusoidal at per-row position
        d = cfg.d_model
        inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
        ang = pos[:, None].astype(jnp.float32) * inv[None]
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe[:, None].astype(x.dtype)
    return x


# ============================================================== prefill ====
def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            encoder_frames=None, rules: Optional[MeshRules] = None,
            cache_dtype=jnp.bfloat16, fused_attention: bool = False):
    """Run the full prompt, build the decode cache, return last-token logits.

    tokens (B, S).  Cache is sized for ``max_len`` (or the SWA window)."""
    b, s_len = tokens.shape
    hidden, _, kv_stack, (enc_out, xkv, mamba_states) = forward(
        params, cfg, tokens, encoder_frames=encoder_frames, rules=rules,
        collect_kv=True, fused_attention=fused_attention)
    cache = init_cache(cfg, b, max_len, dtype=cache_dtype,
                       enc_seq=0 if enc_out is None else enc_out.shape[1])
    kl = decode_cache_len(cfg, max_len)

    def fill(kc, knew):
        # knew (L?, B, S, K, hd) -> write into (L?, B, KL, K, hd)
        knew = knew.astype(kc.dtype)
        if s_len <= kl:
            return jax.lax.dynamic_update_slice(
                kc, knew, (0,) * kc.ndim)
        # ring: keep last KL tokens at slot = abs_pos % KL
        tail = knew[..., s_len - kl:, :, :]
        slots = (jnp.arange(s_len - kl, s_len)) % kl
        order = jnp.argsort(slots)
        return jnp.take(tail, order, axis=-3)

    if kv_stack is not None:
        ks, vs = kv_stack
        cache["k"] = fill(cache["k"], ks)
        cache["v"] = fill(cache["v"], vs)
    if cfg.n_encoder_layers and xkv is not None:
        cache["xk"] = xkv[0].astype(cache["xk"].dtype)
        cache["xv"] = xkv[1].astype(cache["xv"].dtype)
    if mamba_states is not None:
        cache["mamba"] = jax.tree.map(
            lambda dst, src: src.astype(dst.dtype), cache["mamba"],
            mamba_states)

    logits = lm_logits(params, cfg, hidden[:, -1:])[:, 0]
    return logits, cache
