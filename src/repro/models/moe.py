"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Dispatch avoids one-hot matmuls (they waste FLOPs and poison the roofline):
tokens are scatter-added into per-expert capacity buffers, experts run as a
batched einsum with the expert dim sharded on the `model` axis (expert
parallelism — XLA inserts the all-to-all), and results gather back to token
order.  Tokens overflowing an expert's capacity are dropped (gate zeroed),
Switch-style.

Shapes (per group of Tg tokens):
  x (Tg, D) -> top-k (Tg, k) -> buf (E*C+1, D) -> experts (E, C, F) -> (Tg, D)
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import MeshRules


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    assert cfg.moe is not None
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    r = jax.random.split(rng, 5)

    def ew(key, a, b):
        return (jax.random.normal(key, (e.n_experts, a, b), dtype=jnp.float32)
                / math.sqrt(a)).astype(dtype)

    p = {
        "router": layers.dense_init(r[0], d, e.n_experts, dtype=jnp.float32),
        "w_gate": ew(r[1], d, f),
        "w_up": ew(r[2], d, f),
        "w_down": ew(r[3], f, d),
    }
    if e.n_shared_experts:
        fs = e.n_shared_experts * f
        rs = jax.random.split(r[4], 3)
        p["shared"] = {
            "w_gate": layers.dense_init(rs[0], d, fs, dtype=dtype),
            "w_up": layers.dense_init(rs[1], d, fs, dtype=dtype),
            "w_down": layers.dense_init(rs[2], fs, d, dtype=dtype),
        }
    return p


def moe_specs(cfg: ModelConfig, rules: MeshRules) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ep = rules.tp(e.n_experts)   # expert-parallel on the model axis
    # d/f inner dims are NOT row-sharded: contracting a sharded dim would
    # all-reduce full activation buffers per expert matmul (granite
    # hillclimb g2.2) — per-expert weights are small, EP is the sharding.
    s = {
        "router": P(None, None),
        "w_gate": P(ep, None, None),
        "w_up": P(ep, None, None),
        "w_down": P(ep, None, None),
    }
    if e.n_shared_experts:
        fs = e.n_shared_experts * f
        s["shared"] = {
            "w_gate": P(rules.fsdp(d), rules.tp(fs)),
            "w_up": P(rules.fsdp(d), rules.tp(fs)),
            "w_down": P(rules.tp(fs), rules.fsdp(d)),
        }
    return s


def _capacity(tg: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(tg * top_k * factor / n_experts))
    return max(_round_up(c, 8), 8)


def _dispatch_one_group(xg, gates, eidx, n_experts: int, capacity: int):
    """xg (Tg, D); gates/eidx (Tg, k).  Returns (buf (E*C+1, D), dest, gates)."""
    tg, k = eidx.shape
    flat_e = eidx.reshape(-1)                                  # (Tg*k,)
    oh = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)    # (Tg*k, E)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1    # position in expert
    dropped = pos >= capacity
    dest = jnp.where(dropped, n_experts * capacity, flat_e * capacity + pos)
    gates = jnp.where(dropped.reshape(tg, k), 0.0, gates)
    x_rep = jnp.repeat(xg, k, axis=0)                          # (Tg*k, D)
    buf = jnp.zeros((n_experts * capacity + 1, xg.shape[-1]), dtype=xg.dtype)
    buf = buf.at[dest].add(x_rep)
    return buf, dest, gates


def moe_apply(params, cfg: ModelConfig, x, *, capacity_factor: float = 0.0,
              group_size: int = 4096,
              rules: "MeshRules" = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``rules``: sharding hints — dispatch buffers are constrained so token
    groups stay on the data axes and the expert dim lands on `model`,
    giving the partitioner the token<->expert all-to-all instead of
    activation all-reduces."""
    from repro.models.sharding import constrain
    e = cfg.moe
    capacity_factor = capacity_factor or e.capacity_factor
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, e.top_k)                # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balancing aux loss.
    me = jnp.mean(probs, axis=0)                               # (E,)
    frac = jnp.mean(jax.nn.one_hot(eidx, e.n_experts, dtype=jnp.float32),
                    axis=(0, 1))                               # (E,)
    aux = e.n_experts * jnp.sum(frac * me)

    # group tokens; groups stay batch-major so they align with data shards
    gsz = min(group_size, t)
    while t % gsz:
        gsz //= 2
    ng = t // gsz
    cap = _capacity(gsz, e.top_k, e.n_experts, capacity_factor)

    xg = xf.reshape(ng, gsz, d)
    gg = gates.astype(xf.dtype).reshape(ng, gsz, e.top_k)
    eg = eidx.reshape(ng, gsz, e.top_k)

    bufs, dests, gs = jax.vmap(
        lambda a, g_, i_: _dispatch_one_group(a, g_, i_, e.n_experts, cap)
    )(xg, gg, eg)
    # bufs (ng, E*C+1, D) -> expert batch (ng, E, C, D)
    ein = bufs[:, :-1].reshape(ng, e.n_experts, cap, d)
    if rules is not None:
        # groups on data, experts on model: the partitioner reshapes this
        # boundary into the token->expert all-to-all
        ein = constrain(ein, P(rules.batch(ng), rules.tp(e.n_experts),
                               None, None))

    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, wg.astype(ein.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", ein, wu.astype(ein.dtype))
    eout = jnp.einsum("gecf,efd->gecd", h, wd.astype(ein.dtype))
    if rules is not None:
        eout = constrain(eout, P(rules.batch(ng), None, None, None))
    eflat = jnp.concatenate(
        [eout.reshape(ng, e.n_experts * cap, d),
         jnp.zeros((ng, 1, d), dtype=eout.dtype)], axis=1)     # dump row -> 0

    def combine(ef, dest, g_):
        y = jnp.take(ef, dest, axis=0)                         # (Tg*k, D)
        y = y.reshape(gsz, e.top_k, d) * g_[..., None]
        return jnp.sum(y, axis=1)

    out = jax.vmap(combine)(eflat, dests, gs)                  # (ng, Tg, D)
    out = out.reshape(b, s, d)

    if e.n_shared_experts:
        from repro.models.mlp import mlp_apply
        out = out + mlp_apply(params["shared"], cfg, x)
    return out, aux.astype(jnp.float32)
