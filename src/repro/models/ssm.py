"""Mamba-2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

The pure-jnp chunked SSD here is the reference path used for lowering and the
dry-run; ``repro.kernels.ssd`` holds the Pallas TPU kernel for the same math
(validated against :func:`ssd_chunked` in interpret mode).

Weight layout uses *separate* projections (wz/wx/wB/wC/wdt) instead of one
fused in_proj so each can carry its own PartitionSpec: head-indexed tensors
shard on the `model` (TP) axis; B/C are group-shared (G ≪ H, like GQA KV
heads) and stay column-replicated.  All head-dim einsums are then local under
TP; only out_proj reduces across shards.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import MeshRules


# ------------------------------------------------------------- weights ----
def mamba_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    assert cfg.ssm is not None
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    r = jax.random.split(rng, 8)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    dt = jnp.exp(jax.random.uniform(r[6], (nh,), dtype=jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "wz": layers.dense_init(r[0], d, di, dtype=dtype),
        "wx": layers.dense_init(r[1], d, di, dtype=dtype),
        "wB": layers.dense_init(r[2], d, gn, dtype=dtype),
        "wC": layers.dense_init(r[3], d, gn, dtype=dtype),
        "wdt": layers.dense_init(r[4], d, nh, dtype=dtype),
        "conv_w": (jax.random.normal(r[5], (s.d_conv, di + 2 * gn),
                                     dtype=jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * gn,), dtype=dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype=dtype)},
        "wo": layers.dense_init(r[7], di, d, dtype=dtype),
    }


def mamba_specs(cfg: ModelConfig, rules: MeshRules) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    tp_i = rules.tp(di)
    tp_h = rules.tp(nh)
    return {
        "wz": P(rules.fsdp(d), tp_i),
        "wx": P(rules.fsdp(d), tp_i),
        "wB": P(rules.fsdp(d), None),
        "wC": P(rules.fsdp(d), None),
        "wdt": P(rules.fsdp(d), tp_h),
        # conv channels: x section shards with di only when the full concat
        # dim keeps the x boundary on a shard edge; keep replicated (small).
        "conv_w": P(None, None),
        "conv_b": P(None),
        "dt_bias": P(tp_h),
        "A_log": P(tp_h),
        "D": P(tp_h),
        "norm": {"scale": P(tp_i)},
        "wo": P(tp_i, rules.fsdp(d)),
    }


# ---------------------------------------------------------------- conv ----
def causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv.  x (B,S,C); w (K,C); b (C,).

    ``state`` (B,K-1,C): trailing context from the previous segment (decode /
    chunked prefill).  Returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), dtype=x.dtype)
    xe = jnp.concatenate([state, x], axis=1)            # (B, S+K-1, C)
    new_state = xe[:, -(k - 1):] if k > 1 else state
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xe[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    return jax.nn.silu(y), new_state


# ----------------------------------------------------------------- SSD ----
def ssd_chunked(x, dt, A, Bm, C, *, chunk: int,
                init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (Mamba-2 alg. 1, pure jnp).

    x (B,S,H,Pd); dt (B,S,H) post-softplus; A (H,) negative; Bm/C (B,S,G,N).
    Returns (y (B,S,H,Pd), final_state (B,H,Pd,N)).
    """
    b, s_len, h, pd = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-s_len) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk

    # chunked views: (B, nc, L, ...) -> scan over nc
    xc = x.reshape(b, nc, chunk, h, pd)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    a = dtc * A[None, None, None, :]                    # (B,nc,L,H) ≤ 0
    cum = jnp.cumsum(a, axis=2)                         # within-chunk cumsum
    seg_sum = cum[:, :, -1]                             # (B,nc,H)

    # --- intra-chunk (diagonal) term, computed for all chunks at once ---
    # decay L_mat[i,j] = exp(cum_i - cum_j) * dt_j for i >= j
    li = cum[:, :, :, None, :]                          # (B,nc,L,1,H)
    lj = cum[:, :, None, :, :]                          # (B,nc,1,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    decay = jnp.where(mask[None, None, :, :, None],
                      jnp.exp(li - lj), 0.0)            # (B,nc,L,L,H)
    scores = jnp.einsum("bclgn,bcmgn->bclmg", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))         # (B,nc,L,L,G)
    scores = jnp.repeat(scores, rep, axis=-1)           # -> heads
    w = scores * decay * dtc[:, :, None, :, :]          # (B,nc,L,L,H)
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", w, xc.astype(jnp.float32))

    # --- per-chunk input states: sum_j exp(cum_last - cum_j) dt_j B_j x_j ---
    dstate = jnp.exp(seg_sum[:, :, None, :] - cum) * dtc    # (B,nc,L,H)
    Bh = jnp.repeat(Bc, rep, axis=3)                        # (B,nc,L,H,N)
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                        dstate, Bh.astype(jnp.float32),
                        xc.astype(jnp.float32))             # (B,nc,H,Pd,N)

    # --- inter-chunk recurrence (scan over chunks) ---
    if init_state is None:
        init_state = jnp.zeros((b, h, pd, n), dtype=jnp.float32)

    def step(carry, inp):
        seg, st = inp                                   # (B,H), (B,H,Pd,N)
        new = jnp.exp(seg)[:, :, None, None] * carry + st
        return new, carry                               # emit state *before*

    seg_t = jnp.moveaxis(seg_sum, 1, 0)                 # (nc,B,H)
    st_t = jnp.moveaxis(states, 1, 0)                   # (nc,B,H,Pd,N)
    final, prevs = jax.lax.scan(step, init_state.astype(jnp.float32),
                                (seg_t, st_t))
    prev_states = jnp.moveaxis(prevs, 0, 1)             # (B,nc,H,Pd,N)

    # --- inter-chunk (off-diagonal) output: C_i · S_prev * exp(cum_i) ---
    Ch = jnp.repeat(Cc, rep, axis=3)                    # (B,nc,L,H,N)
    y_off = jnp.einsum("bclhn,bchpn->bclhp", Ch.astype(jnp.float32),
                       prev_states) * jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(b, nc * chunk, h, pd)[:, :s_len]
    return y.astype(x.dtype), final


def ssd_decode(x, dt, A, Bm, C, state):
    """Single-token SSD update.  x (B,H,Pd); dt (B,H); Bm/C (B,G,N);
    state (B,H,Pd,N) fp32.  Returns (y, new_state)."""
    h = x.shape[1]
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)    # (B,H,N)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    da = jnp.exp(dt * A[None, :])                           # (B,H)
    upd = (dt[:, :, None, None] * Bh[:, :, None, :]
           * x.astype(jnp.float32)[..., None])              # (B,H,Pd,N)
    new_state = da[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------- block ------
def _gated_norm(scale, y, z, eps):
    y = y * jax.nn.silu(z)
    return layers.rmsnorm({"scale": scale}, y, eps)


def _proj_all(params, cfg: ModelConfig, u):
    """u (B,S,D) -> z, xBC(conv in), dt."""
    s = cfg.ssm
    z = u @ params["wz"].astype(u.dtype)
    xp = u @ params["wx"].astype(u.dtype)
    Bp = u @ params["wB"].astype(u.dtype)
    Cp = u @ params["wC"].astype(u.dtype)
    dt = u @ params["wdt"].astype(u.dtype)
    return z, xp, Bp, Cp, dt


def mamba_apply(params, cfg: ModelConfig, u, *, init=None):
    """Full-sequence mamba2 block.  u (B,S,D) -> (out, final_cache).

    ``init``/returned cache: {"conv": (B,K-1,C), "ssm": (B,H,Pd,N) fp32}.
    """
    s = cfg.ssm
    b, sl, d = u.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    z, xp, Bp, Cp, dt = _proj_all(params, cfg, u)
    xbc = jnp.concatenate([xp, Bp, Cp], axis=-1)
    conv_state = None if init is None else init["conv"]
    xbc, conv_state = causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  state=conv_state)
    xp, Bp, Cp = jnp.split(xbc, [di, di + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    x4 = xp.reshape(b, sl, nh, s.head_dim)
    Bm = Bp.reshape(b, sl, s.n_groups, s.d_state)
    Cm = Cp.reshape(b, sl, s.n_groups, s.d_state)
    ssm_state = None if init is None else init["ssm"]
    y, final = ssd_chunked(x4, dt, A, Bm, Cm, chunk=s.chunk_size,
                           init_state=ssm_state)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * x4
    y = y.reshape(b, sl, di)
    y = _gated_norm(params["norm"]["scale"], y, z, cfg.norm_eps)
    out = y @ params["wo"].astype(y.dtype)
    return out, {"conv": conv_state, "ssm": final}


def mamba_decode(params, cfg: ModelConfig, u, cache):
    """One-token step.  u (B,1,D); cache {"conv","ssm"}."""
    s = cfg.ssm
    b, _, d = u.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    z, xp, Bp, Cp, dt = _proj_all(params, cfg, u)
    xbc = jnp.concatenate([xp, Bp, Cp], axis=-1)
    xbc, conv_state = causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  state=cache["conv"])
    xp, Bp, Cp = jnp.split(xbc, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]
    A = -jnp.exp(params["A_log"])
    y, new_state = ssd_decode(
        xp[:, 0].reshape(b, nh, s.head_dim), dt, A,
        Bp[:, 0].reshape(b, s.n_groups, s.d_state),
        Cp[:, 0].reshape(b, s.n_groups, s.d_state), cache["ssm"])
    y = y + params["D"][None, :, None].astype(y.dtype) \
        * xp[:, 0].reshape(b, nh, s.head_dim)
    y = y.reshape(b, 1, di)
    y = _gated_norm(params["norm"]["scale"], y, z, cfg.norm_eps)
    out = y @ params["wo"].astype(y.dtype)
    return out, {"conv": conv_state, "ssm": new_state}


def mamba_cache_init(cfg: ModelConfig, batch: int, *, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    gn = s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * gn), dtype=dtype),
        "ssm": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state),
                         dtype=jnp.float32),
    }


def mamba_cache_specs(cfg: ModelConfig, rules: MeshRules, batch: int) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    nh = s.n_heads(d)
    return {
        "conv": P(rules.batch(batch), None, None),
        "ssm": P(rules.batch(batch), rules.tp(nh), None, None),
    }
