"""Base layers: norms, dense projections, rotary/absolute embeddings.

Pure-functional JAX: every module is an ``init_*`` returning a params pytree
plus a ``*_specs`` returning the matching PartitionSpec pytree (a property
test asserts the trees are congruent for every architecture).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import MeshRules


# ---------------------------------------------------------------- dense ----
def dense_init(rng, in_dim: int, out_dim: int, *, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32)
            * scale).astype(dtype)


def bias_init(dim: int, *, dtype=jnp.float32):
    return jnp.zeros((dim,), dtype=dtype)


# ---------------------------------------------------------------- norms ----
def rmsnorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


def norm_apply(params, x, eps: float = 1e-5):
    if "bias" in params:
        return layernorm(params, x, eps)
    return rmsnorm(params, x, eps)


def norm_specs(params_like: dict) -> dict:
    return {k: P(None) for k in params_like}


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with even D; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                 # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int):
    """Whisper-style absolute sinusoidal embeddings (n_pos, dim)."""
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32)
                              / dim))
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


# ------------------------------------------------------------ embedding ----
def embed_init(rng, vocab: int, dim: int, *, dtype=jnp.float32):
    return {"table": (jax.random.normal(rng, (vocab, dim), dtype=jnp.float32)
                      * 0.02).astype(dtype)}


def embed_specs(rules: MeshRules, vocab: int, d_model: int) -> dict:
    # vocab rows FSDP-sharded + D on model when divisible: the lookup
    # gathers only the touched rows; under zero3 the table shards 256-way.
    return {"table": P(rules.fsdp(vocab), rules.tp(d_model))}


def embed_lookup(params, ids):
    return jnp.take(params["table"], ids, axis=0)
