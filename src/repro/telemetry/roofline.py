"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Sources:
  * ``compiled.cost_analysis()``   -> per-device HLO FLOPs + bytes accessed
    (calibrated: on an N-way SPMD program these are per-device numbers).
  * ``compiled.as_text()``         -> post-partitioning optimized HLO; we
    parse every collective op (shapes are per-device) for collective bytes.
  * ``compiled.memory_analysis()`` -> per-device argument/output/temp bytes.

Hardware model: TPU v5e —
  197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.

Terms (seconds, per the assignment formulas; collective bytes parsed from
the per-device SPMD module so chips cancels):
  compute    = HLO_FLOPs_per_device / peak
  memory     = HLO_bytes_per_device / hbm_bw
  collective = wire_bytes_per_device / link_bw
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

# `= <result-type> <op>(` where op may be the async `-start` variant.
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_TILED_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_naive: Dict[str, int] = field(default_factory=dict)  # Σ result sizes
    bytes_wire: Dict[str, float] = field(default_factory=dict)  # ring estimate

    @property
    def total_naive(self) -> int:
        return sum(self.bytes_naive.values())

    @property
    def total_wire(self) -> float:
        return sum(self.bytes_wire.values())

    def as_dict(self) -> Dict:
        return {"counts": self.counts, "bytes_naive": self.bytes_naive,
                "bytes_wire": self.bytes_wire,
                "total_naive": self.total_naive,
                "total_wire": self.total_wire}


def _group_size(line: str) -> int:
    m = _GROUPS_TILED_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_factor(op: str, g: int) -> float:
    """Ring-algorithm bytes-on-wire per participating device, as a factor of
    the *result* buffer size."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g              # result is the gathered (big) buffer
    if op == "reduce-scatter":
        return float(g - 1)             # result is the scattered (small) one
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        nbytes = _type_bytes(type_str)
        g = _group_size(line)
        st.counts[op] = st.counts.get(op, 0) + 1
        st.bytes_naive[op] = st.bytes_naive.get(op, 0) + nbytes
        st.bytes_wire[op] = (st.bytes_wire.get(op, 0.0)
                             + nbytes * _wire_factor(op, g))
    return st


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll: CollectiveStats
    chips: int
    model_flops: float = 0.0            # 6·N·D (or 2·N·D inference), global
    xla_flops: float = 0.0              # raw cost_analysis (loop bodies x1)
    xla_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll.total_wire / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilisation at the bound: what MFU would be if the
        dominant term ran at peak (the score we hillclimb)."""
        if self.bound_s <= 0:
            return 0.0
        return (self.model_flops / self.chips / PEAK_FLOPS) / self.bound_s

    def as_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.coll.as_dict(),
        }


def analyze(compiled, *, chips: int, model_flops: float = 0.0,
            discount_scope: Optional[str] = None,
            extra_bytes_per_device: float = 0.0) -> Roofline:
    """Roofline terms from the compiled SPMD module.

    FLOPs/bytes/collectives come from the trip-count-aware HLO walker
    (``repro.telemetry.hlo_cost``) — XLA's ``cost_analysis()`` counts while
    bodies once, which under a layers-scan is wrong by ~n_layers.  The raw
    XLA numbers are retained as ``xla_*`` for cross-checking loop-free
    programs.

    ``discount_scope``: zero out HBM bytes of named_scope-marked regions
    that execute as single Pallas kernels on the TPU target; the caller
    adds the kernel boundary traffic via ``extra_bytes_per_device``
    (see :func:`fused_boundary_bytes`)."""
    from repro.compat import normalize_cost_analysis
    from repro.telemetry import hlo_cost

    ca = normalize_cost_analysis(compiled.cost_analysis())
    totals = hlo_cost.analyze_text(compiled.as_text(),
                                   discount_scope=discount_scope)
    coll = CollectiveStats(
        counts={k: int(v) for k, v in totals.coll_counts.items()},
        bytes_naive={k: int(v) for k, v in totals.coll_bytes_naive.items()},
        bytes_wire=dict(totals.coll_bytes_wire))
    return Roofline(flops_per_device=totals.flops,
                    bytes_per_device=totals.bytes + extra_bytes_per_device,
                    coll=coll, chips=chips, model_flops=model_flops,
                    xla_flops=float(ca.get("flops", 0.0)),
                    xla_bytes=float(ca.get("bytes accessed", 0.0)))


def fused_boundary_bytes(cfg, shape, chips: int, *,
                         act_bytes: int = 2) -> float:
    """Per-device HBM boundary traffic of the fused attention kernels.

    Flash fwd reads q,k,v and writes o per layer; the bwd kernel reads
    q,k,v,o,do and writes dq,dk,dv (factor ~3.5 total for training).
    Decode reads the KV cache (the fundamental term) + writes one token.
    """
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for k in cfg.layer_kinds() if k != "mamba")
    if n_attn == 0:
        return 0.0
    h, kv = cfg.n_heads, cfg.n_kv_heads
    if shape.kind in ("train", "prefill"):
        per_token = (2 * h + 2 * kv) * hd * act_bytes   # q+o + k+v
        mult = 3.5 if shape.kind == "train" else 1.0
        total = (n_attn * shape.global_batch * shape.seq_len
                 * per_token * mult)
        if cfg.n_encoder_layers:                        # cross + encoder
            total *= 2
        return total / chips
    # decode: each step reads the whole (windowed) cache per layer
    kl = shape.seq_len
    if cfg.swa_window:
        kl = min(kl, cfg.swa_window)
    elif cfg.family == "hybrid":
        kl = min(kl, 4096)
    cache = n_attn * shape.global_batch * kl * 2 * kv * hd * act_bytes
    return cache / chips


def memory_stats(compiled) -> Dict[str, int]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def model_flops_for(cfg, shape, n_params_active: Optional[int] = None) -> float:
    """6·N·D train / 2·N·D single forward, D = global tokens this step."""
    n = n_params_active if n_params_active is not None else cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch
