"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count — under a layers-scan that under-counts an 80-layer model by 80x, and
the same bug hits any naive collective-bytes grep.  This walker parses the
post-partitioning HLO module, builds the call graph (while bodies, fusions,
calls, conditionals), multiplies every computation's cost by the product of
its ancestors' ``known_trip_count`` annotations, and accumulates:

  * flops            — 2·|out|·K for every dot (K = contracted extent);
                       |out| for elementwise at fusion granularity (minor)
  * bytes            — Σ(operands + outputs) at *fusion boundaries* (HBM
                       traffic model: fusion internals live in registers)
  * collectives      — per-op counts/bytes (naive = result sizes; wire =
                       ring-algorithm bytes on the link), trip-multiplied

Shapes in the SPMD module are per-device, so all results are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_VAR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_SIMPLE_TYPE_RE = re.compile(
    r"([a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(
    r"(?:body|calls|to_apply|condition|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_TILED_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d.strip())))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Op:
    var: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    callees: List[str] = field(default_factory=list)
    raw_operands: str = ""
    is_root: bool = False


@dataclass
class Computation:
    name: str
    params: Dict[str, str]               # param var -> type string
    ops: List[Op] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # var -> type


def _split_top(s: str) -> List[str]:
    """Split on commas at paren/brace depth 0."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def _split_operands_attrs(rest: str) -> Tuple[str, str]:
    """rest starts after the opening '(' of the op: 'operands), attrs'."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def _parse_op_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """Parse '%var = TYPE opcode(rest' -> (var, type_str, opcode, rest).

    Tuple types may embed /*index=N*/ comments (which contain '=' and ','),
    so the type is extracted with a balanced-paren scan, not a regex."""
    m = _VAR_RE.match(line)
    if not m:
        return None
    var = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        j = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    j = i
                    break
        if j < 0:
            return None
        type_str = rest[:j + 1]
        rest = rest[j + 1:].lstrip()
    else:
        m2 = _SIMPLE_TYPE_RE.match(rest)
        if not m2:
            return None
        type_str = m2.group(1)
        rest = rest[m2.end():]
    m3 = _OPCODE_RE.match(rest)
    if not m3:
        return None
    return var, type_str, m3.group(1), rest[m3.end():]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    name = m.group(1)
                    params = {}
                    for p in _split_top(m.group(2)):
                        if ":" in p:
                            pname, ptype = p.split(":", 1)
                            pname = pname.strip().lstrip("%")
                            params[pname] = ptype.strip()
                    cur = Computation(name=name, params=params,
                                      types=dict(params))
                    if line.strip().startswith("ENTRY"):
                        entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        var, type_str, opcode, rest = parsed
        operands_str, attrs = _split_operands_attrs(rest)
        operands = [o.split()[-1].lstrip("%")
                    for o in _split_top(operands_str)
                    if o.lstrip().startswith("%") or " %" in o]
        callees = []
        for g1, g2 in _CALLS_RE.findall(attrs):
            if g1:
                callees += [c.strip().lstrip("%") for c in g1.split(",")]
            elif g2:
                callees.append(g2)
        cur.types[var] = type_str
        cur.ops.append(Op(var=var, type_str=type_str, opcode=opcode,
                          operands=operands, attrs=attrs, callees=callees,
                          raw_operands=operands_str,
                          is_root="ROOT" in line.split("%")[0]))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_counts: Dict[str, float] = field(default_factory=dict)
    coll_bytes_naive: Dict[str, float] = field(default_factory=dict)
    coll_bytes_wire: Dict[str, float] = field(default_factory=dict)

    @property
    def coll_total_naive(self) -> float:
        return sum(self.coll_bytes_naive.values())

    @property
    def coll_total_wire(self) -> float:
        return sum(self.coll_bytes_wire.values())

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "coll_counts": self.coll_counts,
                "coll_bytes_naive": self.coll_bytes_naive,
                "coll_bytes_wire": self.coll_bytes_wire,
                "coll_total_naive": self.coll_total_naive,
                "coll_total_wire": self.coll_total_wire}


def _group_size(attrs: str) -> int:
    m = _GROUPS_TILED_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)
    if op in ("all-to-all", "ragged-all-to-all"):
        return (g - 1) / g
    return 1.0                           # collective-permute


# opcodes that move no bytes themselves
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "bitcast-convert", "after-all", "partition-id", "replica-id",
             "iota", "rng-bit-generator"}
_CONTROL_OPS = {"while", "call", "conditional", "fusion", "async-start",
                "async-done", "custom-call"}


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _type_elems(op.type_str)
    k = 1
    m = _CDIM_RE.search(op.attrs)
    if m and op.operands:
        lhs_t = comp.types.get(op.operands[0], "")
        shapes = _parse_shapes(lhs_t)
        if shapes:
            dims = shapes[0][1]
            for idx in m.group(1).split(","):
                if idx.strip() and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


class HloCost:
    """``discount_scope``: ops whose metadata op_name contains this marker
    are charged ZERO HBM bytes (flops still count).  The model wraps
    regions that execute as single Pallas kernels on the TPU target (flash
    attention, SSD) in ``jax.named_scope("vmem_fused_*")`` — their interior
    traffic lives in VMEM; the caller adds the kernel's boundary bytes
    back analytically (roofline.fused_boundary_bytes)."""

    def __init__(self, text: str, discount_scope: Optional[str] = None):
        self.comps = parse_module(text)
        self.totals = CostTotals()
        self.discount_scope = discount_scope
        self.discounted_bytes = 0.0
        self._memo: Dict[str, CostTotals] = {}
        if "__entry__" in self.comps:
            self._walk(self.comps["__entry__"].name, 1.0, self.totals,
                       inside_fusion=False)

    def _discounted(self, op: Op) -> bool:
        return (self.discount_scope is not None
                and self.discount_scope in op.attrs)

    # ------------------------------------------------------------------
    def _charge(self, acc: CostTotals, op: Op, amount: float) -> None:
        if self._discounted(op):
            self.discounted_bytes += amount
        else:
            acc.bytes += amount

    def _walk(self, comp_name: str, mult: float, acc: CostTotals, *,
              inside_fusion: bool):
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVES:
                nbytes = _type_bytes(op.type_str)
                g = _group_size(op.attrs)
                acc.coll_counts[base] = acc.coll_counts.get(base, 0) + mult
                acc.coll_bytes_naive[base] = (
                    acc.coll_bytes_naive.get(base, 0.0) + mult * nbytes)
                acc.coll_bytes_wire[base] = (
                    acc.coll_bytes_wire.get(base, 0.0)
                    + mult * nbytes * _wire_factor(base, g))
                if not inside_fusion:
                    self._charge(acc, op, mult * self._io_bytes(op, comp))
                continue
            if oc == "while":
                trip = 1
                m = _TRIP_RE.search(op.attrs)
                if m:
                    trip = int(m.group(1))
                for callee in op.callees:
                    self._walk(callee, mult * trip, acc,
                               inside_fusion=inside_fusion)
                continue
            if oc in ("call", "conditional"):
                for callee in op.callees:
                    self._walk(callee, mult, acc, inside_fusion=inside_fusion)
                continue
            if oc == "fusion":
                if not inside_fusion:
                    self._charge(acc, op, mult * self._fusion_io_bytes(op))
                # count dot flops inside the fused computation
                for callee in op.callees:
                    self._walk(callee, mult, acc, inside_fusion=True)
                continue
            if oc == "dot":
                acc.flops += mult * _dot_flops(op, comp)
                if not inside_fusion:
                    self._charge(acc, op, mult * self._io_bytes(op, comp))
                continue
            if oc in _FREE_OPS:
                continue
            if oc == "dynamic-update-slice":
                # in-place: traffic = update read + write, not the big buf
                upd = (comp.types.get(op.operands[1], "")
                       if len(op.operands) > 1 else op.type_str)
                if not inside_fusion:
                    self._charge(acc, op, mult * 2 * _type_bytes(upd))
                continue
            if oc in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced/gathered region, not the operand
                if not inside_fusion:
                    self._charge(acc, op, mult * 2 * _type_bytes(op.type_str))
                continue
            if oc == "scatter":
                # in-place contract: traffic = updates read + written region
                # + indices, NOT the full operand buffer
                upd = (comp.types.get(op.operands[2], "")
                       if len(op.operands) > 2 else "")
                idx = (comp.types.get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                if not inside_fusion:
                    self._charge(acc, op, mult * (2 * _type_bytes(upd)
                                                  + _type_bytes(idx)))
                continue
            # generic op: 1 flop/elem, operand+output traffic at top level
            acc.flops += mult * _type_elems(op.type_str)
            if not inside_fusion:
                self._charge(acc, op, mult * self._io_bytes(op, comp))
            if oc == "reduce" or oc == "sort" or oc == "scatter":
                for callee in op.callees:
                    self._walk(callee, mult, acc, inside_fusion=True)

    def _io_bytes(self, op: Op, comp: Computation) -> float:
        total = float(_type_bytes(op.type_str))
        for o in op.operands:
            total += _type_bytes(comp.types.get(o, ""))
        return total

    def _fusion_io_bytes(self, op: Op) -> float:
        """Slice-aware, convert-transparent traffic at a fusion boundary.

        A fused parameter consumed only by dynamic-slice/gather (possibly
        through dtype casts) reads only the slices; a fusion rooted at
        dynamic-update-slice/scatter writes only the update region; pure
        cast/copy fusions are free (fused into consumers on the TPU
        target — the CPU backend's bf16 legalization inserts them)."""
        fused = self.comps.get(op.callees[0]) if op.callees else None
        if fused is None:
            return float(_type_bytes(op.type_str)) * 2
        return (_fusion_reads(fused)
                + _fusion_writes(fused, float(_type_bytes(op.type_str))))

# ops that are looked through when attributing fused traffic: on the TPU
# target, dtype casts / layout bitcasts fuse into their consumers (the CPU
# backend's bf16->f32 legalization round-trips must not be charged)
_TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "transpose")


class _FusionView:
    """Use/def analysis inside one fused computation, convert-transparent."""

    def __init__(self, fused: Computation):
        self.fused = fused
        self.defs = {o.var: o for o in fused.ops}

    def effective_uses(self, var: str) -> List[Op]:
        out, frontier, seen = [], [var], set()
        while frontier:
            v = frontier.pop()
            for u in self.fused.ops:
                if v not in u.operands:
                    continue
                if u.opcode in _TRANSPARENT:
                    if u.var not in seen:
                        seen.add(u.var)
                        frontier.append(u.var)
                else:
                    out.append((v, u))
        return out

    def effective_root(self, op: Op) -> Op:
        seen = set()
        while (op.opcode in _TRANSPARENT and op.operands
               and op.operands[0] in self.defs
               and op.var not in seen):
            seen.add(op.var)
            op = self.defs[op.operands[0]]
        return op


def _fusion_reads(fused: Computation) -> float:
    view = _FusionView(fused)
    reads = 0.0
    for fop in fused.ops:
        if fop.opcode != "parameter":
            continue
        pvar = fop.var
        full = float(_type_bytes(fused.types.get(pvar, "")))
        uses = view.effective_uses(pvar)
        if not uses:
            continue                     # pure cast/copy: charged at root
        if all(u.opcode in ("dynamic-slice", "gather", "slice")
               or (u.opcode in ("dynamic-update-slice", "scatter")
                   and u.operands and u.operands[0] == via)
               for via, u in uses):
            part = 0.0
            for via, u in uses:
                if u.opcode in ("dynamic-update-slice", "scatter"):
                    continue             # pure write target: no read
                part += _type_bytes(u.type_str)
            reads += min(part, full)
        else:
            reads += full
    return reads


def _fusion_writes(fused: Computation, fallback: float) -> float:
    view = _FusionView(fused)
    root = next((o for o in fused.ops if o.is_root), None)
    if root is None:
        return fallback
    elems = []
    if root.opcode == "tuple":
        for ov in root.operands:
            d = view.defs.get(ov)
            elems.append(view.effective_root(d) if d is not None else root)
    else:
        elems = [view.effective_root(root)]
    writes = 0.0
    for r in elems:
        if r.opcode == "dynamic-update-slice" and len(r.operands) > 1:
            writes += _type_bytes(fused.types.get(r.operands[1], "")) or 0.0
        elif r.opcode == "scatter" and len(r.operands) > 2:
            writes += _type_bytes(fused.types.get(r.operands[2], "")) or 0.0
        elif r.opcode == "parameter":
            writes += 0.0                # pure pass-through/cast fusion
        else:
            writes += _type_bytes(r.type_str)
    return writes


def analyze_text(text: str, discount_scope: Optional[str] = None
                 ) -> CostTotals:
    return HloCost(text, discount_scope=discount_scope).totals
