"""Roofline report generator: dry-run JSON cache -> markdown tables.

    PYTHONPATH=src python -m repro.telemetry.report > experiments/ROOFLINE.md
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load(mesh: str) -> List[Dict]:
    out = []
    d = DRYRUN / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.2e}"
    return f"{x:.3f}"


def table(mesh: str) -> str:
    rows = load(mesh)
    lines = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | variant | status | compute_s | memory_s | "
        "collective_s | dominant | useful | frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in rows:
        tag = rec.get("tag", "") or "baseline"
        if rec["status"] == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {tag} | skipped | - | -"
                f" | - | - | - | - | {rec.get('reason', '')[:60]} |")
            continue
        if rec["status"] != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {tag} | ERROR | - | -"
                f" | - | - | - | - | {rec.get('error', '')[:60]} |")
            continue
        r = rec["roofline"]
        note = ""
        if tag != "baseline":
            note = ", ".join(f"{k}={v}" for k, v in
                             rec.get("bundle_kw", {}).items())[:60]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {tag} | ok | "
            f"{_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} | "
            f"{_fmt_s(r['collective_s'])} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
            f" {note} |")
    lines.append("")
    return "\n".join(lines)


def main() -> int:
    print("# Roofline report (generated from experiments/dryrun/)\n")
    print("Terms per §Roofline: seconds/step/device on TPU v5e constants "
          "(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI); "
          "`useful` = MODEL_FLOPS / compiled FLOPs; `frac` = useful-MFU "
          "at the dominant bound.\n")
    for mesh in ("pod", "multipod"):
        print(table(mesh))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
