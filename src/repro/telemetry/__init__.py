from repro.telemetry import hlo_cost, roofline
__all__ = ["hlo_cost", "roofline"]
