"""Fleet control plane: placement, health sweeps, pre-copy auto-migration.

``FleetController`` is the cloud-provisioning layer over a pool of
``Shell``s (the RC3E framing): score-based placement of new tenants,
periodic health/QoS sweeps, and controller-triggered live migration off
hotspots and wedged members — pre-copy by default, so the service gap
is O(dirty delta).
"""
from repro.fleet.controller import FleetController, FleetDecision

__all__ = ["FleetController", "FleetDecision"]
