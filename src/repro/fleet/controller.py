"""FleetController: decides *when* and *where* tenants move.

PR 5 made ``migrate()`` a manual verb; this module is the control plane
that drives it (and its pre-copy successor) automatically:

- **Placement** — ``place(pages_needed)`` scores every member by free
  KV-page fraction minus a recent-fault penalty (``HealthMonitor.
  recent_faults``) and returns the best shell with capacity.  Members
  that cannot fit the tenant are excluded outright, not down-scored.
- **Sweeps** — ``sweep()`` is the reconcile loop body: every member's
  ``check_health`` runs first (wedged slots are recovered in place via
  ``Shell.recover_slot``, or migrated off when recovery fails), then
  hotspots (aggregate page utilization above ``hot_util``) shed their
  largest tenant to a colder member with capacity.  Moves use
  :func:`repro.core.migrate.migrate_precopy` unless ``precopy=False``.
- **Stream re-routing** — when both members have a registered
  ``ServingGateway`` (``attach_gateway``), a successful move re-homes
  the tenant's live ``TokenStream``s onto the destination gateway
  (``adopt_streams``): readers keep their stream objects, tokens keep
  flowing, exactly once.

Every action (including failed ones) is recorded as a
:class:`FleetDecision` — the controller's audit log.

Engines on different members must use disjoint ``rid_base`` ranges
(the same rule every cross-shell migration already has).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.migrate import MigrationError, migrate, migrate_precopy

__all__ = ["FleetController", "FleetDecision"]


@dataclass
class FleetDecision:
    """One controller action: what it did, to whom, and why."""
    action: str                       # "place" | "migrate" | "recover"
    tenant: Optional[str] = None
    src: Optional[str] = None         # member name
    dst: Optional[str] = None
    reason: str = ""
    ok: bool = True
    error: str = ""
    report: Any = None                # MigrationReport / RecoveryReport

    def to_dict(self) -> Dict[str, Any]:
        return {"action": self.action, "tenant": self.tenant,
                "src": self.src, "dst": self.dst, "reason": self.reason,
                "ok": self.ok, "error": self.error}


class FleetController:
    """Control plane over a pool of shells.

    ``engine_factory(shell, slot) -> ServingEngine`` lets the controller
    materialize a destination engine on a free vFPGA slot when no idle
    matching-geometry engine exists on the chosen member (the factory
    must bind the engine to the shell/slot, which ``ServingEngine(
    shell=..., slot=...)`` does by construction).
    """

    def __init__(self, *, precopy: bool = True, hot_util: float = 0.85,
                 cold_util: float = 0.60, fault_window_s: float = 30.0,
                 max_moves_per_sweep: int = 1, drain_timeout: float = 30.0,
                 auto_recover: bool = True,
                 engine_factory: Optional[Callable] = None):
        self.precopy = precopy
        self.hot_util = hot_util
        self.cold_util = cold_util
        self.fault_window_s = fault_window_s
        self.max_moves_per_sweep = max_moves_per_sweep
        self.drain_timeout = drain_timeout
        self.auto_recover = auto_recover
        self.engine_factory = engine_factory
        self.shells: List[Any] = []
        self.decisions: List[FleetDecision] = []
        self._gateways: Dict[str, Any] = {}       # member name -> gateway

    # ------------------------------------------------------------ members --
    def add_shell(self, shell) -> None:
        if any(s.name == shell.name for s in self.shells):
            raise ValueError(f"duplicate fleet member name {shell.name!r}")
        self.shells.append(shell)

    def attach_gateway(self, shell, gateway) -> None:
        """Register the member's serving gateway so migrations re-route
        its live token streams."""
        self._gateways[shell.name] = gateway

    def member_load(self, shell) -> Dict[str, Any]:
        """Aggregate paged-memory load of one member (each engine-owned
        MMU counted once, plus the shell's own mmu service)."""
        mmus = {}
        for eng in shell.engines.values():
            mmus[id(eng.mmu)] = eng.mmu
        if "mmu" in shell.services.names():
            svc = shell.services.get("mmu")
            mmus.setdefault(id(svc), svc)
        total = used = seqs = dirty = 0
        for mmu in mmus.values():
            u = mmu.utilization()
            total += u["pages_total"]
            used += u["pages_used"]
            seqs += u["sequences"]
            dirty += u.get("dirty_pages", 0)
        return {
            "name": shell.name,
            "pages_total": total, "pages_used": used,
            "pages_free": total - used, "sequences": seqs,
            "dirty_pages": dirty,
            "util": used / max(total, 1),
            "recent_faults": shell.health.recent_faults(
                self.fault_window_s),
        }

    # ---------------------------------------------------------- placement --
    def placement_score(self, shell, pages_needed: int = 0
                        ) -> Optional[float]:
        """Higher is better; None means the member is excluded (cannot
        fit the tenant).  Free-page fraction dominates; recent faults
        subtract a fixed penalty each so a flapping member loses to a
        clean one at equal occupancy."""
        load = self.member_load(shell)
        if pages_needed and load["pages_free"] < pages_needed:
            return None
        return (load["pages_free"] / max(load["pages_total"], 1)
                - 0.1 * load["recent_faults"])

    def place(self, pages_needed: int = 0, *,
              exclude=()) -> Optional[Any]:
        """The best member for a new ``pages_needed``-page tenant (None
        when nobody has capacity).  Records a ``place`` decision."""
        best, best_score = None, None
        for shell in self.shells:
            if shell in exclude or shell.name in exclude:
                continue
            score = self.placement_score(shell, pages_needed)
            if score is not None and (best_score is None
                                      or score > best_score):
                best, best_score = shell, score
        self.decisions.append(FleetDecision(
            action="place", dst=best.name if best else None,
            ok=best is not None,
            reason=f"pages_needed={pages_needed} score={best_score}"))
        return best

    # ------------------------------------------------------------- sweeps --
    def sweep(self) -> List[FleetDecision]:
        """One reconcile pass: heal wedged slots, then cool hotspots.
        Returns the decisions taken this pass (also appended to
        ``self.decisions``)."""
        out: List[FleetDecision] = []
        moves = 0
        for shell in self.shells:
            hc = shell.check_health(auto_recover=False)
            for slot in hc["wedged"]:
                d = self._heal(shell, slot)
                out.append(d)
                if d.action == "migrate" and d.ok:
                    moves += 1
        for shell in self.shells:
            if moves >= self.max_moves_per_sweep:
                break
            load = self.member_load(shell)
            if load["util"] <= self.hot_util:
                continue
            d = self._cool_hotspot(shell, load)
            if d is not None:
                out.append(d)
                if d.ok:
                    moves += 1
        self.decisions.extend(out)
        return out

    def _heal(self, shell, slot: int) -> FleetDecision:
        """A wedged slot: recover in place; if that fails, evacuate the
        tenant to another member (the slot itself is suspect)."""
        eng = shell.engines.get(slot)
        tenant = getattr(eng, "tenant", None) if eng is not None else None
        if self.auto_recover:
            try:
                rep = shell.recover_slot(slot,
                                         drain_timeout=self.drain_timeout)
                return FleetDecision(action="recover", tenant=tenant,
                                     src=shell.name, reason="wedged",
                                     report=rep)
            except Exception as e:  # noqa: BLE001 — recovery failing is
                # exactly the case the fleet exists for: migrate off
                err = str(e)
        else:
            err = "auto_recover disabled"
        d = self._migrate_off(shell, slot, reason=f"wedged ({err})")
        d.tenant = d.tenant or tenant
        return d

    def _cool_hotspot(self, shell, load) -> Optional[FleetDecision]:
        """Shed the hot member's largest tenant to a colder member."""
        victims = []
        for slot, eng in shell.engines.items():
            rids = [r.rid for r in eng.slots if r is not None]
            pages = len(eng.mmu.live_page_keys(rids)) if rids else 0
            if pages:
                victims.append((pages, slot))
        for pages, slot in sorted(victims, reverse=True):
            d = self._migrate_off(
                shell, slot, min_pages=pages,
                reason=f"hotspot util={load['util']:.2f}")
            if d is not None:
                return d
        return None

    def _migrate_off(self, src_shell, slot: int, *, min_pages: int = 0,
                     reason: str = "") -> Optional[FleetDecision]:
        """Move the tenant on ``src_shell[slot]`` to the best other
        member that can take it; None when no candidate exists AND the
        call came from hotspot cooling (healing always records)."""
        eng = src_shell.engines.get(slot)
        tenant = getattr(eng, "tenant", None) if eng is not None else None
        candidates = []
        for dst in self.shells:
            if dst is src_shell:
                continue
            score = self.placement_score(dst, min_pages)
            dload = self.member_load(dst)
            if score is None or dload["util"] >= self.cold_util:
                continue
            candidates.append((score, dst))
        if not candidates:
            return FleetDecision(
                action="migrate", tenant=tenant, src=src_shell.name,
                ok=False, reason=reason,
                error="no member with capacity below cold_util")
        candidates.sort(key=lambda c: c[0], reverse=True)
        _, dst_shell = candidates[0]
        dslot = self._dst_slot_for(dst_shell, eng)
        if dslot is None:
            return FleetDecision(
                action="migrate", tenant=tenant, src=src_shell.name,
                dst=dst_shell.name, ok=False, reason=reason,
                error="no idle matching-geometry engine on destination "
                      "(pass engine_factory= to create one)")
        mover = migrate_precopy if self.precopy else migrate
        try:
            rep = mover(src_shell, dst_shell, slot, dst_slot=dslot,
                        drain_timeout=self.drain_timeout)
        except MigrationError as e:
            return FleetDecision(
                action="migrate", tenant=tenant, src=src_shell.name,
                dst=dst_shell.name, ok=False, reason=reason,
                error=str(e))
        self._reroute(src_shell, dst_shell)
        return FleetDecision(
            action="migrate", tenant=rep.tenant, src=src_shell.name,
            dst=dst_shell.name, reason=reason, report=rep)

    def migrate_tenant(self, tenant: str, dst_shell=None) -> FleetDecision:
        """Operator verb: move ``tenant`` (found by name) to
        ``dst_shell`` or the best-scoring member."""
        for shell in self.shells:
            for slot, eng in shell.engines.items():
                if getattr(eng, "tenant", None) == tenant:
                    if dst_shell is None:
                        d = self._migrate_off(shell, slot,
                                              reason="operator")
                    else:
                        d = self._move_to(shell, slot, dst_shell,
                                          reason="operator")
                    self.decisions.append(d)
                    return d
        raise KeyError(f"no member serves tenant {tenant!r}")

    def _move_to(self, src_shell, slot: int, dst_shell, *,
                 reason: str) -> FleetDecision:
        eng = src_shell.engines.get(slot)
        tenant = getattr(eng, "tenant", None) if eng is not None else None
        dslot = self._dst_slot_for(dst_shell, eng)
        if dslot is None:
            return FleetDecision(
                action="migrate", tenant=tenant, src=src_shell.name,
                dst=dst_shell.name, ok=False, reason=reason,
                error="no idle matching-geometry engine on destination")
        mover = migrate_precopy if self.precopy else migrate
        try:
            rep = mover(src_shell, dst_shell, slot, dst_slot=dslot,
                        drain_timeout=self.drain_timeout)
        except MigrationError as e:
            return FleetDecision(
                action="migrate", tenant=tenant, src=src_shell.name,
                dst=dst_shell.name, ok=False, reason=reason,
                error=str(e))
        self._reroute(src_shell, dst_shell)
        return FleetDecision(
            action="migrate", tenant=rep.tenant, src=src_shell.name,
            dst=dst_shell.name, reason=reason, report=rep)

    def _dst_slot_for(self, dst_shell, src_engine) -> Optional[int]:
        """An idle destination engine with matching geometry, or a
        fresh one from ``engine_factory`` on a free vFPGA slot."""
        if src_engine is None:
            return None
        geo = src_engine.geometry()
        for dslot, eng in sorted(dst_shell.engines.items()):
            if (eng is not src_engine and eng.geometry() == geo
                    and eng.active == 0 and not eng.queue):
                return dslot
        if self.engine_factory is not None:
            for dslot in range(dst_shell.config.n_vfpgas):
                if dslot not in dst_shell.engines:
                    self.engine_factory(dst_shell, dslot)
                    return dslot
        return None

    def _reroute(self, src_shell, dst_shell) -> None:
        gsrc = self._gateways.get(src_shell.name)
        gdst = self._gateways.get(dst_shell.name)
        if gsrc is not None and gdst is not None and gsrc is not gdst:
            gdst.adopt_streams(gsrc)

    # -------------------------------------------------------------- status --
    def status(self) -> Dict[str, Any]:
        return {
            "members": [self.member_load(s) for s in self.shells],
            "decisions": [d.to_dict() for d in self.decisions[-20:]],
            "moves": sum(1 for d in self.decisions
                         if d.action == "migrate" and d.ok),
            "recoveries": sum(1 for d in self.decisions
                              if d.action == "recover" and d.ok),
        }
