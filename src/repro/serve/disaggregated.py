"""Prefill/decode disaggregation across the `pod` axis.

The paper's RDMA story at LLM scale: pod 0 runs compute-bound prefill,
pod 1 runs memory-bound decode, and the prefilled KV cache crosses the pod
boundary through the collective service's queue pairs — a one-sided
``rdma_write`` (collective_permute on the `pod` axis), exactly the
Coyote v2 networking service pattern (§6.2: the stack does "on-datapath
custom off-loads", here the off-load is the KV hand-off).

``make_handoff_fn`` builds the pjit-able transfer: inside shard_map over
the pod axis, the prefill pod sends its cache shard and the decode pod
receives it; intra-pod shardings (batch on data, seq on model) pass
through untouched, so the wire volume is exactly one cache copy over the
inter-pod links.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.services.collectives import CollectiveConfig, CollectiveService


def make_handoff_fn(mesh, svc: CollectiveService = None, *,
                    pod_axis: str = "pod"):
    """Returns handoff(cache_pytree) -> cache_pytree where every leaf has
    pod 0's data delivered to pod 1 (pod 0 keeps its copy: one-sided
    write semantics).  Leaves keep their intra-pod sharding."""
    svc = svc or CollectiveService(CollectiveConfig(pod_axis=pod_axis))
    qp = svc.create_qp(0, 1)
    n_pods = mesh.shape[pod_axis]
    assert n_pods >= 2, "disaggregation needs a multi-pod mesh"

    def _leaf_handoff(x):
        """x dim0 is pod-sharded: pod 0's rows = freshly prefilled KV,
        pod 1's rows = its decode pool.  After handoff, pod 1's rows hold
        pod 0's data (one-sided write); pod 0 keeps its copy."""
        def local(v):
            sent = svc.rdma_write(v, qp, pod_axis=pod_axis)
            idx = jax.lax.axis_index(pod_axis)
            return jnp.where(idx > 0, sent, v)
        return shard_map(local, mesh=mesh,
                         in_specs=P(pod_axis),
                         out_specs=P(pod_axis),
                         check_rep=False)(x)

    def handoff(cache):
        return jax.tree.map(_leaf_handoff, cache)

    return handoff, qp


def handoff_wire_bytes(cache, n_pods: int = 2) -> float:
    """Modeled inter-pod bytes: one copy of the prefill pod's cache."""
    total = sum(x.nbytes for x in jax.tree.leaves(cache))
    return total / n_pods     # only the prefill pod's shard crosses
