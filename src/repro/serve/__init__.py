from repro.serve.engine import Request, ServingEngine
from repro.serve.paged_model import decode_step_paged, make_pools, write_prefill
from repro.serve.sampler import SamplerConfig, sample
from repro.serve.disaggregated import handoff_wire_bytes, make_handoff_fn
__all__ = ["Request", "ServingEngine", "decode_step_paged", "make_pools",
           "write_prefill", "SamplerConfig", "sample",
           "handoff_wire_bytes", "make_handoff_fn"]
