from repro.serve.engine import Request, ServingEngine
from repro.serve.gateway import ServingGateway, TokenStream
from repro.serve.paged_model import (TRACE_COUNTS, decode_step_paged,
                                     make_pools, prefill_chunk_paged,
                                     prefill_paged, write_prefill)
from repro.serve.sampler import (SamplerConfig, fold_row_keys, sample,
                                 sample_per_row)
from repro.serve.disaggregated import handoff_wire_bytes, make_handoff_fn
__all__ = ["Request", "ServingEngine", "ServingGateway", "TokenStream",
           "decode_step_paged", "make_pools", "prefill_chunk_paged",
           "prefill_paged", "write_prefill", "TRACE_COUNTS",
           "SamplerConfig", "fold_row_keys", "sample", "sample_per_row",
           "handoff_wire_bytes", "make_handoff_fn"]
