"""Serving gateway: the always-on front door of the paged engine.

The missing piece between "benchmark harness" and "serving system":
production traffic is an *open arrival* process — requests show up on
their own clock, carrying their own SLOs — while the engine underneath
admits in slot-granular steps.  The gateway bridges the two:

  * **Continuous batching.**  A completed engine row is backfilled from
    the gateway queue at the very next step (the engine's
    ``admission_hook`` runs before every ``_admit``), instead of waiting
    for the whole wave to drain.  ``mode="wave"`` keeps the old
    admit-everything-when-idle behaviour — it exists so the benchmark
    can measure exactly what continuous batching buys.
  * **Token streams out.**  ``submit()`` returns a :class:`TokenStream`
    that fills live as the engine emits tokens (the engine's
    ``token_sink`` hook), with per-request TTFT/TPOT measured from
    *arrival* — gateway queueing time is part of the user's latency,
    unlike the engine-side view which starts at engine admission.
  * **SLO-aware admission.**  With ``admission="slo"`` each request's
    relative ``deadline_s`` is checked at the door against the engine's
    measured prefill/decode step-time EWMAs: a deadline that cannot be
    met even if the request ran alone is rejected immediately with a
    typed ``PortError(kind=SLO_INFEASIBLE)`` — failing fast beats
    burning page-credits on a guaranteed miss.  Queued requests whose
    deadline passes are expired (``SLO_EXPIRED``) before they waste a
    prefill.  Queued priorities *age* as slack shrinks, and dispatch
    order is (effective priority, deadline slack, arrival) — a gold
    request with a tight deadline leapfrogs best-effort traffic without
    starving it (aging is bounded).
  * **Port-billed admission.**  When the engine is shell-bound, every
    accepted request is billed through ``port.submit`` as a
    ``gateway_admit`` IO invocation — quarantine, fault injection, DWRR
    credits and QoS accounting all apply to the front door exactly as
    they do to decode-step IO.

Everything is driven synchronously from ``step()``/``drain()`` — the
gateway adds no threads; an async transport would sit on top of it and
call the same entry points.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.faults import FaultKind
from repro.core.port import Invocation, PortError


@dataclass
class TokenStream:
    """Per-request output handle: fills live while the gateway pumps."""
    gid: int                              # gateway sequence number
    prompt_len: int
    max_new_tokens: int
    priority: int = 0
    deadline: float = math.inf            # absolute perf_counter time
    tid: int = 0
    rid: Optional[int] = None             # engine rid once dispatched
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    error: Optional[PortError] = None
    t_arrival: float = 0.0
    t_first: float = 0.0                  # first token (from arrival)
    t_done: float = 0.0
    eff_priority: int = 0                 # last aged priority (observable)

    @property
    def rejected(self) -> bool:
        return self.error is not None

    @property
    def met_deadline(self) -> bool:
        return (self.done and self.error is None
                and self.t_done <= self.deadline)

    def ttft(self) -> Optional[float]:
        return (self.t_first - self.t_arrival) if self.t_first > 0 else None

    def tpot(self) -> Optional[float]:
        n = len(self.tokens) - 1
        if self.t_done > 0 and self.t_first > 0 and n > 0:
            return (self.t_done - self.t_first) / n
        return None


@dataclass
class _Pending:
    """A queued arrival the gateway has accepted but not yet dispatched."""
    stream: TokenStream
    prompt: List[int]
    temperature: float
    top_k: int
    top_p: float


class ServingGateway:
    """Open-arrival frontend over one :class:`ServingEngine`.

    mode       -- "continuous" (backfill every step) | "wave" (admit
                  only when the engine is fully idle; the A/B baseline).
    admission  -- "slo" (feasibility checks, expiry, aging, slack
                  ordering) | "fifo" (arrival order, no rejection).
    max_queue  -- backpressure bound; arrivals beyond it are rejected
                  with retryable ``GATEWAY_FULL`` (0 = unbounded).
    headroom   -- feasibility margin: reject when
                  ``arrival + headroom * service_estimate > deadline``.
    min_obs    -- EWMA warm-up: no feasibility rejection until the
                  engine has at least this many prefill AND decode
                  timing observations (cold estimates reject wrongly).
    aging_max  -- bound on the deadline-driven priority boost.
    aging_window_s -- slack below which aging kicks in (boost scales
                  linearly from 0 at the window edge to aging_max at
                  zero slack).
    """

    def __init__(self, engine, *, mode: str = "continuous",
                 admission: str = "slo", max_queue: int = 0,
                 headroom: float = 1.5, min_obs: int = 3,
                 aging_max: int = 4, aging_window_s: float = 1.0):
        assert mode in ("continuous", "wave"), mode
        assert admission in ("slo", "fifo"), admission
        self.engine = engine
        self.mode = mode
        self.admission = admission
        self.max_queue = max_queue
        self.headroom = headroom
        self.min_obs = min_obs
        self.aging_max = aging_max
        self.aging_window_s = aging_window_s
        self.queue: List[_Pending] = []
        self.streams: Dict[int, TokenStream] = {}     # engine rid -> stream
        self.completed: List[TokenStream] = []
        self.rejected: List[TokenStream] = []
        self._gid_next = 0
        self._admit_futs: List = []
        # counters (stats())
        self.submitted = 0
        self.dispatched = 0
        self.rejected_infeasible = 0
        self.rejected_full = 0
        self.expired = 0
        self.t_open = time.perf_counter()
        engine.admission_hook = self._backfill
        engine.token_sink = self._on_token

    # ------------------------------------------------------------ intake ---
    def _service_estimate(self, prompt_len: int,
                          max_new_tokens: int) -> Optional[float]:
        """Best-case seconds to serve the request alone, from measured
        EWMAs; None while the engine's timing model is cold."""
        eng = self.engine
        if (eng.ewma_prefill_s_per_tok is None
                or eng.ewma_decode_step_s is None
                or eng.prefill_obs < self.min_obs
                or eng.decode_obs < self.min_obs):
            return None
        return (eng.ewma_prefill_s_per_tok * prompt_len
                + eng.ewma_decode_step_s * max_new_tokens)

    def submit(self, prompt: List[int], max_new_tokens: int = 16, *,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
               tid: int = 0, priority: int = 0,
               deadline_s: Optional[float] = None) -> TokenStream:
        """Accept (or reject, typed) one arriving request.

        Raises ``PortError`` with kind ``GATEWAY_FULL`` (retryable — the
        queue bound is load, not damage), ``SLO_INFEASIBLE`` (the
        deadline cannot be met even unqueued), or ``QUARANTINED``
        (propagated from the billing port for a quarantined tenant).
        """
        now = time.perf_counter()
        self.submitted += 1
        gid = self._gid_next
        self._gid_next += 1
        stream = TokenStream(
            gid=gid, prompt_len=len(prompt), max_new_tokens=max_new_tokens,
            priority=priority, eff_priority=priority, tid=tid,
            deadline=(now + deadline_s if deadline_s is not None
                      else math.inf),
            t_arrival=now)
        if self.max_queue and len(self.queue) >= self.max_queue:
            self.rejected_full += 1
            stream.error = PortError(
                f"gateway queue full ({self.max_queue}); retry later",
                kind=FaultKind.GATEWAY_FULL, slot=self.engine.slot,
                tenant=self.engine.tenant, retryable=True)
            self.rejected.append(stream)
            raise stream.error
        if self.admission == "slo" and deadline_s is not None:
            est = self._service_estimate(len(prompt), max_new_tokens)
            if est is not None and now + self.headroom * est > stream.deadline:
                self.rejected_infeasible += 1
                stream.error = PortError(
                    f"deadline {deadline_s:.3f}s infeasible: best-case "
                    f"service estimate {est:.3f}s (x{self.headroom} "
                    "headroom) — rejected at admission",
                    kind=FaultKind.SLO_INFEASIBLE, slot=self.engine.slot,
                    tenant=self.engine.tenant, retryable=False)
                self.rejected.append(stream)
                raise stream.error
        # bill the accepted admission through the unified port: the
        # shell's quarantine / fault-injection / DWRR paths all see the
        # front door.  A quarantined tenant is rejected right here.
        if self.engine.port is not None:
            fut = self.engine.port.submit(Invocation.io(
                max(len(prompt), 1) * 4, tag="gateway_admit",
                tenant=self.engine.tenant, priority=priority,
                deadline_s=deadline_s))
            self._admit_futs.append(fut)
        self.queue.append(_Pending(stream=stream, prompt=list(prompt),
                                   temperature=temperature, top_k=top_k,
                                   top_p=top_p))
        return stream

    # -------------------------------------------------------- scheduling ---
    def _aged_priority(self, stream: TokenStream, now: float,
                       est: Optional[float]) -> int:
        """Deadline-driven aging: boost grows linearly as slack (time to
        deadline minus estimated service time) shrinks inside the aging
        window, bounded by ``aging_max``.  No deadline -> no aging."""
        if math.isinf(stream.deadline) or self.aging_max <= 0:
            return stream.priority
        slack = stream.deadline - now - (est or 0.0)
        if slack >= self.aging_window_s:
            return stream.priority
        frac = 1.0 - max(slack, 0.0) / self.aging_window_s
        return stream.priority + min(self.aging_max,
                                     int(math.ceil(frac * self.aging_max)))

    def _slack(self, stream: TokenStream, now: float,
               est: Optional[float]) -> float:
        if math.isinf(stream.deadline):
            return math.inf
        return stream.deadline - now - (est or 0.0)

    def _backfill(self, engine) -> None:
        """Engine admission hook — runs before ``_admit`` every step.

        Expires dead entries, ages priorities, orders the queue by
        (effective priority desc, deadline slack asc, arrival asc), and
        feeds the engine exactly as many requests as it can place this
        step (continuous) or a full wave when idle (wave)."""
        if not self.queue:
            return
        now = time.perf_counter()
        if self.admission == "slo":
            alive: List[_Pending] = []
            for p in self.queue:
                if now > p.stream.deadline:
                    self.expired += 1
                    p.stream.error = PortError(
                        "deadline expired while queued",
                        kind=FaultKind.SLO_EXPIRED, slot=engine.slot,
                        tenant=engine.tenant, retryable=False)
                    self.rejected.append(p.stream)
                else:
                    alive.append(p)
            self.queue = alive
            if not self.queue:
                return
            keyed = []
            for p in self.queue:
                est = self._service_estimate(p.stream.prompt_len,
                                             p.stream.max_new_tokens)
                p.stream.eff_priority = self._aged_priority(
                    p.stream, now, est)
                keyed.append((-p.stream.eff_priority,
                              self._slack(p.stream, now, est),
                              p.stream.gid, p))
            keyed.sort(key=lambda t: t[:3])
            self.queue = [t[3] for t in keyed]
        if self.mode == "wave":
            # wave baseline: a new wave only once the engine fully drains
            if engine.active > 0 or engine.queue:
                return
            n = min(engine.max_batch, len(self.queue))
        else:
            free = engine.max_batch - engine.active
            n = max(0, min(free - len(engine.queue), len(self.queue)))
        for p in self.queue[:n]:
            rid = engine.submit(
                p.prompt, p.stream.max_new_tokens,
                temperature=p.temperature, top_k=p.top_k, top_p=p.top_p,
                tid=p.stream.tid, priority=p.stream.eff_priority,
                deadline_s=(None if math.isinf(p.stream.deadline)
                            else p.stream.deadline))
            p.stream.rid = rid
            self.streams[rid] = p.stream
            self.dispatched += 1
        del self.queue[:n]

    def _on_token(self, req, token: int, done: bool) -> None:
        """Engine token sink: route every emitted token to its stream."""
        stream = self.streams.get(req.rid)
        if stream is None:
            return
        stream.tokens.append(token)
        now = time.perf_counter()
        if stream.t_first == 0.0:
            stream.t_first = now
        if done and not stream.done:
            stream.done = True
            stream.t_done = now
            self.completed.append(stream)
            del self.streams[req.rid]

    def adopt_streams(self, src: "ServingGateway") -> Dict[str, int]:
        """Take over another gateway's live ``TokenStream``s after its
        tenant migrated to OUR engine.

        Request ids survive ``restore_state`` (in-flight and demoted
        chunk-prefill requests keep their rids), so moving the rid ->
        stream map is all the re-route needs: the next token our engine
        emits for a moved rid lands in the SAME ``TokenStream`` object
        the caller has been reading — no token lost, none duplicated.
        Gateway-queued (not yet dispatched) pendings move too and will
        dispatch here with fresh rids.  Already-completed streams stay
        with the source gateway's history."""
        n_streams, n_queued = len(src.streams), len(src.queue)
        self.streams.update(src.streams)
        src.streams.clear()
        self.queue.extend(src.queue)
        src.queue.clear()
        self.submitted += n_streams + n_queued
        return {"streams": n_streams, "queued": n_queued}

    # ------------------------------------------------------------- drive ---
    def step(self) -> int:
        """One engine step (backfill runs inside via the hook)."""
        return self.engine.step()

    def pending(self) -> bool:
        return bool(self.queue) or self.engine.pending()

    def drain(self, max_steps: int = 100_000) -> None:
        """Pump until every accepted request has completed or expired."""
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            steps += 1
        self._settle_admit_io()

    def _settle_admit_io(self) -> None:
        if self._admit_futs:
            self._admit_futs = [f for f in self._admit_futs
                                if not f.done()]

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict[str, float]:
        """Gateway-side QoS view: goodput (deadline-met completions per
        second), TTFT/TPOT percentiles measured from ARRIVAL, and the
        admission-control counters."""
        now = time.perf_counter()
        wall = max(now - self.t_open, 1e-9)
        met = sum(1 for s in self.completed if s.met_deadline)
        out: Dict[str, float] = {
            "submitted": self.submitted,
            "dispatched": self.dispatched,
            "completed": len(self.completed),
            "met_deadline": met,
            "goodput": met / wall,
            "throughput": len(self.completed) / wall,
            "rejected_infeasible": self.rejected_infeasible,
            "rejected_full": self.rejected_full,
            "expired": self.expired,
            "queued": len(self.queue),
            "wall_s": wall,
        }
        ttfts = [s.ttft() for s in self.completed if s.ttft() is not None]
        tpots = [s.tpot() for s in self.completed if s.tpot() is not None]
        if ttfts:
            out["ttft_p50_ms"] = float(np.percentile(ttfts, 50) * 1e3)
            out["ttft_p99_ms"] = float(np.percentile(ttfts, 99) * 1e3)
        if tpots:
            out["tpot_p50_ms"] = float(np.percentile(tpots, 50) * 1e3)
            out["tpot_p99_ms"] = float(np.percentile(tpots, 99) * 1e3)
        return out
