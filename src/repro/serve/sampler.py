"""Sampling suite for the serving engine: greedy, temperature, top-k,
top-p (nucleus), min-p — pure jnp, jit-friendly, PRNG-explicit."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => disabled
    top_p: float = 1.0            # 1 => disabled
    min_p: float = 0.0            # 0 => disabled


def _apply_top_k(logits, k: int):
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    thresh = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < thresh, -jnp.inf, logits)


def _apply_top_p(logits, p: float):
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with cumulative prob >= p (always >= 1 token)
    cutoff_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def _apply_min_p(logits, mp: float):
    if mp <= 0.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.where(probs < mp * top, -jnp.inf, logits)


def sample_per_row(rng, logits, temperatures):
    """Fused per-row sampling for the device-resident decode hot path.

    logits (B, V) float; temperatures (B,) float — rows with
    temperature <= 0 take the argmax, the rest draw via Gumbel-max
    (argmax of logits/T + Gumbel noise == categorical(softmax(logits/T))).
    Returns (B,) int32.  Not jitted on its own: it is traced inside
    ``decode_step_paged``/``prefill_paged`` so logits never leave the
    device and the PRNG key stays device-resident.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures, 1e-6)[:, None].astype(jnp.float32)
    g = jax.random.gumbel(rng, logits.shape, jnp.float32)
    noisy = jnp.argmax(logits.astype(jnp.float32) / t + g,
                       axis=-1).astype(jnp.int32)
    return jnp.where(temperatures > 0, noisy, greedy)


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample(rng, logits, cfg: SamplerConfig = SamplerConfig()):
    """logits (..., V) -> token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    z = logits.astype(jnp.float32) / cfg.temperature
    z = _apply_top_k(z, cfg.top_k)
    z = _apply_top_p(z, cfg.top_p)
    z = _apply_min_p(z, cfg.min_p)
    return jax.random.categorical(rng, z, axis=-1).astype(jnp.int32)
