"""Sampling suite for the serving engine: greedy, temperature, top-k,
top-p (nucleus), min-p — pure jnp, jit-friendly, PRNG-explicit."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => disabled
    top_p: float = 1.0            # 1 => disabled
    min_p: float = 0.0            # 0 => disabled


def _apply_top_k(logits, k: int):
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    thresh = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < thresh, -jnp.inf, logits)


def _apply_top_p(logits, p: float):
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep the smallest prefix with cumulative prob >= p (always >= 1 token)
    cutoff_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def _apply_min_p(logits, mp: float):
    if mp <= 0.0:
        return logits
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    return jnp.where(probs < mp * top, -jnp.inf, logits)


def _filter_per_row(z, top_k, top_p):
    """Per-row top-k then top-p nucleus filtering on temperature-scaled
    logits z (B, V).  top_k (B,) int32, 0 = disabled; top_p (B,) float,
    >= 1 = disabled.  At least one token always survives per row."""
    v = z.shape[-1]
    srt = jnp.sort(z, axis=-1)[..., ::-1]            # descending
    # top-k: keep z >= k-th largest (k clamped to [1, V])
    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
    kth = jnp.where((top_k > 0)[:, None], kth, -jnp.inf)
    z = jnp.where(z < kth, -jnp.inf, z)
    # top-p: smallest prefix of the (top-k-filtered) sorted distribution
    # with cumulative probability >= p (always >= 1 token).  The top-k
    # mask only removes the tail of the sorted array, so masking srt
    # directly keeps it sorted — no second O(V log V) sort.
    srt2 = jnp.where(srt < kth, -jnp.inf, srt)
    probs = jax.nn.softmax(srt2, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    idx = jnp.minimum(jnp.sum(cum < top_p[:, None], axis=-1), v - 1)
    cutoff = jnp.take_along_axis(srt2, idx[:, None], axis=-1)
    cutoff = jnp.where((top_p < 1.0)[:, None], cutoff, -jnp.inf)
    return jnp.where(z < cutoff, -jnp.inf, z)


def fold_row_keys(rng, seq_ids, positions):
    """Counter-based per-row sampling keys: fold (seq_id, position) into
    a fixed base key.  A row's draw then depends only on its identity
    and the index of the token being sampled — NOT on how admission,
    chunked prefill, or continuous batching happened to interleave the
    batch.  This is what makes chunked/one-shot prefill and
    continuous/wave schedules sample token-for-token identical streams
    (and makes migration/recovery parity independent of step counts)."""
    def one(sid, p):
        return jax.random.fold_in(jax.random.fold_in(rng, sid), p)
    return jax.vmap(one)(jnp.asarray(seq_ids, jnp.int32),
                         jnp.asarray(positions, jnp.int32))


def sample_per_row(rng, logits, temperatures, top_k=None, top_p=None):
    """Fused per-row sampling for the device-resident decode hot path.

    logits (B, V) float; temperatures (B,) float — rows with
    temperature <= 0 take the argmax, the rest draw via Gumbel-max
    (argmax of logits/T + Gumbel noise == categorical(softmax(logits/T))).
    Optional per-request filtering: top_k (B,) int32 (0 = disabled) and
    top_p (B,) float (>= 1 = disabled).  The filter pass (a per-row sort)
    runs under ``lax.cond`` so batches with every filter disabled — the
    greedy/temperature steady state — never pay for it.
    ``rng`` is either ONE key (shared Gumbel field across the batch) or
    a (B,)-batch of per-row keys from :func:`fold_row_keys` (each row
    draws its own field — schedule-independent sampling).
    Returns (B,) int32.  Not jitted on its own: it is traced inside
    ``decode_step_paged``/``prefill_paged`` so logits never leave the
    device and the PRNG key stays device-resident.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperatures, 1e-6)[:, None].astype(jnp.float32)
    z = logits.astype(jnp.float32) / t
    if top_k is not None or top_p is not None:
        b = logits.shape[0]
        tk = (jnp.asarray(top_k, jnp.int32) if top_k is not None
              else jnp.zeros((b,), jnp.int32))
        tp = (jnp.asarray(top_p, jnp.float32) if top_p is not None
              else jnp.ones((b,), jnp.float32))
        enabled = jnp.any(tk > 0) | jnp.any(tp < 1.0)
        z = jax.lax.cond(enabled,
                         lambda zz: _filter_per_row(zz, tk, tp),
                         lambda zz: zz, z)
    if jnp.ndim(rng) == 2:      # (B,)-batch of per-row keys
        g = jax.vmap(lambda k: jax.random.gumbel(
            k, logits.shape[-1:], jnp.float32))(rng)
    else:
        g = jax.random.gumbel(rng, logits.shape, jnp.float32)
    noisy = jnp.argmax(jnp.where(jnp.isfinite(z), z + g, -jnp.inf),
                       axis=-1).astype(jnp.int32)
    return jnp.where(temperatures > 0, noisy, greedy)


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample(rng, logits, cfg: SamplerConfig = SamplerConfig()):
    """logits (..., V) -> token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    z = logits.astype(jnp.float32) / cfg.temperature
    z = _apply_top_k(z, cfg.top_k)
    z = _apply_top_p(z, cfg.top_p)
    z = _apply_min_p(z, cfg.min_p)
    return jax.random.categorical(rng, z, axis=-1).astype(jnp.int32)
