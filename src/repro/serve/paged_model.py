"""Decode path through the MMU's paged KV pools.

The serving twin of ``repro.models.transformer.decode_step``: instead of a
dense per-sequence cache, KV lives in the MMU service's page pools and
attention walks the block tables (via the Pallas paged-attention kernel or
its oracle).

Hot-path contract (device-resident decode):

  * **Flat pool layout.**  The pools are a single
    ``(n_layers * n_pages, page_size, kv_heads, head_dim)`` buffer per
    side; layer ``l``'s physical page ``p`` lives at flat slot
    ``l * n_pages + p``.  This lets the pools ride the decode scan as an
    *aliased loop carry* — per-layer KV appends are in-place
    dynamic-updates into one buffer — instead of as scan inputs/outputs,
    which would force a full pool copy every step.  Per-layer access is
    pure page-id arithmetic (bias the block table by ``l * n_pages``), so
    the paged-attention kernel is unchanged.
  * **Donation.**  ``pools`` (and the decode-state buffers lens /
    last_tokens / rng) are donated into the jitted steps — KV is updated
    in place, never copied.  Callers must drop their reference and adopt
    the returned arrays (the engine reassigns ``self.pools`` etc. every
    step).
  * **Fused sampling.**  Greedy argmax and Gumbel-max temperature
    sampling happen inside the jit, so the (B, vocab) logits tensor never
    crosses to the host — the step returns only a (B,) int32 token
    vector.
  * ``prefill_paged`` admits a whole batch of new requests in one padded
    forward pass and scatters their KV into the pools in the same jit.

Applicability: attention-family architectures.  SSM archs have O(1) decode
state and bypass paging (DESIGN.md §5 — their MMU use is the constant-size
state page).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention.ops import paged_decode
from repro.models import attention, layers, mlp, moe
from repro.models.transformer import _is_moe_layer, forward, lm_logits
from repro.serve.sampler import fold_row_keys, sample_per_row

# Trace-time counters, keyed by function name.  Incremented as a Python
# side effect while tracing, so a test (or an operator) can assert that a
# hot-path function compiled exactly once across a run — the retrace guard
# for the device-resident decode contract.
TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(name: str) -> None:
    TRACE_COUNTS[name] = TRACE_COUNTS.get(name, 0) + 1


def make_pools(cfg: ModelConfig, n_pages: int, page_size: int, *,
               dtype=jnp.float32, kv_sharding=None) -> Dict[str, jnp.ndarray]:
    """Flat KV pools: layer ``l``'s page ``p`` is flat slot
    ``l * n_pages + p`` of a (n_layers * n_pages, page, K, hd) buffer.

    ``kv_sharding``: optional ``NamedSharding`` for tensor-parallel
    serving — the canonical TP layout shards axis 2 (``kv_heads``) on the
    mesh's ``model`` axis (``P(None, None, "model", None)``), so each
    device holds every page but only its head slice and paged attention
    needs no collective (softmax is head-local).  The page-id geometry is
    unchanged: block tables, the pager, and migration stay shard-agnostic.
    """
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers * n_pages, page_size, cfg.n_kv_heads, hd)
    pools = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kv_sharding is not None:
        pools = {s: jax.device_put(p, kv_sharding)
                 for s, p in pools.items()}
    return pools


def write_prefill(pools, layer_kv, tables, lens, page_size: int):
    """Scatter a prefilled sequence batch into the flat pools.

    layer_kv: (ks, vs) each (L, B, S, K, hd); tables (B, maxp) int32
    per-layer page ids; lens (B,) prompt lengths.  One scatter per side:
    tokens at/after a row's len (padding) and positions whose table entry
    is unmapped are routed to an out-of-bounds flat slot and dropped by
    the scatter (``mode="drop"``) — no gather of the existing pool
    contents is needed.
    """
    ks, vs = layer_kv
    l, b, s, kh, hd = ks.shape
    n_flat = pools["k"].shape[0]
    n_pages = n_flat // l
    pos = jnp.arange(s)
    vpage = pos // page_size                         # (S,)
    off = pos % page_size
    ppage = jnp.take_along_axis(
        tables, jnp.broadcast_to(vpage[None], (b, s)), axis=1)  # (B,S)
    valid = (pos[None, :] < lens[:, None]) & (ppage >= 0)       # (B,S)
    base = (jnp.arange(l) * n_pages)[:, None, None]             # (L,1,1)
    # invalid writes point one past the pool end: dropped by mode="drop"
    flat_page = jnp.where(valid[None], base + ppage[None], n_flat)
    flat_page = flat_page.reshape(-1)                # (L*B*S,)
    flat_off = jnp.broadcast_to(
        jnp.broadcast_to(off[None], (b, s)).reshape(-1)[None],
        (l, b * s)).reshape(-1)

    def write(pool, new):
        upd = new.reshape(l * b * s, kh, hd).astype(pool.dtype)
        return pool.at[flat_page, flat_off].set(upd, mode="drop")

    return {"k": write(pools["k"], ks), "v": write(pools["v"], vs)}


def flat_page_indices(ppages, n_layers: int, n_pages: int) -> jnp.ndarray:
    """Flat pool slots of physical pages ``ppages`` across every layer.

    Layer ``l``'s copy of page ``p`` lives at flat slot ``l*n_pages + p``
    (the pool layout contract above), so the result is layer-major:
    ``[l0p0, l0p1, ..., l1p0, ...]`` with shape ``(n_layers * len(ppages),)``.
    Both the migration gather and the evict-with-copy pager use this
    ordering — gather and scatter MUST agree on it for KV bytes to land
    back on the right (layer, page) after a move.
    """
    pp = jnp.asarray(ppages, jnp.int32).reshape(-1)
    base = jnp.arange(n_layers, dtype=jnp.int32)[:, None] * n_pages
    return (base + pp[None, :]).reshape(-1)


def bucket_pages(n: int, *, floor: int = 4) -> int:
    """Round a page-transfer count up to the next power of two (at least
    ``floor``).  The pre-copy freeze window gathers/scatters the dirty
    delta, whose size jitters by a page or two between moves — padding
    the transfer to a bucket makes those shapes collide, so the compiled
    gather/scatter is reused instead of retraced inside the downtime
    window (pad pages repeat the last real page; a duplicate scatter of
    identical rows is a no-op)."""
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return b


@jax.jit
def gather_kv_pages(pools, flat_idx):
    """Device-side compact gather of live KV pages.

    ``flat_idx`` (n,) int32 flat pool slots (see :func:`flat_page_indices`);
    returns ``{"k": (n, page, K, hd), "v": ...}`` — the transfer buffer a
    migration snapshot ships, and the payload the MMU pager preserves on
    evict.  Pools are NOT donated (the source keeps serving until the
    move commits).  Retraces per distinct gather size — this is the cold
    control path, not the decode loop.
    """
    _count_trace("gather_kv_pages")
    return {"k": jnp.take(pools["k"], flat_idx, axis=0),
            "v": jnp.take(pools["v"], flat_idx, axis=0)}


@functools.partial(jax.jit, donate_argnames=("pools",))
def scatter_kv_pages(pools, flat_idx, data):
    """Scatter a gathered transfer buffer back into (donated) pools at
    ``flat_idx`` — the restore half of migration and of the pager's
    fault-back-in.  ``data`` must use :func:`flat_page_indices` ordering."""
    _count_trace("scatter_kv_pages")
    return {"k": pools["k"].at[flat_idx].set(
                data["k"].astype(pools["k"].dtype)),
            "v": pools["v"].at[flat_idx].set(
                data["v"].astype(pools["v"].dtype))}


@functools.partial(jax.jit, static_argnames=("cfg", "page_size"),
                   donate_argnames=("pools", "rng"))
def prefill_paged(params, pools, tokens, lens, tables, rng, temperatures,
                  top_k=None, top_p=None,
                  *, cfg: ModelConfig, page_size: int):
    """Batched prefill: one padded forward for every admitted request.

    tokens (N, S) int32 right-padded prompts; lens (N,) prompt lengths
    (0 = padding row); tables (N, maxp) block tables for the freshly
    allocated sequences; temperatures (N,); optional per-request top_k
    (N,) int32 / top_p (N,) float32 sampling filters.  Returns
    (first_tokens (N,) int32, new_pools, new_rng).  ``pools`` and ``rng``
    are donated; sampling happens on device (padding rows yield garbage
    tokens the caller ignores).
    """
    _count_trace("prefill_paged")
    n = tokens.shape[0]
    hidden, _, kv_stack, _ = forward(params, cfg, tokens, collect_kv=True)
    pools = write_prefill(pools, kv_stack, tables, lens, page_size)
    last = hidden[jnp.arange(n), jnp.maximum(lens - 1, 0)]      # (N, D)
    logits = lm_logits(params, cfg, last)[..., :cfg.vocab_size]
    rng, sub = jax.random.split(rng)
    first = sample_per_row(sub, logits, temperatures, top_k, top_p)
    return first, pools, rng


def _prefill_shared_impl(params, pools, tokens, q_lens, q_starts,
                         write_from, tables, rng, temperatures,
                         top_k=None, top_p=None, seq_ids=None,
                         *, cfg: ModelConfig, page_size: int,
                         psum_attn=None, psum_mlp=None):
    """Suffix prefill for prefix-shared admissions.

    When the MMU maps a prompt's leading pages onto already-resident
    shared pages (``alloc_seq(..., prompt_tokens=...)``), only the
    *uncovered suffix* needs a forward pass: the shared pages already
    hold the exact KV those positions would produce.  This kernel runs
    the transformer over just the suffix tokens, attending through the
    block tables (so queries see the shared prefix KV), and scatters
    new KV only at positions >= ``write_from`` — shared pages are never
    written, preserving them for their other owners.

    tokens (N, T) int32   — suffix tokens, right-padded; row i holds
                            prompt[q_starts[i] : q_starts[i]+q_lens[i]];
    q_lens (N,) int32     — suffix lengths (0 = padding row);
    q_starts (N,) int32   — absolute position of tokens[i, 0].  For a
                            fully covered prompt this is len-1: the last
                            token's query is recomputed to produce
                            logits, but its KV write is masked off;
    write_from (N,) int32 — absolute position from which KV is written
                            (= tokens covered by shared pages);
    tables (N, maxp)      — block tables for the full prompt (shared
                            prefix pages + freshly allocated suffix).

    Returns (first_tokens (N,) int32, new_pools, new_rng); ``pools`` and
    ``rng`` are donated.  Retraces per (N, T, maxp) bucket — admission
    is the cold path, so this mirrors ``prefill_paged``'s bucketing.

    ``seq_ids`` (N,) int32, optional: when given, sampling keys are
    counter-based — ``fold_in(fold_in(rng, seq_id), prompt_len)`` per
    row instead of one batch-wide split — so a request's first token is
    identical however admission batched or chunked its prefill, and
    ``rng`` passes through unconsumed.

    ``psum_attn`` / ``psum_mlp``: optional reduction hooks for the
    tensor-parallel path (``repro.serve.tp``) — called on the out-proj /
    FFN partial sums when this body runs inside shard_map with
    head-/hidden-sharded weights.  None (the default) is the
    single-device path, byte-for-byte the pre-TP behaviour.
    """
    n, t = tokens.shape
    maxp = tables.shape[1]
    n_flat = pools["k"].shape[0]
    n_pages = n_flat // cfg.n_layers
    kh = cfg.n_kv_heads
    g = cfg.n_heads // kh
    scale = cfg.resolved_head_dim ** -0.5
    pos = q_starts[:, None] + jnp.arange(t)[None, :]        # (N,T) absolute
    qvalid = jnp.arange(t)[None, :] < q_lens[:, None]
    kv_lens = q_starts + q_lens                             # full prompt len
    vpage = jnp.minimum(pos // page_size, maxp - 1)
    off = pos % page_size
    ppage = jnp.take_along_axis(tables, vpage, axis=1)      # (N,T)
    wvalid = qvalid & (pos >= write_from[:, None]) & (ppage >= 0)
    kpos = jnp.arange(maxp * page_size)[None]               # (1,S)
    page_ok = jnp.repeat(tables >= 0, page_size, axis=1)    # (N,S)
    kv_ok = (kpos < kv_lens[:, None]) & page_ok             # (N,S)

    x = layers.embed_lookup(params["embed"], tokens)        # (N,T,D)

    def body(carry, inp):
        x, kp, vp = carry
        li, lp = inp
        base = li * n_pages
        h = layers.norm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], cfg, h)
        if cfg.pos_embed == "rope":
            q = layers.apply_rope(q, pos, cfg.rope_theta)
            k = layers.apply_rope(k, pos, cfg.rope_theta)
        # scatter suffix KV first so suffix queries see their own keys;
        # masked-off writes (shared-prefix positions, padding, unmapped
        # pages) drop at the out-of-bounds slot
        drop_page = jnp.where(wvalid, base + ppage, n_flat)
        kp = kp.at[drop_page, off].set(k.astype(kp.dtype), mode="drop")
        vp = vp.at[drop_page, off].set(v.astype(vp.dtype), mode="drop")
        # gather the full paged KV (shared prefix + fresh suffix) and
        # run exact causal attention against it, ref-oracle style
        safe = jnp.maximum(tables, 0) + base
        kg = jnp.take(kp, safe.reshape(-1), axis=0).reshape(
            n, maxp * page_size, kh, -1)
        vg = jnp.take(vp, safe.reshape(-1), axis=0).reshape(
            n, maxp * page_size, kh, -1)
        qf = q.reshape(n, t, kh, g, -1).astype(jnp.float32)
        s = jnp.einsum("ntkgd,nskd->nkgts", qf,
                       kg.astype(jnp.float32)) * scale
        mask = kv_ok[:, None, :] & (kpos[:, None, :] <= pos[:, :, None])
        s = jnp.where(mask[:, None, None], s, attention.NEG_INF)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        att = jnp.einsum("nkgts,nskd->ntkgd", p, vg.astype(jnp.float32))
        any_ok = jnp.any(mask, axis=-1)                     # (N,T)
        att = jnp.where(any_ok[:, :, None, None, None], att, 0.0)
        att = att.reshape(n, t, cfg.n_heads, -1).astype(x.dtype)
        o = attention.out_proj(lp["attn"], cfg, att)
        if psum_attn is not None:
            o = psum_attn(o)
        x = x + o
        h = layers.norm_apply(lp["norm2"], x, cfg.norm_eps)
        if _is_moe_layer(cfg):
            out, _ = moe.moe_apply(lp["ffn"], cfg, h)
        else:
            out = mlp.mlp_apply(lp["ffn"], cfg, h)
        if psum_mlp is not None:
            out = psum_mlp(out)
        return (x + out, kp, vp), None

    (x, kpool, vpool), _ = jax.lax.scan(
        body, (x, pools["k"], pools["v"]),
        (jnp.arange(cfg.n_layers), params["layers"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm_eps)
    last = x[jnp.arange(n), jnp.maximum(q_lens - 1, 0)]     # (N,D)
    logits = lm_logits(params, cfg, last)[..., :cfg.vocab_size]
    if seq_ids is None:
        rng, sub = jax.random.split(rng)
    else:
        # kv_lens == full prompt length == index of the token sampled
        sub = fold_row_keys(rng, seq_ids, kv_lens)
    first = sample_per_row(sub, logits, temperatures, top_k, top_p)
    return first, {"k": kpool, "v": vpool}, rng


@functools.partial(jax.jit, static_argnames=("cfg", "page_size"),
                   donate_argnames=("pools", "rng"))
def prefill_shared_paged(params, pools, tokens, q_lens, q_starts,
                         write_from, tables, rng, temperatures,
                         top_k=None, top_p=None, seq_ids=None,
                         *, cfg: ModelConfig, page_size: int):
    """Jitted single-device entry point over :func:`_prefill_shared_impl`
    (see its docstring for the full contract).  The tensor-parallel twin
    lives in ``repro.serve.tp`` and wraps the same impl in shard_map."""
    _count_trace("prefill_shared_paged")
    return _prefill_shared_impl(
        params, pools, tokens, q_lens, q_starts, write_from, tables, rng,
        temperatures, top_k, top_p, seq_ids, cfg=cfg, page_size=page_size)


def _prefill_chunk_impl(params, pools, tokens, q_lens, q_starts, tables,
                        *, cfg: ModelConfig, page_size: int,
                        psum_attn=None, psum_mlp=None):
    """One INTERMEDIATE chunk of a streaming prefill: KV only, no logits.

    The chunked-prefill twin of :func:`prefill_shared_paged`: row i runs
    the transformer over ``prompt[q_starts[i] : q_starts[i]+q_lens[i]]``,
    attending through the block tables (so chunk queries see every
    earlier chunk's KV in the pools), and scatters the chunk's KV at its
    absolute positions.  Because an intermediate chunk emits no token it
    computes NO final norm, NO logits, and — critically — consumes NO
    PRNG: the engine's rng key advances exactly as many times under
    chunked prefill as under one-shot prefill, which is what makes
    chunked/one-shot token streams identical even for sampled requests.

    Positions below ``q_starts`` are never written (they belong to
    earlier chunks or to shared prefix pages), so interleaving chunks
    with decode steps can only append — a 2k-token prompt stops costing
    one giant padded forward that stalls every running row.

    Returns ``new_pools`` only; ``pools`` is donated.  Retraces per
    (N, T, maxp) bucket like the other prefill entry points — chunk
    sizes are engine-fixed, so the bucket set stays O(log) small.
    ``psum_attn``/``psum_mlp`` are the TP reduction hooks (see
    :func:`_prefill_shared_impl`).
    """
    n, t = tokens.shape
    maxp = tables.shape[1]
    n_flat = pools["k"].shape[0]
    n_pages = n_flat // cfg.n_layers
    kh = cfg.n_kv_heads
    g = cfg.n_heads // kh
    scale = cfg.resolved_head_dim ** -0.5
    pos = q_starts[:, None] + jnp.arange(t)[None, :]        # (N,T) absolute
    qvalid = jnp.arange(t)[None, :] < q_lens[:, None]
    kv_lens = q_starts + q_lens                  # tokens in cache after us
    vpage = jnp.minimum(pos // page_size, maxp - 1)
    off = pos % page_size
    ppage = jnp.take_along_axis(tables, vpage, axis=1)      # (N,T)
    wvalid = qvalid & (ppage >= 0)
    kpos = jnp.arange(maxp * page_size)[None]               # (1,S)
    page_ok = jnp.repeat(tables >= 0, page_size, axis=1)    # (N,S)
    kv_ok = (kpos < kv_lens[:, None]) & page_ok             # (N,S)

    x = layers.embed_lookup(params["embed"], tokens)        # (N,T,D)

    def body(carry, inp):
        x, kp, vp = carry
        li, lp = inp
        base = li * n_pages
        h = layers.norm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], cfg, h)
        if cfg.pos_embed == "rope":
            q = layers.apply_rope(q, pos, cfg.rope_theta)
            k = layers.apply_rope(k, pos, cfg.rope_theta)
        drop_page = jnp.where(wvalid, base + ppage, n_flat)
        kp = kp.at[drop_page, off].set(k.astype(kp.dtype), mode="drop")
        vp = vp.at[drop_page, off].set(v.astype(vp.dtype), mode="drop")
        safe = jnp.maximum(tables, 0) + base
        kg = jnp.take(kp, safe.reshape(-1), axis=0).reshape(
            n, maxp * page_size, kh, -1)
        vg = jnp.take(vp, safe.reshape(-1), axis=0).reshape(
            n, maxp * page_size, kh, -1)
        qf = q.reshape(n, t, kh, g, -1).astype(jnp.float32)
        s = jnp.einsum("ntkgd,nskd->nkgts", qf,
                       kg.astype(jnp.float32)) * scale
        mask = kv_ok[:, None, :] & (kpos[:, None, :] <= pos[:, :, None])
        s = jnp.where(mask[:, None, None], s, attention.NEG_INF)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        att = jnp.einsum("nkgts,nskd->ntkgd", p, vg.astype(jnp.float32))
        any_ok = jnp.any(mask, axis=-1)                     # (N,T)
        att = jnp.where(any_ok[:, :, None, None, None], att, 0.0)
        att = att.reshape(n, t, cfg.n_heads, -1).astype(x.dtype)
        o = attention.out_proj(lp["attn"], cfg, att)
        if psum_attn is not None:
            o = psum_attn(o)
        x = x + o
        h = layers.norm_apply(lp["norm2"], x, cfg.norm_eps)
        if _is_moe_layer(cfg):
            out, _ = moe.moe_apply(lp["ffn"], cfg, h)
        else:
            out = mlp.mlp_apply(lp["ffn"], cfg, h)
        if psum_mlp is not None:
            out = psum_mlp(out)
        return (x + out, kp, vp), None

    (_, kpool, vpool), _ = jax.lax.scan(
        body, (x, pools["k"], pools["v"]),
        (jnp.arange(cfg.n_layers), params["layers"]))
    return {"k": kpool, "v": vpool}


@functools.partial(jax.jit, static_argnames=("cfg", "page_size"),
                   donate_argnames=("pools",))
def prefill_chunk_paged(params, pools, tokens, q_lens, q_starts, tables,
                        *, cfg: ModelConfig, page_size: int):
    """Jitted single-device entry point over :func:`_prefill_chunk_impl`."""
    _count_trace("prefill_chunk_paged")
    return _prefill_chunk_impl(params, pools, tokens, q_lens, q_starts,
                               tables, cfg=cfg, page_size=page_size)


def _decode_step_impl(params, pools, tables, lens, last_tokens, rng,
                      temperatures, top_k=None, top_p=None, seq_ids=None,
                      *, cfg: ModelConfig, page_size: int,
                      use_pallas: bool = False,
                      pages_per_block: Optional[int] = None,
                      psum_attn=None, psum_mlp=None):
    """One fused decode step for the whole running batch.

    last_tokens (B,) int32 — last sampled token per row;
    lens (B,) int32       — tokens already in cache (new token position);
    tables (B, maxp)      — MMU block tables (row of -1s = inactive slot);
    temperatures (B,)     — per-row sampling temperature (<= 0 = greedy);
    top_k (B,) int32      — optional per-row top-k filter (0 = disabled);
    top_p (B,) float32    — optional per-row nucleus filter (>=1 = off).

    Returns (next_tokens (B,) int32, new_pools, new_lens, new_rng).
    ``pools``, ``lens``, ``last_tokens`` and ``rng`` are donated: the
    flat KV pools are an aliased carry of the layer scan, updated in
    place.  ``tables`` is NOT donated — it is the MMU's cached device
    view, reused across steps.  The only host<->device traffic a caller
    needs per step is reading back the (B,) token vector.

    ``psum_attn``/``psum_mlp`` are the TP reduction hooks (see
    :func:`_prefill_shared_impl`): under ``repro.serve.tp`` this body
    runs inside shard_map with a per-device head/hidden slice of the
    weights and KV pools, and the hooks all-reduce the out-proj and FFN
    partial sums over the ``model`` axis.
    """
    maxp = tables.shape[1]
    n_flat = pools["k"].shape[0]
    n_pages = n_flat // cfg.n_layers
    x = layers.embed_lookup(params["embed"], last_tokens[:, None])
    pos = lens                                        # 0-based new position
    vpage = jnp.minimum(pos // page_size, maxp - 1)
    off = pos % page_size
    ppage = jnp.take_along_axis(tables, vpage[:, None], axis=1)[:, 0]
    active = ppage >= 0
    kv_lens = jnp.where(active, lens + 1, 0)

    def body(carry, inp):
        x, kp, vp = carry
        li, lp = inp
        base = li * n_pages
        h = layers.norm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], cfg, h)
        if cfg.pos_embed == "rope":
            q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
        knew = k[:, 0].astype(kp.dtype)               # (B,K,hd)
        vnew = v[:, 0].astype(vp.dtype)
        # inactive rows write one past the pool end: dropped by "drop"
        drop_page = jnp.where(active, base + ppage, n_flat)
        kp = kp.at[drop_page, off].set(knew, mode="drop")
        vp = vp.at[drop_page, off].set(vnew, mode="drop")
        ltab = jnp.where(tables >= 0, tables + base, -1)
        att = paged_decode(q[:, 0], kp, vp, ltab, kv_lens,
                           use_pallas=use_pallas,
                           pages_per_block=pages_per_block)
        o = attention.out_proj(lp["attn"], cfg, att[:, None])
        if psum_attn is not None:
            o = psum_attn(o)
        x = x + o
        h = layers.norm_apply(lp["norm2"], x, cfg.norm_eps)
        if _is_moe_layer(cfg):
            out, _ = moe.moe_apply(lp["ffn"], cfg, h)
        else:
            out = mlp.mlp_apply(lp["ffn"], cfg, h)
        if psum_mlp is not None:
            out = psum_mlp(out)
        return (x + out, kp, vp), None

    (x, kpool, vpool), _ = jax.lax.scan(
        body, (x, pools["k"], pools["v"]),
        (jnp.arange(cfg.n_layers), params["layers"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x)[:, 0][..., :cfg.vocab_size]
    if seq_ids is None:
        rng, sub = jax.random.split(rng)
    else:
        # lens + 1 == index of the token being sampled: counter-based
        # keys make the draw independent of batching/step interleave
        sub = fold_row_keys(rng, seq_ids, lens + 1)
    # sample every row (the host ignores empty slots): a live row whose
    # write-position page was evicted still emits a real (degraded)
    # sample, matching the host-side oracle's behaviour under pressure.
    next_tokens = sample_per_row(sub, logits, temperatures, top_k, top_p)
    # lens mirrors the host's per-step append unconditionally, so an
    # evicted row's write position keeps tracking host truth and the row
    # self-reactivates once its next page is mapped (slot transitions
    # reset the counters host-side).
    new_lens = lens + 1
    return next_tokens, {"k": kpool, "v": vpool}, new_lens, rng


@functools.partial(jax.jit, static_argnames=("cfg", "page_size",
                                             "use_pallas",
                                             "pages_per_block"),
                   donate_argnames=("pools", "lens", "last_tokens", "rng"))
def decode_step_paged(params, pools, tables, lens, last_tokens, rng,
                      temperatures, top_k=None, top_p=None, seq_ids=None,
                      *, cfg: ModelConfig, page_size: int,
                      use_pallas: bool = False,
                      pages_per_block: Optional[int] = None):
    """Jitted single-device entry point over :func:`_decode_step_impl`
    (see its docstring for the full contract).  The tensor-parallel twin
    lives in ``repro.serve.tp``."""
    _count_trace("decode_step_paged")
    return _decode_step_impl(
        params, pools, tables, lens, last_tokens, rng, temperatures,
        top_k, top_p, seq_ids, cfg=cfg, page_size=page_size,
        use_pallas=use_pallas, pages_per_block=pages_per_block)
