"""Decode path through the MMU's paged KV pools.

The serving twin of ``repro.models.transformer.decode_step``: instead of a
dense per-sequence cache, KV lives in the MMU service's page pools and
attention walks the block tables (via the Pallas paged-attention kernel or
its oracle).  Pools are stacked on the layer axis and scanned, so depth
never bloats the HLO; pool buffers are donated every step.

Applicability: attention-family architectures.  SSM archs have O(1) decode
state and bypass paging (DESIGN.md §5 — their MMU use is the constant-size
state page).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention.ops import paged_decode
from repro.models import attention, layers, mlp, moe
from repro.models.transformer import _is_moe_layer, lm_logits


def make_pools(cfg: ModelConfig, n_pages: int, page_size: int, *,
               dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_prefill(pools, layer_kv, tables, lens, page_size: int):
    """Scatter a prefilled sequence batch into the pools.

    layer_kv: (ks, vs) each (L, B, S, K, hd); tables (B, maxp) int32;
    lens (B,) prompt lengths (tokens beyond a row's len are dropped via a
    dump page at pool slot... they are written to page 0 offset 0 of their
    own page id — callers allocate exact pages so S == max len in batch).
    """
    ks, vs = layer_kv
    l, b, s, kh, hd = ks.shape
    pos = jnp.arange(s)
    vpage = pos // page_size                         # (S,)
    off = pos % page_size
    ppage = jnp.take_along_axis(
        tables, jnp.broadcast_to(vpage[None], (b, s)), axis=1)  # (B,S)
    valid = pos[None, :] < lens[:, None]             # (B,S)
    safe_page = jnp.where(valid, ppage, 0)

    def write(pool, new):
        # pool (L,P,page,K,hd); new (L,B,S,K,hd)
        flat_b = safe_page.reshape(-1)               # (B*S,)
        flat_o = jnp.broadcast_to(off[None], (b, s)).reshape(-1)
        upd = new.reshape(l, b * s, kh, hd).astype(pool.dtype)
        # drop invalid writes by pointing them at a scratch page slot 0/0
        # with where-masking the update against the existing value
        cur = pool[:, flat_b, flat_o]
        m = valid.reshape(1, b * s, 1, 1)
        upd = jnp.where(m, upd, cur)
        return pool.at[:, flat_b, flat_o].set(upd)

    return {"k": write(pools["k"], ks), "v": write(pools["v"], vs)}


@functools.partial(jax.jit, static_argnames=("cfg", "page_size",
                                             "use_pallas"))
def decode_step_paged(params, pools, tables, lens, tokens, *,
                      cfg: ModelConfig, page_size: int,
                      use_pallas: bool = False):
    """One decode step for the whole running batch.

    tokens (B,1) int32 — last sampled token per row;
    lens (B,) int32    — tokens already in cache (new token position);
    tables (B, maxp)   — MMU block tables (row of -1s = inactive slot).
    Returns (logits (B,V), new_pools).  Donate ``pools``.
    """
    b = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = layers.embed_lookup(params["embed"], tokens)
    pos = lens                                        # 0-based new position
    vpage = pos // page_size
    off = pos % page_size
    ppage = jnp.take_along_axis(tables, vpage[:, None], axis=1)[:, 0]
    active = ppage >= 0
    safe_page = jnp.where(active, ppage, 0)
    rows = jnp.arange(b)

    def body(x, inp):
        lp, kp, vp = inp                              # pool (P,page,K,hd)
        h = layers.norm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], cfg, h)
        if cfg.pos_embed == "rope":
            q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
            k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
        knew = k[:, 0].astype(kp.dtype)               # (B,K,hd)
        vnew = v[:, 0].astype(vp.dtype)
        mask = active[:, None, None]
        kp = kp.at[safe_page, off].set(
            jnp.where(mask, knew, kp[safe_page, off]))
        vp = vp.at[safe_page, off].set(
            jnp.where(mask, vnew, vp[safe_page, off]))
        att = paged_decode(q[:, 0], kp, vp, tables,
                           jnp.where(active, lens + 1, 0),
                           use_pallas=use_pallas)
        x = x + attention.out_proj(lp["attn"], cfg, att[:, None])
        h = layers.norm_apply(lp["norm2"], x, cfg.norm_eps)
        if _is_moe_layer(cfg):
            out, _ = moe.moe_apply(lp["ffn"], cfg, h)
        else:
            out = mlp.mlp_apply(lp["ffn"], cfg, h)
        return x + out, (kp, vp)

    x, (ks, vs) = jax.lax.scan(body, x,
                               (params["layers"], pools["k"], pools["v"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"k": ks, "v": vs}
