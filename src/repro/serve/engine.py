"""Continuous-batching serving engine on the MMU's paged KV cache.

The LLM mirror of the paper's multi-threaded AES pipeline (Fig 1/9/10):
token-by-token decode has a strict sequential dependence per request, so a
single stream leaves the pipeline idle — the engine fills the bubbles by
interleaving many concurrent requests (cThread streams) into one batched
decode step.  Admission is credit-based (page budget via the MMU), pages
are allocated on demand and freed at completion, and finished rows are
immediately replaced from the queue (continuous batching).
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.services.mmu import MMU, MMUConfig
from repro.models import transformer as T
from repro.serve.paged_model import (decode_step_paged, make_pools,
                                     write_prefill)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    tid: int = 0                      # submitting cThread
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, mmu: MMU, *,
                 max_batch: int = 8, max_len: int = 1024,
                 use_pallas: bool = False, seed: int = 0,
                 shell=None, slot: int = 0, tenant: Optional[str] = None):
        assert cfg.ssm is None and len(cfg.block_pattern) == 1, \
            "paged engine serves attention archs (DESIGN.md §5)"
        self.cfg = cfg
        self.params = params
        self.mmu = mmu
        self.page = mmu.config.page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.max_pages = -(-max_len // self.page)
        self.use_pallas = use_pallas
        self.pools = make_pools(cfg, mmu.config.n_pages, self.page)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._rng = np.random.RandomState(seed)
        self._rid = itertools.count(1)
        self.completed: List[Request] = []
        self.steps = 0
        self.tokens_out = 0
        # Optional shell binding: decode-step I/O is then submitted through
        # the shell scheduler (weighted credits + arbiter) instead of
        # bypassing the shared link — multi-tenant serving engines contend
        # for bandwidth exactly like any other vFPGA traffic.
        self.shell = shell
        self.slot = slot
        self.tenant = tenant
        self.io_bytes = 0
        if shell is not None and tenant is not None:
            shell.scheduler.bind_slot(slot, tenant)

    # -------------------------------------------------------------- API ----
    def submit(self, prompt: List[int], max_new_tokens: int = 16, *,
               temperature: float = 0.0, tid: int = 0) -> int:
        rid = next(self._rid)
        self.queue.append(Request(
            rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
            temperature=temperature, tid=tid, t_submit=time.perf_counter()))
        return rid

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def pending(self) -> bool:
        return self.active > 0 or bool(self.queue)

    # -------------------------------------------------------- admission ----
    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = -(-(len(req.prompt) + req.max_new_tokens) // self.page)
            if need > self.mmu.config.n_pages - (
                    self.mmu.utilization()["pages_used"]):
                break                          # page credits exhausted
            self.queue.popleft()
            self.mmu.alloc_seq(req.rid, len(req.prompt), slot=i)
            self.slots[i] = req
            self._prefill(i, req)

    def _prefill(self, slot: int, req: Request) -> None:
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        hidden, _, kv_stack, _ = T.forward(self.params, self.cfg, toks,
                                           collect_kv=True)
        tables = jnp.asarray(
            self.mmu.block_table([req.rid], self.max_pages))
        lens = jnp.asarray([len(req.prompt)], jnp.int32)
        self.pools = write_prefill(self.pools, kv_stack, tables, lens,
                                   self.page)
        logits = T.lm_logits(self.params, self.cfg, hidden[:, -1])
        tok = self._sample(np.asarray(logits), req.temperature)[0]
        req.out_tokens.append(int(tok))
        req.t_first_token = time.perf_counter()
        self.mmu.extend_seq(req.rid, 1, slot=slot)
        self.tokens_out += 1

    def _sample(self, logits: np.ndarray, temperature: float) -> np.ndarray:
        logits = logits[..., :self.cfg.vocab_size]
        if temperature <= 0:
            return np.argmax(logits, axis=-1)
        z = logits / temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self._rng.choice(p.shape[-1], p=row)
                         for row in p])

    # ------------------------------------------------------------ decode ----
    def step(self) -> int:
        """One continuous-batching engine step; returns tokens emitted."""
        self._admit()
        if self.active == 0:
            return 0
        rids = [r.rid if r is not None else -1 for r in self.slots]
        live = [r for r in self.slots if r is not None]
        tables = np.full((self.max_batch, self.max_pages), -1, np.int32)
        lens = np.zeros((self.max_batch,), np.int32)
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tables[i] = self.mmu.block_table([req.rid], self.max_pages)[0]
            # length BEFORE this step's token (its write position)
            lens[i] = len(req.prompt) + len(req.out_tokens) - 1
            tokens[i, 0] = req.out_tokens[-1]

        logits, self.pools = decode_step_paged(
            self.params, self.pools, jnp.asarray(tables), jnp.asarray(lens),
            jnp.asarray(tokens), cfg=self.cfg, page_size=self.page,
            use_pallas=self.use_pallas)
        logits = np.asarray(logits)
        self.steps += 1
        self._submit_step_io(n_live=len(live), logits_row_bytes=(
            logits[0].nbytes if len(logits) else 0))

        emitted = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(self._sample(logits[i][None], req.temperature)[0])
            req.out_tokens.append(tok)
            emitted += 1
            self.mmu.extend_seq(req.rid, 1, slot=i)
            total = len(req.prompt) + len(req.out_tokens)
            if (len(req.out_tokens) >= req.max_new_tokens
                    or total >= self.max_len):
                req.done = True
                req.t_done = time.perf_counter()
                self.mmu.free_seq(req.rid)
                self.completed.append(req)
                self.slots[i] = None
        self.tokens_out += emitted
        return emitted

    def _submit_step_io(self, n_live: int, logits_row_bytes: int) -> None:
        """Bill this decode step's host I/O (token ids in, sampled logits
        row out per live request) to our tenant through the shell
        scheduler, so serving bandwidth is QoS-scheduled, not free."""
        if self.shell is None or n_live == 0:
            return
        nbytes = n_live * (4 + logits_row_bytes)
        self.io_bytes += nbytes
        self.shell.scheduler.submit_io(
            nbytes, slot=self.slot, tenant=self.tenant, tag="decode_io",
            wait=True, timeout=30.0)

    def run(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        while self.pending() and self.steps < max_steps:
            self.step()
        dt = time.perf_counter() - t0
        return {"wall_s": dt, "engine_steps": self.steps,
                "tokens": self.tokens_out,
                "tokens_per_s": self.tokens_out / max(dt, 1e-9),
                "completed": len(self.completed)}
