"""Continuous-batching serving engine on the MMU's paged KV cache.

The LLM mirror of the paper's multi-threaded AES pipeline (Fig 1/9/10):
token-by-token decode has a strict sequential dependence per request, so a
single stream leaves the pipeline idle — the engine fills the bubbles by
interleaving many concurrent requests (cThread streams) into one batched
decode step.  Admission is credit-based (page budget via the MMU), pages
are allocated on demand and freed at completion, and finished rows are
immediately replaced from the queue (continuous batching).

Hot-path invariants (the Coyote v2 "shell out of the datapath" story):

  * **Device-resident state.**  The KV pools, block tables, row lengths,
    last-sampled tokens, per-row temperatures, and the PRNG key all live
    on device.  Block tables are a cached :class:`DeviceBlockTable` view
    owned by the MMU — rows are re-uploaded only when an alloc/extend/
    free/evict delta changes a sequence's mapping (i.e. on page-boundary
    crossings and slot churn), never per step.
  * **Donation.**  ``decode_step_paged`` donates the pools and the
    decode-state buffers, so KV is updated in place instead of copied.
    ``self.pools`` / ``self.dev_lens`` / ``self.dev_tokens`` /
    ``self.rng`` must be reassigned from the step's return values every
    call — holding a stale reference to a donated buffer is an error.
    The block-table view is NOT donated (the cache reuses it).
  * **One (B,) vector per step.**  Sampling (greedy argmax + Gumbel-max
    temperature) is fused inside the jitted step; the (B, vocab) logits
    tensor never leaves the device.  The only per-step host<->device
    traffic is reading back the (B,) int32 token vector.
  * **Batched prefill.**  All requests admitted in one ``_admit()`` pass
    run as a single padded forward (``prefill_shared_paged``), with
    suffix lengths and batch counts bucketed to powers of two to bound
    retraces.  Prompt pages the MMU mapped onto shared prefix pages are
    skipped entirely — only the uncovered suffix is computed.
  * **Non-blocking billing.**  Decode-step I/O is submitted to the shell
    scheduler asynchronously; credits settle at step boundaries
    (``_settle_io``) and ``flush_io()`` drains the tail, so in normal
    operation QoS accounting never stalls the decode loop.  The one
    intended exception is the scheduler's submitter-side back-pressure:
    a tenant whose pending I/O hits its bound stalls *itself* at submit
    (paper §7.2 containment) — that is the QoS design, not a hot-path
    regression.
  * **One compilation.**  ``decode_step_paged`` traces exactly once per
    (engine shape, flags) across a run regardless of occupancy changes —
    ``repro.serve.paged_model.TRACE_COUNTS`` is the retrace guard.

Bench reproduction: ``PYTHONPATH=src python -m benchmarks.run --only
llm_serving`` (writes ``BENCH_serving.json``), or ``scripts/ci.sh`` for
the tier-1 smoke path plus the quick bench.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.faults import FaultKind
from repro.core.port import PortError
from repro.core.services.mmu import MMU, MMUConfig
from repro.serve.paged_model import (bucket_pages, decode_step_paged,
                                     flat_page_indices, gather_kv_pages,
                                     make_pools, prefill_chunk_paged,
                                     prefill_shared_paged,
                                     scatter_kv_pages)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = disabled
    top_p: float = 1.0                # >= 1 = disabled
    tid: int = 0                      # submitting cThread
    priority: int = 0                 # scheduler priority (higher = sooner)
    deadline_s: Optional[float] = None  # absolute SLO deadline (perf_counter)
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    done: bool = False
    # chunked-prefill cursor: -1 = not chunking; >= 0 = prompt tokens
    # whose KV is already in the pools (the row holds a slot + pages but
    # is NOT bound into the decode batch until its final chunk lands)
    prefill_pos: int = -1


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two (capped) so padded prefill shapes
    bucket into O(log) distinct compilations."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, mmu: MMU, *,
                 max_batch: int = 8, max_len: int = 1024,
                 use_pallas: bool = False,
                 pages_per_block: Optional[int] = None, seed: int = 0,
                 shell=None, slot: int = 0, tenant: Optional[str] = None,
                 rid_base: int = 0, prefill_chunk: Optional[int] = None,
                 admit_window: int = 8, mesh=None, collectives=None):
        assert cfg.ssm is None and len(cfg.block_pattern) == 1, \
            "paged engine serves attention archs (DESIGN.md §5)"
        self.cfg = cfg
        self.params = params
        self.mmu = mmu
        self.page = mmu.config.page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.max_pages = -(-max_len // self.page)
        self.use_pallas = use_pallas
        self.pages_per_block = pages_per_block
        # chunked/streaming prefill: prompts whose uncovered suffix
        # exceeds ``prefill_chunk`` tokens are prefilled one chunk per
        # step, interleaved with decode, instead of one giant padded
        # forward that stalls every running row.  None = one-shot.
        self.prefill_chunk = prefill_chunk
        # head-of-line fix: how deep past a blocked queue head admission
        # may scan for smaller requests that DO fit the page budget
        # (per-tenant FIFO is always preserved)
        self.admit_window = admit_window
        # step-time EWMAs (SLO admission feasibility inputs): seconds
        # per prefilled prompt token, and seconds per fused decode step.
        # Samples are clamped against the running estimate so a JIT
        # recompile outlier cannot wreck the feasibility math.
        self.ewma_prefill_s_per_tok: Optional[float] = None
        self.ewma_decode_step_s: Optional[float] = None
        self.prefill_obs = 0
        self.decode_obs = 0
        self._ewma_alpha = 0.25
        # gateway hooks: ``admission_hook(engine)`` runs at the top of
        # every step (before ``_admit``) so a frontend can backfill the
        # queue at step granularity; ``token_sink(req, token, done)``
        # fires for every emitted token (prefill first-tokens included)
        self.admission_hook = None
        self.token_sink = None
        # Tensor-parallel serving (docs/sharding.md): a mesh with a
        # model axis > 1 shards weights and KV pools across its devices
        # while everything host-side — MMU, block table, pager, queue,
        # scheduler — stays logically single.  ``collectives`` routes
        # the per-layer partial-sum reductions through the shell's
        # CollectiveService port.
        self.mesh = mesh
        self.tp = None
        if mesh is not None and dict(mesh.shape).get("model", 1) > 1:
            from repro.serve.tp import TPContext
            self.tp = TPContext(cfg, mesh, params, page_size=self.page,
                                use_pallas=use_pallas,
                                pages_per_block=pages_per_block,
                                collectives=collectives)
            self.params = self.tp.params
        if self.tp is not None:
            self._decode_step = self.tp.decode_step
            self._prefill_shared = self.tp.prefill_shared
            self._prefill_chunk = self.tp.prefill_chunk
        else:
            self._decode_step = functools.partial(
                decode_step_paged, cfg=cfg, page_size=self.page,
                use_pallas=use_pallas, pages_per_block=pages_per_block)
            self._prefill_shared = functools.partial(
                prefill_shared_paged, cfg=cfg, page_size=self.page)
            self._prefill_chunk = functools.partial(
                prefill_chunk_paged, cfg=cfg, page_size=self.page)
        self.pools = make_pools(
            cfg, mmu.config.n_pages, self.page,
            kv_sharding=self.tp.kv_sharding if self.tp is not None
            else None)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._rng = np.random.RandomState(seed)     # host sampling oracle
        # request/sequence ids: ``rid_base`` namespaces the id range so
        # a migration destination adopting foreign rids (or shells whose
        # engines use per-tenant MMU instances) never collides in the
        # page tables.  NOTE: two paged engines must NOT share one MMU
        # instance — register_pager(owner=...) enforces it.
        self._rid_next = rid_base + 1
        self.completed: List[Request] = []
        self.steps = 0
        self.tokens_out = 0
        # prefix-sharing accounting: prompt tokens actually run through a
        # prefill forward vs tokens whose KV came from shared pages
        self.prefill_computed = 0
        self.prefill_skipped = 0
        # Device-resident decode state: block tables (cached MMU view),
        # row lengths, last tokens, temperatures, PRNG key.
        self.block_table = mmu.block_table_device(
            max_batch, self.max_pages,
            sharding=self.tp.replicated if self.tp is not None else None)
        self.dev_lens = self._place(jnp.zeros((max_batch,), jnp.int32))
        self.dev_tokens = self._place(jnp.zeros((max_batch,), jnp.int32))
        self.dev_temps = self._place(jnp.zeros((max_batch,), jnp.float32))
        self.dev_topk = self._place(jnp.zeros((max_batch,), jnp.int32))
        self.dev_topp = self._place(jnp.ones((max_batch,), jnp.float32))
        # per-slot sequence ids: sampling keys are counter-based
        # fold_in(fold_in(rng, rid), token_index), so a request's
        # sampled stream is invariant to admission order, chunking, and
        # continuous-vs-wave scheduling (see sampler.fold_row_keys)
        self.dev_rids = self._place(jnp.zeros((max_batch,), jnp.int32))
        self.rng = self._place(jax.random.PRNGKey(seed))
        # Optional shell binding: decode-step I/O is then submitted through
        # the slot's unified Port (Port API v2) into the shell scheduler
        # (weighted credits + arbiter) instead of bypassing the shared
        # link — multi-tenant serving engines contend for bandwidth
        # exactly like any other vFPGA traffic.
        self.shell = shell
        self.slot = slot
        self.tenant = tenant
        self.io_bytes = 0
        self.io_failures = 0          # billed-IO futures that failed typed
        self._io_futs: List = []
        self.port = (shell.attach(slot, tenant=tenant)
                     if shell is not None else None)
        if shell is not None:
            shell.engines[slot] = self     # migrate() resolves us by slot
        # evict-with-copy: the MMU pager gathers a page's KV payload off
        # the device before recycling the page and scatters it back on
        # fault-in.  owner=self makes the one-pool-owner-per-MMU rule
        # explicit: a second engine on this MMU is refused at
        # construction, not discovered as silent KV corruption on evict.
        mmu.register_pager(self._pager_gather, self._pager_scatter,
                           owner=self)

    # --------------------------------------------------- TP placement ------
    def _place(self, arr):
        """Device-resident decode state: replicated across the TP mesh
        when sharded, plain single-device array otherwise."""
        if self.tp is not None:
            return jax.device_put(arr, self.tp.replicated)
        return jnp.asarray(arr)

    def _adopt_pools(self, pools):
        """Re-pin KV pools to the TP head-sharded layout after a scatter
        (GSPMD propagation normally preserves it; this makes the decode
        jit's input layout an invariant, not an inference)."""
        if self.tp is not None:
            pools = {s: jax.device_put(p, self.tp.kv_sharding)
                     for s, p in pools.items()}
        return pools

    # ------------------------------------------------- evict-with-copy -----
    def _pager_gather(self, ppage: int) -> Dict[str, np.ndarray]:
        """Copy one physical page's KV (all layers) to host — called by
        the MMU just before it recycles the device page."""
        flat = flat_page_indices([ppage], self.cfg.n_layers,
                                 self.mmu.config.n_pages)
        kv = gather_kv_pages(self.pools, flat)
        return {"k": np.asarray(kv["k"]), "v": np.asarray(kv["v"])}

    def _pager_scatter(self, ppage: int,
                       data: Dict[str, np.ndarray]) -> None:
        """Write a preserved page payload into a freshly mapped device
        page (MMU fault-back-in path)."""
        flat = flat_page_indices([ppage], self.cfg.n_layers,
                                 self.mmu.config.n_pages)
        self.pools = self._adopt_pools(scatter_kv_pages(
            self.pools, flat, {"k": jnp.asarray(data["k"]),
                               "v": jnp.asarray(data["v"])}))

    # -------------------------------------------------------------- API ----
    def submit(self, prompt: List[int], max_new_tokens: int = 16, *,
               temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0, tid: int = 0, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        if prompt and (min(prompt) < 0 or max(prompt) >= self.cfg.vocab_size):
            # out-of-range ids would embed as NaN (XLA gathers fill OOB
            # reads) and silently poison the KV cache; fail at the door
            raise ValueError(
                f"prompt token out of range for vocab_size="
                f"{self.cfg.vocab_size}")
        health = getattr(self.shell, "health", None)
        if health is not None and health.is_quarantined(self.tenant):
            # graceful degradation: a repeatedly-faulting tenant is
            # rejected fast with a typed error, bystanders keep flowing
            health.record_rejection(self.tenant)
            raise PortError(
                f"tenant {self.tenant!r} is quarantined (repeated faults "
                "within the quarantine window); "
                "shell.health.unquarantine() to lift",
                kind=FaultKind.QUARANTINED, slot=self.slot,
                tenant=self.tenant, retryable=False)
        rid = self._rid_next
        self._rid_next += 1
        self.queue.append(Request(
            rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
            temperature=temperature, top_k=top_k, top_p=top_p, tid=tid,
            priority=priority, deadline_s=deadline_s,
            t_submit=time.perf_counter()))
        return rid

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def pending(self) -> bool:
        return self.active > 0 or bool(self.queue)

    # -------------------------------------------------------- admission ----
    def _ewma(self, prev: Optional[float], sample: float) -> float:
        """EWMA update with a 10x clamp against the running estimate so
        a one-off JIT-recompile outlier cannot poison feasibility math."""
        if prev is None:
            return sample
        a = self._ewma_alpha
        return (1 - a) * prev + a * min(sample, 10.0 * prev)

    def _admit(self) -> None:
        """Admit queued requests into free slots under the page budget.

        The queue head no longer blocks everything behind it: when a
        request does not fit the remaining page credits, admission scans
        up to ``admit_window`` entries deep for smaller requests that DO
        fit, while skipping any request whose tenant (``tid``) already
        has a blocked one ahead of it — per-tenant FIFO order is never
        reordered, only independent tenants leapfrog a stuck head.
        """
        if not self.queue:
            return
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        if not free:
            return
        oneshot, taken, blocked = [], set(), set()
        qlist = list(self.queue)
        for qi, req in enumerate(qlist):
            if not free:
                break
            if blocked and qi >= self.admit_window:
                break                  # bounded skip-ahead exhausted
            if req.tid in blocked:
                continue               # preserve per-tenant FIFO
            plen = len(req.prompt)
            need = -(-(plen + req.max_new_tokens) // self.page)
            # prefix-shared pages cost no new capacity: charge admission
            # credits only for the uncovered suffix
            probe = self.mmu.probe_prefix(req.prompt)
            need -= probe // self.page
            if need > self.mmu.config.n_pages - (
                    self.mmu.utilization()["pages_used"]):
                blocked.add(req.tid)   # page credits exhausted for this
                continue               # size; try smaller ones behind it
            i = free.pop(0)
            # a row that will chunk-prefill must NOT publish its prompt
            # pages into the prefix index yet: the pages exist at
            # admission but their KV lands over later steps — a sharer
            # admitted in between would read unwritten KV.  Publication
            # happens when the final chunk lands (_prefill_chunks).
            will_chunk = (self.prefill_chunk is not None
                          and plen - probe > self.prefill_chunk)
            covered = self.mmu.alloc_seq(req.rid, plen, slot=i,
                                         prompt_tokens=req.prompt,
                                         publish=not will_chunk)
            self.slots[i] = req
            taken.add(qi)
            if will_chunk:
                # long uncovered suffix: stream it chunk-by-chunk.  The
                # row holds its slot + pages but stays UNBOUND from the
                # decode batch until the final chunk samples its first
                # token — decode steps keep running at full speed.
                req.prefill_pos = covered
                self.prefill_skipped += covered
            else:
                self.block_table.bind(i, req.rid)
                qstart = covered if covered < plen else plen - 1
                self.prefill_computed += plen - qstart
                self.prefill_skipped += qstart
                oneshot.append((i, req, qstart, covered))
        if taken:
            self.queue = deque(r for qi, r in enumerate(qlist)
                               if qi not in taken)
        if oneshot:
            self._prefill_batch(oneshot)

    def _prefill_chunks(self) -> None:
        """Advance every chunk-prefilling row by ONE chunk.

        Intermediate chunks run through ``prefill_chunk_paged`` (KV
        writes only — no logits, no PRNG use), batched into one padded
        forward.  Rows whose remaining suffix now fits a single chunk
        take the normal ``_prefill_batch`` path, which samples their
        first token and binds them into the decode batch — from then on
        they are indistinguishable from one-shot admissions, which is
        why chunked and one-shot token streams match token-for-token.
        """
        rows = [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.prefill_pos >= 0]
        if not rows:
            return
        inter, finals = [], []
        for i, req in rows:
            if len(req.prompt) - req.prefill_pos <= self.prefill_chunk:
                finals.append((i, req))
            else:
                inter.append((i, req))
        if inter:
            t0 = time.perf_counter()
            n = len(inter)
            nb = _bucket(n, self.max_batch)
            chunk = self.prefill_chunk
            smax = max(len(r.prompt) for _, r in inter)
            maxp = max(self.max_pages,
                       -(-_bucket(smax, 1 << 30) // self.page))
            tables = np.full((nb, maxp), -1, np.int32)
            tables[:n] = self.mmu.block_table(
                [req.rid for _, req in inter], maxp)
            q_starts = np.zeros((nb,), np.int32)
            q_lens = np.zeros((nb,), np.int32)
            tokens = np.zeros((nb, chunk), np.int32)
            for j, (_, req, ) in enumerate(inter):
                q_starts[j] = req.prefill_pos
                q_lens[j] = chunk
                tokens[j] = req.prompt[req.prefill_pos:
                                       req.prefill_pos + chunk]
            self.pools = self._prefill_chunk(
                self.params, self.pools, jnp.asarray(tokens),
                jnp.asarray(q_lens), jnp.asarray(q_starts),
                jnp.asarray(tables))
            jax.block_until_ready(self.pools["k"])
            n_tok = n * chunk
            self.prefill_computed += n_tok
            self.ewma_prefill_s_per_tok = self._ewma(
                self.ewma_prefill_s_per_tok,
                (time.perf_counter() - t0) / n_tok)
            self.prefill_obs += 1
            for _, req in inter:
                # the chunk's KV just landed in pages allocated at
                # admission — dirty them NOW, not at alloc time, so a
                # pre-copy round between alloc and write can't clear
                # the flag before the content exists
                self.mmu.mark_dirty_range(req.rid, req.prefill_pos,
                                          req.prefill_pos + chunk)
                req.prefill_pos += chunk
        if finals:
            batch = []
            for i, req in finals:
                self.block_table.bind(i, req.rid)
                plen = len(req.prompt)
                qstart = req.prefill_pos
                self.prefill_computed += plen - qstart
                # write_from == qstart: every earlier position was
                # written by a previous chunk or a shared prefix page
                batch.append((i, req, qstart, qstart))
                req.prefill_pos = -1
            self._prefill_batch(batch)
            # every prompt position's KV is now resident: the deferred
            # prefix-index publication (alloc_seq publish=False) is safe
            for _, req in finals:
                self.mmu.publish_prefix(req.rid, req.prompt)

    def _prefill_batch(self, rows) -> None:
        """One padded forward for a batch of prefill-finishing rows.

        ``rows`` are (slot, request, qstart, write_from): row j computes
        queries for ``prompt[qstart:]`` and scatters KV only at
        positions >= ``write_from`` (shared prefix pages and
        already-chunked positions are never rewritten).  One-shot
        admissions pass qstart = coverage (or len-1 when fully covered);
        final chunks pass qstart = write_from = their chunk cursor.
        Using ONE kernel for shared, unshared, and chunked rows is what
        makes the parity bit-exact — a row's ops depend only on its own
        tokens, absolute positions, and page bytes, so identical rows
        produce identical tokens whatever the rest of the wave skipped.
        Prefill accounting (prefill_computed/skipped) is the CALLER's
        job — chunked rows bill incrementally as chunks land.
        """
        t0 = time.perf_counter()
        n = len(rows)
        nb = _bucket(n, self.max_batch)
        smax = max(len(r.prompt) for _, r, _, _ in rows)
        # prompts may exceed max_len (such requests finish right after
        # prefill): size the prefill tables for the longest prompt
        maxp = max(self.max_pages, -(-_bucket(smax, 1 << 30) // self.page))
        temps = np.zeros((nb,), np.float32)
        topks = np.zeros((nb,), np.int32)
        topps = np.ones((nb,), np.float32)
        tables = np.full((nb, maxp), -1, np.int32)
        tables[:n] = self.mmu.block_table(
            [req.rid for _, req, _, _ in rows], maxp)
        q_starts = np.zeros((nb,), np.int32)
        q_lens = np.zeros((nb,), np.int32)
        write_from = np.zeros((nb,), np.int32)
        for j, (_, req, qstart, wfrom) in enumerate(rows):
            temps[j] = req.temperature
            topks[j] = req.top_k
            topps[j] = req.top_p
            q_starts[j] = qstart
            q_lens[j] = len(req.prompt) - qstart
            write_from[j] = wfrom
        sb = _bucket(int(q_lens.max()), 1 << 30)
        tokens = np.zeros((nb, sb), np.int32)
        for j, (_, req, qstart, _) in enumerate(rows):
            tokens[j, :q_lens[j]] = req.prompt[qstart:]
        seq_ids = np.zeros((nb,), np.int32)
        for j, (_, req, _, _) in enumerate(rows):
            seq_ids[j] = req.rid
        first, self.pools, self.rng = self._prefill_shared(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(q_lens), jnp.asarray(q_starts),
            jnp.asarray(write_from), jnp.asarray(tables), self.rng,
            jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps),
            jnp.asarray(seq_ids))
        first = np.asarray(first)
        now = time.perf_counter()
        for _, req, _, wfrom in rows:
            # prefill KV for [write_from, plen) just landed (pre-copy
            # dirty tracking; see _prefill_chunks)
            self.mmu.mark_dirty_range(req.rid, wfrom, len(req.prompt))
        self.ewma_prefill_s_per_tok = self._ewma(
            self.ewma_prefill_s_per_tok,
            (now - t0) / max(int(q_lens.sum()), 1))
        self.prefill_obs += 1
        slots_i, srows = [], []
        for j, (i, req, _, _) in enumerate(rows):
            tok = int(first[j])
            req.out_tokens.append(tok)
            req.t_first_token = now
            self.mmu.extend_seq(req.rid, 1, slot=i)
            self.tokens_out += 1
            if len(req.prompt) + 1 >= self.max_len:
                # no decode budget left: complete straight from prefill
                req.done = True
                req.t_done = now
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.completed.append(req)
                self.slots[i] = None
                if self.token_sink is not None:
                    self.token_sink(req, tok, True)
                continue
            if self.token_sink is not None:
                self.token_sink(req, tok, False)
            slots_i.append(i)
            # write position of the NEXT decode step's token
            srows.append((len(req.prompt), tok, req.temperature,
                          req.top_k, req.top_p, req.rid))
        if slots_i:
            self._sync_slot_state(slots_i, srows)

    def _sync_slot_state(self, slots_i, rows) -> None:
        """Push slot-transition deltas into the device-resident state
        (admissions and frees only — never on the per-step path).
        ``rows`` is a list of (len, token, temperature, top_k, top_p,
        rid)."""
        idx = jnp.asarray(slots_i, jnp.int32)
        lens, toks, temps, topks, topps, rids = zip(*rows)
        self.dev_rids = self.dev_rids.at[idx].set(
            jnp.asarray(rids, jnp.int32))
        self.dev_lens = self.dev_lens.at[idx].set(
            jnp.asarray(lens, jnp.int32))
        self.dev_tokens = self.dev_tokens.at[idx].set(
            jnp.asarray(toks, jnp.int32))
        self.dev_temps = self.dev_temps.at[idx].set(
            jnp.asarray(temps, jnp.float32))
        self.dev_topk = self.dev_topk.at[idx].set(
            jnp.asarray(topks, jnp.int32))
        self.dev_topp = self.dev_topp.at[idx].set(
            jnp.asarray(topps, jnp.float32))

    def _sample(self, logits: np.ndarray, temperature: float,
                top_k: int = 0, top_p: float = 1.0) -> np.ndarray:
        """Host-side sampling oracle for the fused on-device sampler:
        vectorized Gumbel-max with the same top-k -> top-p filter rule
        (greedy at temperature <= 0)."""
        logits = logits[..., :self.cfg.vocab_size]
        if temperature <= 0:
            return np.argmax(logits, axis=-1)
        z = logits.astype(np.float64) / temperature
        v = z.shape[-1]
        if 0 < top_k < v:
            kth = np.sort(z, axis=-1)[..., -top_k][..., None]
            z = np.where(z < kth, -np.inf, z)
        if top_p < 1.0:
            srt = np.sort(z, axis=-1)[..., ::-1]
            ez = np.exp(srt - srt[..., :1])
            cum = np.cumsum(ez / ez.sum(axis=-1, keepdims=True), axis=-1)
            idx = np.minimum((cum < top_p).sum(axis=-1), v - 1)
            cutoff = np.take_along_axis(srt, idx[..., None], axis=-1)
            z = np.where(z < cutoff, -np.inf, z)
        u = np.clip(self._rng.random_sample(z.shape), 1e-12, 1 - 1e-12)
        g = -np.log(-np.log(u))
        return np.argmax(np.where(np.isfinite(z), z + g, -np.inf), axis=-1)

    # ------------------------------------------------------------ decode ----
    def step(self) -> int:
        """One continuous-batching engine step; returns tokens emitted."""
        if self.shell is not None:
            health = getattr(self.shell, "health", None)
            if health is not None:
                health.beat(self.slot)      # watchdog: slot is decoding
        self._settle_io()
        if self.admission_hook is not None:
            self.admission_hook(self)
        self._admit()
        self._prefill_chunks()
        # decode runs over BOUND rows only: chunk-prefilling rows hold a
        # slot + pages but emit nothing until their final chunk lands
        live = [i for i, r in enumerate(self.slots)
                if r is not None and r.prefill_pos < 0]
        if not live:
            return 0
        t0 = time.perf_counter()
        tables = self.block_table.device_view()
        # rows whose mapping changed (page crossing, eviction, fault-back)
        # re-sync lens/tokens from host truth, so device state can never
        # drift from the MMU even when a live row loses a page under
        # pressure.  Steady-state steps see no updated rows and skip this.
        upd = [i for i in self.block_table.last_updated_rows
               if self.slots[i] is not None
               and self.slots[i].prefill_pos < 0]
        if upd:
            self._sync_slot_state(
                upd,
                [(len(self.slots[i].prompt)
                  + len(self.slots[i].out_tokens) - 1,
                  self.slots[i].out_tokens[-1],
                  self.slots[i].temperature,
                  self.slots[i].top_k,
                  self.slots[i].top_p,
                  self.slots[i].rid) for i in upd])
        next_toks, self.pools, self.dev_lens, self.rng = self._decode_step(
            self.params, self.pools, tables, self.dev_lens,
            self.dev_tokens, self.rng, self.dev_temps, self.dev_topk,
            self.dev_topp, self.dev_rids)
        self.dev_tokens = next_toks
        # the ONLY per-step device->host sync: the (B,) int32 token vector
        toks = np.asarray(next_toks)
        self.ewma_decode_step_s = self._ewma(
            self.ewma_decode_step_s, time.perf_counter() - t0)
        self.decode_obs += 1
        self.steps += 1
        self._submit_step_io(n_live=len(live))

        emitted = 0
        freed = []
        for i in live:
            req = self.slots[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            emitted += 1
            self.mmu.extend_seq(req.rid, 1, slot=i)
            total = len(req.prompt) + len(req.out_tokens)
            if (len(req.out_tokens) >= req.max_new_tokens
                    or total >= self.max_len):
                req.done = True
                req.t_done = time.perf_counter()
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.completed.append(req)
                self.slots[i] = None
                freed.append(i)
            if self.token_sink is not None:
                self.token_sink(req, tok, req.done)
        if freed:
            self._sync_slot_state(freed, [(0, 0, 0.0, 0, 1.0, 0)] * len(freed))
        self.tokens_out += emitted
        return emitted

    # ---------------------------------------------------------- billing ----
    def _submit_step_io(self, n_live: int) -> None:
        """Bill this decode step's host I/O — one int32 token per live
        row is all that crosses the link — to our tenant through the
        slot's unified Port (``port.submit`` -> shell scheduler).
        Submission is async: the future is collected and settled at the
        next step boundary.  Only the scheduler's submitter back-pressure
        (tenant pending bound) can block here, which is the intended
        self-containment of an over-subscribed tenant."""
        if self.port is None or n_live == 0:
            return
        from repro.core.port import Invocation
        nbytes = n_live * 4
        self.io_bytes += nbytes
        fut = self.port.submit(Invocation.io(
            nbytes, tag="decode_io", tenant=self.tenant))
        self._io_futs.append(fut)

    def _settle_io(self) -> None:
        """Drop completed I/O futures (non-blocking settle)."""
        if self._io_futs:
            self._io_futs = [f for f in self._io_futs if not f.done()]

    def flush_io(self, timeout: float = 30.0, *,
                 strict: bool = False) -> bool:
        """Wait (bounded by one shared deadline) for outstanding billed
        I/O to clear the link.

        A future that FAILED with a typed ``PortError`` is settled — the
        error was already delivered and health-recorded by the port
        layer — and counted in ``io_failures``.  Futures that neither
        complete nor fail stay queued so accounting is never silently
        dropped.  Returns True when fully drained; a timeout is recorded
        as an ``io_flush_timeout`` health event when shell-bound, and
        ``strict=True`` raises it as a typed ``PortError`` instead of
        returning False."""
        deadline = time.perf_counter() + timeout
        remaining = []
        for fut in self._io_futs:
            left = deadline - time.perf_counter()
            try:
                comp = fut.completion(timeout=max(left, 0.0))
            except BaseException:  # noqa: BLE001 — typed failure: the
                self.io_failures += 1  # IO never cleared but is settled
                continue
            if comp is None and not fut.done():
                remaining.append(fut)
        self._io_futs = [f for f in remaining if not f.done()]
        if not self._io_futs:
            return True
        health = getattr(self.shell, "health", None)
        msg = (f"{len(self._io_futs)} decode-IO future(s) still pending "
               f"after {timeout}s on slot {self.slot}")
        if health is not None:
            health.record_fault(FaultKind.IO_FLUSH_TIMEOUT,
                                slot=self.slot, tenant=self.tenant,
                                site="engine.flush_io", strike=False,
                                msg=msg)
        if strict:
            raise PortError(msg, kind=FaultKind.IO_FLUSH_TIMEOUT,
                            slot=self.slot, tenant=self.tenant,
                            retryable=True)
        return False

    # ------------------------------------------- migration state (v2) ------
    @staticmethod
    def _req_to_dict(req: Request) -> Dict:
        return {"rid": req.rid, "prompt": list(req.prompt),
                "max_new_tokens": req.max_new_tokens,
                "temperature": float(req.temperature),
                "top_k": int(req.top_k), "top_p": float(req.top_p),
                "tid": req.tid, "priority": int(req.priority),
                "deadline_s": (None if req.deadline_s is None
                               else float(req.deadline_s)),
                "out_tokens": list(req.out_tokens),
                "t_submit": float(req.t_submit),
                "t_first_token": float(req.t_first_token)}

    @staticmethod
    def _req_from_dict(d: Dict) -> Request:
        dl = d.get("deadline_s")
        return Request(rid=int(d["rid"]), prompt=list(d["prompt"]),
                       max_new_tokens=int(d["max_new_tokens"]),
                       temperature=float(d["temperature"]),
                       top_k=int(d["top_k"]), top_p=float(d["top_p"]),
                       tid=int(d["tid"]),
                       priority=int(d.get("priority", 0)),
                       deadline_s=None if dl is None else float(dl),
                       out_tokens=list(d["out_tokens"]),
                       t_submit=float(d["t_submit"]),
                       t_first_token=float(d["t_first_token"]))

    def geometry(self) -> Dict[str, int]:
        """The shape contract a migration peer must match byte-for-byte:
        page geometry and the KV head layout of the pools."""
        return {"page_size": self.page,
                "n_layers": self.cfg.n_layers,
                "n_kv_heads": self.cfg.n_kv_heads,
                "head_dim": self.cfg.resolved_head_dim,
                "vocab_size": self.cfg.vocab_size}

    def snapshot_state(self, *, only_pages=None) -> Tuple[Dict, Dict]:
        """Freeze this engine's paged tenant state for migration.

        ``only_pages`` (a set of MMU share keys — ``("d", ppage)`` /
        ``("h", hslot)``) restricts the shipped PAYLOADS to that subset:
        pre-copy migrations pass the final dirty delta so the freeze
        gathers O(delta) pages instead of the whole KV footprint.  The
        header (page tables, requests, queue, PRNG) is always complete.

        Returns ``(header, arrays)``: a JSON-safe header (in-flight and
        queued requests, the MMU page-table snapshot, the gather order of
        the live pages, geometry) and an array pytree (the PRNG key, the
        device-side compact KV gather of every live page, preserved
        host-evicted page payloads).  The engine must be quiesced: no
        concurrent ``step()``.  Nothing here is pickled — the pair feeds
        ``repro.core.bitstream.encode("migration", ...)`` directly.
        """
        # rows still mid-chunk-prefill (no sampled token yet) are demoted
        # back to the queue: their partial KV is cheap to recompute and
        # carries no sampled state, so the destination just re-prefills —
        # token streams are unaffected (prefill is deterministic and the
        # PRNG is untouched until the first sample)
        reqs = [{"slot": i, **self._req_to_dict(r)}
                for i, r in enumerate(self.slots)
                if r is not None and r.prefill_pos < 0]
        demoted = [r for r in self.slots
                   if r is not None and r.prefill_pos >= 0]
        seq_ids = [r["rid"] for r in reqs]
        mmu_snap = self.mmu.snapshot_seqs(seq_ids)
        # dedupe: each physical page (device ppage / host slot) ships
        # ONCE however many sequences share it — restore_seqs rebuilds
        # the sharing from the per-seq page tables in ``mmu_snap``
        pages, host_pages = [], {}
        seen_pp = set()
        for sd in mmu_snap["seqs"]:
            for p in sd["pages"]:
                if p["on_host"]:
                    hs = int(p.get("host_slot", -1))
                    if (only_pages is not None and hs >= 0
                            and ("h", hs) not in only_pages):
                        continue
                    key = (f"h:{hs}" if hs >= 0
                           else f"u:{sd['seq_id']}:{p['vpage']}")
                    if key in host_pages:
                        continue
                    data = self.mmu.host_page_data(sd["seq_id"],
                                                   p["vpage"])
                    if data is not None:
                        host_pages[key] = {
                            "k": np.asarray(data["k"]),
                            "v": np.asarray(data["v"])}
                elif p["ppage"] not in seen_pp:
                    seen_pp.add(p["ppage"])
                    if (only_pages is not None
                            and ("d", p["ppage"]) not in only_pages):
                        continue
                    pages.append({"ppage": p["ppage"]})
        header = {
            "geometry": self.geometry(),
            "requests": reqs,
            "queue": [self._req_to_dict(r)
                      for r in list(demoted) + list(self.queue)],
            "mmu": mmu_snap,
            "pages": pages,          # gather order of kv_k/kv_v rows
        }
        arrays: Dict = {"rng": np.asarray(self.rng)}
        if pages:
            pps = [p["ppage"] for p in pages]
            L = self.cfg.n_layers
            if only_pages is not None:
                # latency-critical freeze window (pre-copy delta): pad
                # the gather to a power-of-two bucket so freezes with
                # slightly different delta sizes hit one compiled
                # gather instead of retracing inside the downtime gap;
                # the shipped arrays are trimmed back to the real count
                nb = bucket_pages(len(pps))
                flat = flat_page_indices(pps + [pps[-1]] * (nb - len(pps)),
                                         L, self.mmu.config.n_pages)
                kv = gather_kv_pages(self.pools, flat)

                def _trim(x):
                    x = np.asarray(x).reshape(L, nb, *x.shape[1:])
                    return np.ascontiguousarray(
                        x[:, :len(pps)]).reshape(L * len(pps),
                                                 *x.shape[2:])
                arrays["kv_k"] = _trim(kv["k"])
                arrays["kv_v"] = _trim(kv["v"])
            else:
                flat = flat_page_indices(pps, L, self.mmu.config.n_pages)
                kv = gather_kv_pages(self.pools, flat)
                arrays["kv_k"] = np.asarray(kv["k"])
                arrays["kv_v"] = np.asarray(kv["v"])
        if host_pages:
            arrays["host_pages"] = host_pages
        return header, arrays

    def restore_state(self, header: Dict, arrays: Dict, *,
                      staged=None) -> Dict[str, int]:
        """Adopt a migrated tenant: fresh page allocation on OUR MMU,
        block-table rebuild (dirty rows upload on the next view), KV
        payload scattered to the new physical pages, decode state synced,
        PRNG stream adopted.  In-flight requests land on their original
        slot index when free (keeps the sampled noise stream aligned
        row-for-row), else the first free slot.

        ``staged`` (pre-copy): ``{source share key: our ppage}`` of
        pages already filled by warm rounds — forwarded to
        ``MMU.restore_seqs`` so those mappings adopt the staged pages;
        the delta payloads in ``arrays`` then overwrite exactly the
        pages that changed after their last warm copy."""
        g = header["geometry"]
        mine = self.geometry()
        if g != mine:
            raise ValueError(
                f"migration geometry mismatch: snapshot {g} vs "
                f"destination {mine} — KV pages are not byte-compatible")
        reqs = header["requests"]
        free = [i for i in range(self.max_batch)
                if self.slots[i] is None]
        if len(reqs) > len(free):
            raise ValueError(
                f"destination engine has {len(free)} free slots for "
                f"{len(reqs)} in-flight migrated requests")
        mapping = self.mmu.restore_seqs(header["mmu"], slot=self.slot,
                                        staged=staged)
        # shared source pages restored to ONE destination page each:
        # index the new ppage by old device ppage / host slot so every
        # shipped payload (deduped at snapshot) scatters exactly once
        by_old, by_hslot, by_sv = {}, {}, {}
        for sid, pl in mapping.items():
            for p in pl:
                if p["was_host"]:
                    if p["host_slot"] >= 0:
                        by_hslot[p["host_slot"]] = p["new_ppage"]
                    by_sv[(sid, p["vpage"])] = p["new_ppage"]
                else:
                    by_old[p["old_ppage"]] = p["new_ppage"]
        n_pages = self.mmu.config.n_pages
        if header["pages"]:
            new_pps = [by_old[p["ppage"]] for p in header["pages"]]
            kk = np.asarray(arrays["kv_k"])
            vv = np.asarray(arrays["kv_v"])
            if staged is not None:
                # pre-copy delta restore runs inside the freeze window:
                # pad to the same power-of-two bucket as the snapshot
                # gather (pad = last real page repeated; duplicate
                # indices carry identical rows, so the extra scatter
                # writes are no-ops) to avoid a per-delta-size retrace
                L = self.cfg.n_layers
                nb = bucket_pages(len(new_pps))
                pad = nb - len(new_pps)
                if pad:
                    def _pad(x):
                        x = x.reshape(L, -1, *x.shape[1:])
                        x = np.concatenate(
                            [x, np.repeat(x[:, -1:], pad, axis=1)],
                            axis=1)
                        return x.reshape(L * nb, *x.shape[2:])
                    kk, vv = _pad(kk), _pad(vv)
                    new_pps = new_pps + [new_pps[-1]] * pad
            flat = flat_page_indices(new_pps, self.cfg.n_layers, n_pages)
            self.pools = self._adopt_pools(scatter_kv_pages(
                self.pools, flat, {"k": jnp.asarray(kk),
                                   "v": jnp.asarray(vv)}))
        for key, data in (arrays.get("host_pages") or {}).items():
            if key.startswith("h:"):
                new_pp = by_hslot[int(key[2:])]
            else:                       # "u:<sid>:<vpage>" legacy pages
                _, sid, vpage = key.split(":")
                new_pp = by_sv[(int(sid), int(vpage))]
            flat = flat_page_indices([new_pp], self.cfg.n_layers, n_pages)
            self.pools = self._adopt_pools(scatter_kv_pages(
                self.pools, flat, {"k": jnp.asarray(data["k"]),
                                   "v": jnp.asarray(data["v"])}))
        slots_i, rows = [], []
        for rd in reqs:
            req = self._req_from_dict(rd)
            want = int(rd.get("slot", -1))
            i = want if (0 <= want < self.max_batch
                         and self.slots[want] is None) else free[0]
            free.remove(i)
            self.slots[i] = req
            self.block_table.bind(i, req.rid)
            assert req.out_tokens, "in-flight request without prefill"
            slots_i.append(i)
            rows.append((len(req.prompt) + len(req.out_tokens) - 1,
                         req.out_tokens[-1], req.temperature,
                         req.top_k, req.top_p, req.rid))
        if slots_i:
            self._sync_slot_state(slots_i, rows)
        for rd in header["queue"]:
            self.queue.append(self._req_from_dict(rd))
        self.rng = self._place(jnp.asarray(arrays["rng"]))
        adopted = ([r["rid"] for r in reqs]
                   + [r["rid"] for r in header["queue"]])
        if adopted:
            self._rid_next = max(self._rid_next, max(adopted) + 1)
        return {"requests": len(reqs), "queued": len(header["queue"]),
                "pages": len(header["pages"])
                + len(arrays.get("host_pages") or {})}

    def reset_decode_state(self) -> None:
        """Cold-reset the engine's device-side soft state — the local
        analogue of restarting the slot's logic after a crash: a fresh
        block-table view, zeroed lens/tokens/sampling params, dropped
        billed-IO futures, full TLB flush.  KV pool *contents* are not
        touched: :meth:`restore_state` scatters the preserved page
        payloads back in right after, which is what makes a recovery
        KV-intact instead of a re-prefill."""
        self.block_table = self.mmu.block_table_device(
            self.max_batch, self.max_pages,
            sharding=self.tp.replicated if self.tp is not None else None)
        self.dev_lens = self._place(jnp.zeros((self.max_batch,), jnp.int32))
        self.dev_tokens = self._place(
            jnp.zeros((self.max_batch,), jnp.int32))
        self.dev_temps = self._place(
            jnp.zeros((self.max_batch,), jnp.float32))
        self.dev_topk = self._place(jnp.zeros((self.max_batch,), jnp.int32))
        self.dev_topp = self._place(jnp.ones((self.max_batch,), jnp.float32))
        self.dev_rids = self._place(jnp.zeros((self.max_batch,), jnp.int32))
        self._io_futs = []
        self.mmu.tlb.invalidate()

    def evacuate(self) -> Dict[str, int]:
        """Release the tenant's paged state AFTER a successful snapshot
        restore elsewhere: free every sequence on our MMU (returning the
        pages to the shared pool), unbind block-table rows, clear the
        run queue.  The engine stays usable for new work."""
        freed, n_seqs = [], 0
        for i, req in enumerate(self.slots):
            if req is not None:
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.slots[i] = None
                freed.append(i)
                n_seqs += 1
        if freed:
            self._sync_slot_state(freed, [(0, 0, 0.0, 0, 1.0, 0)] * len(freed))
        n_q = len(self.queue)
        self.queue.clear()
        return {"seqs": n_seqs, "queued": n_q}

    def latency_stats(self) -> Dict[str, float]:
        """TTFT/TPOT percentiles over completed requests (milliseconds).

        TTFT = first sampled token's wall time minus ``t_submit``;
        TPOT = mean seconds per decode token after the first.  Both were
        always recorded per request (``t_submit``/``t_first_token``/
        ``t_done``) — this aggregates them into the p50/p99 view every
        serving paper quotes.
        """
        ttfts, tpots = [], []
        for r in self.completed:
            if r.t_first_token > 0 and r.t_submit > 0:
                ttfts.append(r.t_first_token - r.t_submit)
            n_dec = len(r.out_tokens) - 1
            if r.t_done > 0 and r.t_first_token > 0 and n_dec > 0:
                tpots.append((r.t_done - r.t_first_token) / n_dec)
        out: Dict[str, float] = {}
        if ttfts:
            out["ttft_p50_ms"] = float(np.percentile(ttfts, 50) * 1e3)
            out["ttft_p99_ms"] = float(np.percentile(ttfts, 99) * 1e3)
        if tpots:
            out["tpot_p50_ms"] = float(np.percentile(tpots, 50) * 1e3)
            out["tpot_p99_ms"] = float(np.percentile(tpots, 99) * 1e3)
        return out

    def run(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        while self.pending() and self.steps < max_steps:
            self.step()
            # decode-step preemption checkpoint: when this loop is the
            # body of a long-running port invocation on a lane, yield to
            # higher-priority granted work between steps (no-op off-lane)
            if self.shell is not None:
                self.shell.scheduler.checkpoint(self.slot)
        drained = self.flush_io()
        dt = time.perf_counter() - t0
        stats = {"wall_s": dt, "engine_steps": self.steps,
                 "tokens": self.tokens_out,
                 "tokens_per_s": self.tokens_out / max(dt, 1e-9),
                 "completed": len(self.completed),
                 "prefill_computed": self.prefill_computed,
                 "prefill_skipped": self.prefill_skipped}
        stats.update(self.latency_stats())
        if self.shell is not None and self.tenant is not None:
            stats["io_drained"] = drained
            stats["io_pending"] = self.shell.scheduler.tenant_pending(
                self.tenant)
        return stats
