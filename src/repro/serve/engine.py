"""Continuous-batching serving engine on the MMU's paged KV cache.

The LLM mirror of the paper's multi-threaded AES pipeline (Fig 1/9/10):
token-by-token decode has a strict sequential dependence per request, so a
single stream leaves the pipeline idle — the engine fills the bubbles by
interleaving many concurrent requests (cThread streams) into one batched
decode step.  Admission is credit-based (page budget via the MMU), pages
are allocated on demand and freed at completion, and finished rows are
immediately replaced from the queue (continuous batching).

Hot-path invariants (the Coyote v2 "shell out of the datapath" story):

  * **Device-resident state.**  The KV pools, block tables, row lengths,
    last-sampled tokens, per-row temperatures, and the PRNG key all live
    on device.  Block tables are a cached :class:`DeviceBlockTable` view
    owned by the MMU — rows are re-uploaded only when an alloc/extend/
    free/evict delta changes a sequence's mapping (i.e. on page-boundary
    crossings and slot churn), never per step.
  * **Donation.**  ``decode_step_paged`` donates the pools and the
    decode-state buffers, so KV is updated in place instead of copied.
    ``self.pools`` / ``self.dev_lens`` / ``self.dev_tokens`` /
    ``self.rng`` must be reassigned from the step's return values every
    call — holding a stale reference to a donated buffer is an error.
    The block-table view is NOT donated (the cache reuses it).
  * **One (B,) vector per step.**  Sampling (greedy argmax + Gumbel-max
    temperature) is fused inside the jitted step; the (B, vocab) logits
    tensor never leaves the device.  The only per-step host<->device
    traffic is reading back the (B,) int32 token vector.
  * **Batched prefill.**  All requests admitted in one ``_admit()`` pass
    run as a single padded forward (``prefill_shared_paged``), with
    suffix lengths and batch counts bucketed to powers of two to bound
    retraces.  Prompt pages the MMU mapped onto shared prefix pages are
    skipped entirely — only the uncovered suffix is computed.
  * **Non-blocking billing.**  Decode-step I/O is submitted to the shell
    scheduler asynchronously; credits settle at step boundaries
    (``_settle_io``) and ``flush_io()`` drains the tail, so in normal
    operation QoS accounting never stalls the decode loop.  The one
    intended exception is the scheduler's submitter-side back-pressure:
    a tenant whose pending I/O hits its bound stalls *itself* at submit
    (paper §7.2 containment) — that is the QoS design, not a hot-path
    regression.
  * **One compilation.**  ``decode_step_paged`` traces exactly once per
    (engine shape, flags) across a run regardless of occupancy changes —
    ``repro.serve.paged_model.TRACE_COUNTS`` is the retrace guard.

Bench reproduction: ``PYTHONPATH=src python -m benchmarks.run --only
llm_serving`` (writes ``BENCH_serving.json``), or ``scripts/ci.sh`` for
the tier-1 smoke path plus the quick bench.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.faults import FaultKind
from repro.core.port import PortError
from repro.core.services.mmu import MMU, MMUConfig
from repro.serve.paged_model import (decode_step_paged, flat_page_indices,
                                     gather_kv_pages, make_pools,
                                     prefill_shared_paged,
                                     scatter_kv_pages)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = disabled
    top_p: float = 1.0                # >= 1 = disabled
    tid: int = 0                      # submitting cThread
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    done: bool = False


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two (capped) so padded prefill shapes
    bucket into O(log) distinct compilations."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, mmu: MMU, *,
                 max_batch: int = 8, max_len: int = 1024,
                 use_pallas: bool = False,
                 pages_per_block: Optional[int] = None, seed: int = 0,
                 shell=None, slot: int = 0, tenant: Optional[str] = None,
                 rid_base: int = 0):
        assert cfg.ssm is None and len(cfg.block_pattern) == 1, \
            "paged engine serves attention archs (DESIGN.md §5)"
        self.cfg = cfg
        self.params = params
        self.mmu = mmu
        self.page = mmu.config.page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.max_pages = -(-max_len // self.page)
        self.use_pallas = use_pallas
        self.pages_per_block = pages_per_block
        self.pools = make_pools(cfg, mmu.config.n_pages, self.page)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self._rng = np.random.RandomState(seed)     # host sampling oracle
        # request/sequence ids: ``rid_base`` namespaces the id range so
        # a migration destination adopting foreign rids (or shells whose
        # engines use per-tenant MMU instances) never collides in the
        # page tables.  NOTE: two paged engines must NOT share one MMU
        # instance — register_pager(owner=...) enforces it.
        self._rid_next = rid_base + 1
        self.completed: List[Request] = []
        self.steps = 0
        self.tokens_out = 0
        # prefix-sharing accounting: prompt tokens actually run through a
        # prefill forward vs tokens whose KV came from shared pages
        self.prefill_computed = 0
        self.prefill_skipped = 0
        # Device-resident decode state: block tables (cached MMU view),
        # row lengths, last tokens, temperatures, PRNG key.
        self.block_table = mmu.block_table_device(max_batch, self.max_pages)
        self.dev_lens = jnp.zeros((max_batch,), jnp.int32)
        self.dev_tokens = jnp.zeros((max_batch,), jnp.int32)
        self.dev_temps = jnp.zeros((max_batch,), jnp.float32)
        self.dev_topk = jnp.zeros((max_batch,), jnp.int32)
        self.dev_topp = jnp.ones((max_batch,), jnp.float32)
        self.rng = jax.random.PRNGKey(seed)
        # Optional shell binding: decode-step I/O is then submitted through
        # the slot's unified Port (Port API v2) into the shell scheduler
        # (weighted credits + arbiter) instead of bypassing the shared
        # link — multi-tenant serving engines contend for bandwidth
        # exactly like any other vFPGA traffic.
        self.shell = shell
        self.slot = slot
        self.tenant = tenant
        self.io_bytes = 0
        self.io_failures = 0          # billed-IO futures that failed typed
        self._io_futs: List = []
        self.port = (shell.attach(slot, tenant=tenant)
                     if shell is not None else None)
        if shell is not None:
            shell.engines[slot] = self     # migrate() resolves us by slot
        # evict-with-copy: the MMU pager gathers a page's KV payload off
        # the device before recycling the page and scatters it back on
        # fault-in.  owner=self makes the one-pool-owner-per-MMU rule
        # explicit: a second engine on this MMU is refused at
        # construction, not discovered as silent KV corruption on evict.
        mmu.register_pager(self._pager_gather, self._pager_scatter,
                           owner=self)

    # ------------------------------------------------- evict-with-copy -----
    def _pager_gather(self, ppage: int) -> Dict[str, np.ndarray]:
        """Copy one physical page's KV (all layers) to host — called by
        the MMU just before it recycles the device page."""
        flat = flat_page_indices([ppage], self.cfg.n_layers,
                                 self.mmu.config.n_pages)
        kv = gather_kv_pages(self.pools, flat)
        return {"k": np.asarray(kv["k"]), "v": np.asarray(kv["v"])}

    def _pager_scatter(self, ppage: int,
                       data: Dict[str, np.ndarray]) -> None:
        """Write a preserved page payload into a freshly mapped device
        page (MMU fault-back-in path)."""
        flat = flat_page_indices([ppage], self.cfg.n_layers,
                                 self.mmu.config.n_pages)
        self.pools = scatter_kv_pages(
            self.pools, flat, {"k": jnp.asarray(data["k"]),
                               "v": jnp.asarray(data["v"])})

    # -------------------------------------------------------------- API ----
    def submit(self, prompt: List[int], max_new_tokens: int = 16, *,
               temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0, tid: int = 0) -> int:
        if prompt and (min(prompt) < 0 or max(prompt) >= self.cfg.vocab_size):
            # out-of-range ids would embed as NaN (XLA gathers fill OOB
            # reads) and silently poison the KV cache; fail at the door
            raise ValueError(
                f"prompt token out of range for vocab_size="
                f"{self.cfg.vocab_size}")
        health = getattr(self.shell, "health", None)
        if health is not None and health.is_quarantined(self.tenant):
            # graceful degradation: a repeatedly-faulting tenant is
            # rejected fast with a typed error, bystanders keep flowing
            health.record_rejection(self.tenant)
            raise PortError(
                f"tenant {self.tenant!r} is quarantined (repeated faults "
                "within the quarantine window); "
                "shell.health.unquarantine() to lift",
                kind=FaultKind.QUARANTINED, slot=self.slot,
                tenant=self.tenant, retryable=False)
        rid = self._rid_next
        self._rid_next += 1
        self.queue.append(Request(
            rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
            temperature=temperature, top_k=top_k, top_p=top_p, tid=tid,
            t_submit=time.perf_counter()))
        return rid

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def pending(self) -> bool:
        return self.active > 0 or bool(self.queue)

    # -------------------------------------------------------- admission ----
    def _admit(self) -> None:
        admitted = []
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = -(-(len(req.prompt) + req.max_new_tokens) // self.page)
            # prefix-shared pages cost no new capacity: charge admission
            # credits only for the uncovered suffix
            need -= self.mmu.probe_prefix(req.prompt) // self.page
            if need > self.mmu.config.n_pages - (
                    self.mmu.utilization()["pages_used"]):
                break                          # page credits exhausted
            self.queue.popleft()
            covered = self.mmu.alloc_seq(req.rid, len(req.prompt), slot=i,
                                         prompt_tokens=req.prompt)
            self.slots[i] = req
            self.block_table.bind(i, req.rid)
            admitted.append((i, req, covered))
        if admitted:
            self._prefill_batch(admitted)

    def _prefill_batch(self, admitted) -> None:
        """One padded forward for every request admitted in this pass.

        ``admitted`` rows are (slot, request, covered) — ``covered`` is
        the prompt-token count the MMU mapped onto shared prefix pages.
        Every wave runs through ``prefill_shared_paged``: row j computes
        only ``prompt[qstart:]`` (all of it at zero coverage; just the
        last token's query when fully covered).  Using ONE kernel for
        shared and unshared rows is what makes the sharing-on/off parity
        bit-exact — a row's ops depend only on its own tokens, absolute
        positions, and page bytes, so identical rows produce identical
        tokens whatever the rest of the wave skipped.
        """
        n = len(admitted)
        nb = _bucket(n, self.max_batch)
        smax = max(len(r.prompt) for _, r, _ in admitted)
        # prompts may exceed max_len (such requests finish right after
        # prefill): size the prefill tables for the longest prompt
        maxp = max(self.max_pages, -(-_bucket(smax, 1 << 30) // self.page))
        temps = np.zeros((nb,), np.float32)
        topks = np.zeros((nb,), np.int32)
        topps = np.ones((nb,), np.float32)
        tables = np.full((nb, maxp), -1, np.int32)
        tables[:n] = self.mmu.block_table(
            [req.rid for _, req, _ in admitted], maxp)
        q_starts = np.zeros((nb,), np.int32)
        q_lens = np.zeros((nb,), np.int32)
        write_from = np.zeros((nb,), np.int32)
        for j, (_, req, cov) in enumerate(admitted):
            temps[j] = req.temperature
            topks[j] = req.top_k
            topps[j] = req.top_p
            plen = len(req.prompt)
            qstart = cov if cov < plen else plen - 1
            q_starts[j] = qstart
            q_lens[j] = plen - qstart
            write_from[j] = cov
            self.prefill_computed += plen - qstart
            self.prefill_skipped += qstart
        sb = _bucket(int(q_lens.max()), 1 << 30)
        tokens = np.zeros((nb, sb), np.int32)
        for j, (_, req, _) in enumerate(admitted):
            tokens[j, :q_lens[j]] = req.prompt[q_starts[j]:]
        first, self.pools, self.rng = prefill_shared_paged(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(q_lens), jnp.asarray(q_starts),
            jnp.asarray(write_from), jnp.asarray(tables), self.rng,
            jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps),
            cfg=self.cfg, page_size=self.page)
        first = np.asarray(first)
        now = time.perf_counter()
        slots_i, rows = [], []
        for j, (i, req, _) in enumerate(admitted):
            tok = int(first[j])
            req.out_tokens.append(tok)
            req.t_first_token = now
            self.mmu.extend_seq(req.rid, 1, slot=i)
            self.tokens_out += 1
            if len(req.prompt) + 1 >= self.max_len:
                # no decode budget left: complete straight from prefill
                req.done = True
                req.t_done = now
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.completed.append(req)
                self.slots[i] = None
                continue
            slots_i.append(i)
            # write position of the NEXT decode step's token
            rows.append((len(req.prompt), tok, req.temperature,
                         req.top_k, req.top_p))
        if slots_i:
            self._sync_slot_state(slots_i, rows)

    def _sync_slot_state(self, slots_i, rows) -> None:
        """Push slot-transition deltas into the device-resident state
        (admissions and frees only — never on the per-step path).
        ``rows`` is a list of (len, token, temperature, top_k, top_p)."""
        idx = jnp.asarray(slots_i, jnp.int32)
        lens, toks, temps, topks, topps = zip(*rows)
        self.dev_lens = self.dev_lens.at[idx].set(
            jnp.asarray(lens, jnp.int32))
        self.dev_tokens = self.dev_tokens.at[idx].set(
            jnp.asarray(toks, jnp.int32))
        self.dev_temps = self.dev_temps.at[idx].set(
            jnp.asarray(temps, jnp.float32))
        self.dev_topk = self.dev_topk.at[idx].set(
            jnp.asarray(topks, jnp.int32))
        self.dev_topp = self.dev_topp.at[idx].set(
            jnp.asarray(topps, jnp.float32))

    def _sample(self, logits: np.ndarray, temperature: float,
                top_k: int = 0, top_p: float = 1.0) -> np.ndarray:
        """Host-side sampling oracle for the fused on-device sampler:
        vectorized Gumbel-max with the same top-k -> top-p filter rule
        (greedy at temperature <= 0)."""
        logits = logits[..., :self.cfg.vocab_size]
        if temperature <= 0:
            return np.argmax(logits, axis=-1)
        z = logits.astype(np.float64) / temperature
        v = z.shape[-1]
        if 0 < top_k < v:
            kth = np.sort(z, axis=-1)[..., -top_k][..., None]
            z = np.where(z < kth, -np.inf, z)
        if top_p < 1.0:
            srt = np.sort(z, axis=-1)[..., ::-1]
            ez = np.exp(srt - srt[..., :1])
            cum = np.cumsum(ez / ez.sum(axis=-1, keepdims=True), axis=-1)
            idx = np.minimum((cum < top_p).sum(axis=-1), v - 1)
            cutoff = np.take_along_axis(srt, idx[..., None], axis=-1)
            z = np.where(z < cutoff, -np.inf, z)
        u = np.clip(self._rng.random_sample(z.shape), 1e-12, 1 - 1e-12)
        g = -np.log(-np.log(u))
        return np.argmax(np.where(np.isfinite(z), z + g, -np.inf), axis=-1)

    # ------------------------------------------------------------ decode ----
    def step(self) -> int:
        """One continuous-batching engine step; returns tokens emitted."""
        if self.shell is not None:
            health = getattr(self.shell, "health", None)
            if health is not None:
                health.beat(self.slot)      # watchdog: slot is decoding
        self._settle_io()
        self._admit()
        if self.active == 0:
            return 0
        tables = self.block_table.device_view()
        # rows whose mapping changed (page crossing, eviction, fault-back)
        # re-sync lens/tokens from host truth, so device state can never
        # drift from the MMU even when a live row loses a page under
        # pressure.  Steady-state steps see no updated rows and skip this.
        upd = [i for i in self.block_table.last_updated_rows
               if self.slots[i] is not None]
        if upd:
            self._sync_slot_state(
                upd,
                [(len(self.slots[i].prompt)
                  + len(self.slots[i].out_tokens) - 1,
                  self.slots[i].out_tokens[-1],
                  self.slots[i].temperature,
                  self.slots[i].top_k,
                  self.slots[i].top_p) for i in upd])
        next_toks, self.pools, self.dev_lens, self.rng = decode_step_paged(
            self.params, self.pools, tables, self.dev_lens,
            self.dev_tokens, self.rng, self.dev_temps, self.dev_topk,
            self.dev_topp, cfg=self.cfg,
            page_size=self.page, use_pallas=self.use_pallas,
            pages_per_block=self.pages_per_block)
        self.dev_tokens = next_toks
        # the ONLY per-step device->host sync: the (B,) int32 token vector
        toks = np.asarray(next_toks)
        self.steps += 1
        n_live = self.active
        self._submit_step_io(n_live=n_live)

        emitted = 0
        freed = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out_tokens.append(int(toks[i]))
            emitted += 1
            self.mmu.extend_seq(req.rid, 1, slot=i)
            total = len(req.prompt) + len(req.out_tokens)
            if (len(req.out_tokens) >= req.max_new_tokens
                    or total >= self.max_len):
                req.done = True
                req.t_done = time.perf_counter()
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.completed.append(req)
                self.slots[i] = None
                freed.append(i)
        if freed:
            self._sync_slot_state(freed, [(0, 0, 0.0, 0, 1.0)] * len(freed))
        self.tokens_out += emitted
        return emitted

    # ---------------------------------------------------------- billing ----
    def _submit_step_io(self, n_live: int) -> None:
        """Bill this decode step's host I/O — one int32 token per live
        row is all that crosses the link — to our tenant through the
        slot's unified Port (``port.submit`` -> shell scheduler).
        Submission is async: the future is collected and settled at the
        next step boundary.  Only the scheduler's submitter back-pressure
        (tenant pending bound) can block here, which is the intended
        self-containment of an over-subscribed tenant."""
        if self.port is None or n_live == 0:
            return
        from repro.core.port import Invocation
        nbytes = n_live * 4
        self.io_bytes += nbytes
        fut = self.port.submit(Invocation.io(
            nbytes, tag="decode_io", tenant=self.tenant))
        self._io_futs.append(fut)

    def _settle_io(self) -> None:
        """Drop completed I/O futures (non-blocking settle)."""
        if self._io_futs:
            self._io_futs = [f for f in self._io_futs if not f.done()]

    def flush_io(self, timeout: float = 30.0, *,
                 strict: bool = False) -> bool:
        """Wait (bounded by one shared deadline) for outstanding billed
        I/O to clear the link.

        A future that FAILED with a typed ``PortError`` is settled — the
        error was already delivered and health-recorded by the port
        layer — and counted in ``io_failures``.  Futures that neither
        complete nor fail stay queued so accounting is never silently
        dropped.  Returns True when fully drained; a timeout is recorded
        as an ``io_flush_timeout`` health event when shell-bound, and
        ``strict=True`` raises it as a typed ``PortError`` instead of
        returning False."""
        deadline = time.perf_counter() + timeout
        remaining = []
        for fut in self._io_futs:
            left = deadline - time.perf_counter()
            try:
                comp = fut.completion(timeout=max(left, 0.0))
            except BaseException:  # noqa: BLE001 — typed failure: the
                self.io_failures += 1  # IO never cleared but is settled
                continue
            if comp is None and not fut.done():
                remaining.append(fut)
        self._io_futs = [f for f in remaining if not f.done()]
        if not self._io_futs:
            return True
        health = getattr(self.shell, "health", None)
        msg = (f"{len(self._io_futs)} decode-IO future(s) still pending "
               f"after {timeout}s on slot {self.slot}")
        if health is not None:
            health.record_fault(FaultKind.IO_FLUSH_TIMEOUT,
                                slot=self.slot, tenant=self.tenant,
                                site="engine.flush_io", strike=False,
                                msg=msg)
        if strict:
            raise PortError(msg, kind=FaultKind.IO_FLUSH_TIMEOUT,
                            slot=self.slot, tenant=self.tenant,
                            retryable=True)
        return False

    # ------------------------------------------- migration state (v2) ------
    @staticmethod
    def _req_to_dict(req: Request) -> Dict:
        return {"rid": req.rid, "prompt": list(req.prompt),
                "max_new_tokens": req.max_new_tokens,
                "temperature": float(req.temperature),
                "top_k": int(req.top_k), "top_p": float(req.top_p),
                "tid": req.tid, "out_tokens": list(req.out_tokens),
                "t_submit": float(req.t_submit),
                "t_first_token": float(req.t_first_token)}

    @staticmethod
    def _req_from_dict(d: Dict) -> Request:
        return Request(rid=int(d["rid"]), prompt=list(d["prompt"]),
                       max_new_tokens=int(d["max_new_tokens"]),
                       temperature=float(d["temperature"]),
                       top_k=int(d["top_k"]), top_p=float(d["top_p"]),
                       tid=int(d["tid"]),
                       out_tokens=list(d["out_tokens"]),
                       t_submit=float(d["t_submit"]),
                       t_first_token=float(d["t_first_token"]))

    def geometry(self) -> Dict[str, int]:
        """The shape contract a migration peer must match byte-for-byte:
        page geometry and the KV head layout of the pools."""
        return {"page_size": self.page,
                "n_layers": self.cfg.n_layers,
                "n_kv_heads": self.cfg.n_kv_heads,
                "head_dim": self.cfg.resolved_head_dim,
                "vocab_size": self.cfg.vocab_size}

    def snapshot_state(self) -> Tuple[Dict, Dict]:
        """Freeze this engine's paged tenant state for migration.

        Returns ``(header, arrays)``: a JSON-safe header (in-flight and
        queued requests, the MMU page-table snapshot, the gather order of
        the live pages, geometry) and an array pytree (the PRNG key, the
        device-side compact KV gather of every live page, preserved
        host-evicted page payloads).  The engine must be quiesced: no
        concurrent ``step()``.  Nothing here is pickled — the pair feeds
        ``repro.core.bitstream.encode("migration", ...)`` directly.
        """
        reqs = [{"slot": i, **self._req_to_dict(r)}
                for i, r in enumerate(self.slots) if r is not None]
        seq_ids = [r["rid"] for r in reqs]
        mmu_snap = self.mmu.snapshot_seqs(seq_ids)
        # dedupe: each physical page (device ppage / host slot) ships
        # ONCE however many sequences share it — restore_seqs rebuilds
        # the sharing from the per-seq page tables in ``mmu_snap``
        pages, host_pages = [], {}
        seen_pp = set()
        for sd in mmu_snap["seqs"]:
            for p in sd["pages"]:
                if p["on_host"]:
                    hs = int(p.get("host_slot", -1))
                    key = (f"h:{hs}" if hs >= 0
                           else f"u:{sd['seq_id']}:{p['vpage']}")
                    if key in host_pages:
                        continue
                    data = self.mmu.host_page_data(sd["seq_id"],
                                                   p["vpage"])
                    if data is not None:
                        host_pages[key] = {
                            "k": np.asarray(data["k"]),
                            "v": np.asarray(data["v"])}
                elif p["ppage"] not in seen_pp:
                    seen_pp.add(p["ppage"])
                    pages.append({"ppage": p["ppage"]})
        header = {
            "geometry": self.geometry(),
            "requests": reqs,
            "queue": [self._req_to_dict(r) for r in self.queue],
            "mmu": mmu_snap,
            "pages": pages,          # gather order of kv_k/kv_v rows
        }
        arrays: Dict = {"rng": np.asarray(self.rng)}
        if pages:
            flat = flat_page_indices([p["ppage"] for p in pages],
                                     self.cfg.n_layers,
                                     self.mmu.config.n_pages)
            kv = gather_kv_pages(self.pools, flat)
            arrays["kv_k"] = np.asarray(kv["k"])
            arrays["kv_v"] = np.asarray(kv["v"])
        if host_pages:
            arrays["host_pages"] = host_pages
        return header, arrays

    def restore_state(self, header: Dict, arrays: Dict) -> Dict[str, int]:
        """Adopt a migrated tenant: fresh page allocation on OUR MMU,
        block-table rebuild (dirty rows upload on the next view), KV
        payload scattered to the new physical pages, decode state synced,
        PRNG stream adopted.  In-flight requests land on their original
        slot index when free (keeps the sampled noise stream aligned
        row-for-row), else the first free slot."""
        g = header["geometry"]
        mine = self.geometry()
        if g != mine:
            raise ValueError(
                f"migration geometry mismatch: snapshot {g} vs "
                f"destination {mine} — KV pages are not byte-compatible")
        reqs = header["requests"]
        free = [i for i in range(self.max_batch)
                if self.slots[i] is None]
        if len(reqs) > len(free):
            raise ValueError(
                f"destination engine has {len(free)} free slots for "
                f"{len(reqs)} in-flight migrated requests")
        mapping = self.mmu.restore_seqs(header["mmu"], slot=self.slot)
        # shared source pages restored to ONE destination page each:
        # index the new ppage by old device ppage / host slot so every
        # shipped payload (deduped at snapshot) scatters exactly once
        by_old, by_hslot, by_sv = {}, {}, {}
        for sid, pl in mapping.items():
            for p in pl:
                if p["was_host"]:
                    if p["host_slot"] >= 0:
                        by_hslot[p["host_slot"]] = p["new_ppage"]
                    by_sv[(sid, p["vpage"])] = p["new_ppage"]
                else:
                    by_old[p["old_ppage"]] = p["new_ppage"]
        n_pages = self.mmu.config.n_pages
        if header["pages"]:
            new_pps = [by_old[p["ppage"]] for p in header["pages"]]
            flat = flat_page_indices(new_pps, self.cfg.n_layers, n_pages)
            self.pools = scatter_kv_pages(
                self.pools, flat, {"k": jnp.asarray(arrays["kv_k"]),
                                   "v": jnp.asarray(arrays["kv_v"])})
        for key, data in (arrays.get("host_pages") or {}).items():
            if key.startswith("h:"):
                new_pp = by_hslot[int(key[2:])]
            else:                       # "u:<sid>:<vpage>" legacy pages
                _, sid, vpage = key.split(":")
                new_pp = by_sv[(int(sid), int(vpage))]
            flat = flat_page_indices([new_pp], self.cfg.n_layers, n_pages)
            self.pools = scatter_kv_pages(
                self.pools, flat, {"k": jnp.asarray(data["k"]),
                                   "v": jnp.asarray(data["v"])})
        slots_i, rows = [], []
        for rd in reqs:
            req = self._req_from_dict(rd)
            want = int(rd.get("slot", -1))
            i = want if (0 <= want < self.max_batch
                         and self.slots[want] is None) else free[0]
            free.remove(i)
            self.slots[i] = req
            self.block_table.bind(i, req.rid)
            assert req.out_tokens, "in-flight request without prefill"
            slots_i.append(i)
            rows.append((len(req.prompt) + len(req.out_tokens) - 1,
                         req.out_tokens[-1], req.temperature,
                         req.top_k, req.top_p))
        if slots_i:
            self._sync_slot_state(slots_i, rows)
        for rd in header["queue"]:
            self.queue.append(self._req_from_dict(rd))
        self.rng = jnp.asarray(arrays["rng"])
        adopted = ([r["rid"] for r in reqs]
                   + [r["rid"] for r in header["queue"]])
        if adopted:
            self._rid_next = max(self._rid_next, max(adopted) + 1)
        return {"requests": len(reqs), "queued": len(header["queue"]),
                "pages": len(header["pages"])
                + len(arrays.get("host_pages") or {})}

    def reset_decode_state(self) -> None:
        """Cold-reset the engine's device-side soft state — the local
        analogue of restarting the slot's logic after a crash: a fresh
        block-table view, zeroed lens/tokens/sampling params, dropped
        billed-IO futures, full TLB flush.  KV pool *contents* are not
        touched: :meth:`restore_state` scatters the preserved page
        payloads back in right after, which is what makes a recovery
        KV-intact instead of a re-prefill."""
        self.block_table = self.mmu.block_table_device(self.max_batch,
                                                       self.max_pages)
        self.dev_lens = jnp.zeros((self.max_batch,), jnp.int32)
        self.dev_tokens = jnp.zeros((self.max_batch,), jnp.int32)
        self.dev_temps = jnp.zeros((self.max_batch,), jnp.float32)
        self.dev_topk = jnp.zeros((self.max_batch,), jnp.int32)
        self.dev_topp = jnp.ones((self.max_batch,), jnp.float32)
        self._io_futs = []
        self.mmu.tlb.invalidate()

    def evacuate(self) -> Dict[str, int]:
        """Release the tenant's paged state AFTER a successful snapshot
        restore elsewhere: free every sequence on our MMU (returning the
        pages to the shared pool), unbind block-table rows, clear the
        run queue.  The engine stays usable for new work."""
        freed, n_seqs = [], 0
        for i, req in enumerate(self.slots):
            if req is not None:
                self.mmu.free_seq(req.rid)
                self.block_table.unbind(i)
                self.slots[i] = None
                freed.append(i)
                n_seqs += 1
        if freed:
            self._sync_slot_state(freed, [(0, 0, 0.0, 0, 1.0)] * len(freed))
        n_q = len(self.queue)
        self.queue.clear()
        return {"seqs": n_seqs, "queued": n_q}

    def run(self, max_steps: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        while self.pending() and self.steps < max_steps:
            self.step()
            # decode-step preemption checkpoint: when this loop is the
            # body of a long-running port invocation on a lane, yield to
            # higher-priority granted work between steps (no-op off-lane)
            if self.shell is not None:
                self.shell.scheduler.checkpoint(self.slot)
        drained = self.flush_io()
        dt = time.perf_counter() - t0
        stats = {"wall_s": dt, "engine_steps": self.steps,
                 "tokens": self.tokens_out,
                 "tokens_per_s": self.tokens_out / max(dt, 1e-9),
                 "completed": len(self.completed),
                 "prefill_computed": self.prefill_computed,
                 "prefill_skipped": self.prefill_skipped}
        if self.shell is not None and self.tenant is not None:
            stats["io_drained"] = drained
            stats["io_pending"] = self.shell.scheduler.tenant_pending(
                self.tenant)
        return stats
