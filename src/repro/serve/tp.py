"""Tensor-parallel paged serving: shard_map twins of the decode hot path.

One serving tenant spans every device on a mesh's ``model`` axis while the
shell stays logically single — the Coyote v2 move of making placement a
property of the shell, not the app.  The engine keeps ONE MMU, ONE block
table, ONE refcounted prefix index and ONE pager; only the *tensors* are
partitioned:

  * **Weights** are Megatron-style tensor-parallel (``MeshRules.serving()``
    — TP columns, no FSDP rows, so decode never all-gathers weights):
    ``wq/wk/wv`` column-sharded on the flattened head dim, ``wo``
    row-sharded; SwiGLU ``w_gate/w_up`` column-sharded on ``d_ff``,
    ``w_down`` row-sharded.  Embeddings, norms, lm_head and MoE experts
    stay replicated.
  * **KV pools** shard axis 2 (``kv_heads``) on ``model``: each device
    holds EVERY page but only its head slice, so paged attention is
    collective-free (per-head softmax is device-local) and the page-id
    geometry — block tables, pager, migration wire format — is untouched.
  * **Reductions** go through :meth:`CollectiveService.all_reduce`
    (``axes=("model",)``): one psum after the attention out-projection and
    one after the FFN per layer.  Everything between blocks is replicated.
  * **Sampling** runs on replicated logits with a replicated PRNG key, so
    every device samples the same (B,) token vector and only that vector
    crosses to the host — the PR-2 device-resident carry invariant holds
    per shard.

Degradation is static and per-part: heads shard only when BOTH
``n_heads`` and ``n_kv_heads`` divide the TP degree (GQA grouping must
survive the split), the FFN only for non-MoE SwiGLU with divisible
``d_ff``.  A part that cannot shard is replicated and its psum is
skipped — never applied to an already-complete sum.

Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
(tests/test_mesh_serving.py, benchmarks/bench_multipod.py); the full guide
is docs/sharding.md.
"""
from __future__ import annotations

import functools
from dataclasses import replace
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ModelConfig
from repro.core.services.collectives import CollectiveService
from repro.models.sharding import MeshRules
from repro.serve import paged_model


def tp_plan(cfg: ModelConfig, tp_size: int) -> Dict[str, bool]:
    """Static sharding decisions for a config at a TP degree.

    ``shard_heads``: attention weights + KV pools split on the head dim —
    requires whole query AND kv heads per shard (GQA groups must not
    straddle devices).  ``shard_mlp``: SwiGLU hidden dim split — MoE FFNs
    and GELU MLPs (whisper's ``b_down`` bias is applied inside the matmul
    epilogue, pre-reduction) stay replicated.
    """
    shard_heads = (tp_size > 1
                   and cfg.n_heads % tp_size == 0
                   and cfg.n_kv_heads % tp_size == 0)
    shard_mlp = (tp_size > 1 and cfg.moe is None and cfg.act == "silu"
                 and cfg.d_ff % tp_size == 0)
    return {"shard_heads": shard_heads, "shard_mlp": shard_mlp}


class TPContext:
    """Mesh-bound tensor-parallel twins of the paged serving kernels.

    Construct once per (engine, mesh); exposes placed parameters
    (``.params``), pool/state shardings, and jitted ``decode_step`` /
    ``prefill_shared`` / ``prefill_chunk`` callables with the same
    positional signatures as their single-device counterparts in
    :mod:`repro.serve.paged_model` (statics pre-bound).
    """

    def __init__(self, cfg: ModelConfig, mesh: Mesh, params, *,
                 page_size: int, use_pallas: bool = False,
                 pages_per_block: Optional[int] = None,
                 collectives: Optional[CollectiveService] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = MeshRules.from_mesh(mesh).serving()
        self.axis = self.rules.tp_axis
        self.tp_size = self.rules.tp_size or 1
        self.collectives = (collectives if collectives is not None
                            else CollectiveService())
        plan = tp_plan(cfg, self.tp_size)
        self.shard_heads = plan["shard_heads"]
        self.shard_mlp = plan["shard_mlp"]
        # Per-device view of the model: the shard_map body sees LOCAL
        # head counts.  head_dim is pinned explicitly because
        # resolved_head_dim would otherwise re-derive from the reduced
        # n_heads (d_model // local_heads is wrong by a factor of tp).
        if self.shard_heads:
            self.local_cfg = replace(
                cfg, n_heads=cfg.n_heads // self.tp_size,
                n_kv_heads=cfg.n_kv_heads // self.tp_size,
                head_dim=cfg.resolved_head_dim)
        else:
            self.local_cfg = cfg
        self.replicated = NamedSharding(mesh, P())
        self.kv_spec = (P(None, None, self.axis, None) if self.shard_heads
                        else P())
        self.kv_sharding = NamedSharding(mesh, self.kv_spec)
        self._pspecs = self._param_specs(params)
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self._pspecs,
                                 is_leaf=lambda x: isinstance(x, P)))
        self._psum_attn = self._reduce if self.shard_heads else None
        self._psum_mlp = self._reduce if self.shard_mlp else None
        self.decode_step = self._build_decode(page_size, use_pallas,
                                              pages_per_block)
        self.prefill_shared = self._build_prefill_shared(page_size)
        self.prefill_chunk = self._build_prefill_chunk(page_size)

    # ------------------------------------------------------------ specs ----
    def _param_specs(self, params):
        """PartitionSpec pytree congruent with the serving param tree:
        replicated everywhere except the TP-sharded attention/FFN mats
        (stacked layer axis — index 0 — is never sharded)."""
        specs = jax.tree.map(lambda _: P(), params)
        ax = self.axis
        if self.shard_heads:
            a = specs["layers"]["attn"]
            a["wq"] = P(None, None, ax)
            a["wk"] = P(None, None, ax)
            a["wv"] = P(None, None, ax)
            a["wo"] = P(None, ax, None)
            for b in ("bq", "bk", "bv"):
                if b in a:
                    a[b] = P(None, ax)
        if self.shard_mlp:
            f = specs["layers"]["ffn"]
            f["w_gate"] = P(None, None, ax)
            f["w_up"] = P(None, None, ax)
            f["w_down"] = P(None, ax, None)
        return specs

    def _reduce(self, x):
        """Sum TP partials through the collective service port."""
        return self.collectives.all_reduce(x, self.mesh, axes=(self.axis,))

    # ----------------------------------------------------------- builders ----
    def _build_decode(self, page_size, use_pallas, pages_per_block):
        impl = functools.partial(
            paged_model._decode_step_impl, cfg=self.local_cfg,
            page_size=page_size, use_pallas=use_pallas,
            pages_per_block=pages_per_block,
            psum_attn=self._psum_attn, psum_mlp=self._psum_mlp)

        def local(params, pools, tables, lens, last, rng, temps, tk, tp_,
                  sids):
            paged_model._count_trace("decode_step_paged_tp")
            return impl(params, pools, tables, lens, last, rng, temps, tk,
                        tp_, sids)

        sm = _shard_map(
            local, mesh=self.mesh,
            in_specs=(self._pspecs, {"k": self.kv_spec, "v": self.kv_spec},
                      P(), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(), {"k": self.kv_spec, "v": self.kv_spec},
                       P(), P()),
            check_rep=False)
        return jax.jit(sm, donate_argnums=(1, 3, 4, 5))

    def _build_prefill_shared(self, page_size):
        impl = functools.partial(
            paged_model._prefill_shared_impl, cfg=self.local_cfg,
            page_size=page_size, psum_attn=self._psum_attn,
            psum_mlp=self._psum_mlp)

        def local(params, pools, tokens, q_lens, q_starts, write_from,
                  tables, rng, temps, tk, tp_, sids):
            paged_model._count_trace("prefill_shared_paged_tp")
            return impl(params, pools, tokens, q_lens, q_starts,
                        write_from, tables, rng, temps, tk, tp_, sids)

        sm = _shard_map(
            local, mesh=self.mesh,
            in_specs=(self._pspecs, {"k": self.kv_spec, "v": self.kv_spec},
                      P(), P(), P(), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(), {"k": self.kv_spec, "v": self.kv_spec}, P()),
            check_rep=False)
        return jax.jit(sm, donate_argnums=(1, 7))

    def _build_prefill_chunk(self, page_size):
        impl = functools.partial(
            paged_model._prefill_chunk_impl, cfg=self.local_cfg,
            page_size=page_size, psum_attn=self._psum_attn,
            psum_mlp=self._psum_mlp)

        def local(params, pools, tokens, q_lens, q_starts, tables):
            paged_model._count_trace("prefill_chunk_paged_tp")
            return impl(params, pools, tokens, q_lens, q_starts, tables)

        sm = _shard_map(
            local, mesh=self.mesh,
            in_specs=(self._pspecs, {"k": self.kv_spec, "v": self.kv_spec},
                      P(), P(), P(), P()),
            out_specs={"k": self.kv_spec, "v": self.kv_spec},
            check_rep=False)
        return jax.jit(sm, donate_argnums=(1,))

    # ------------------------------------------------------------- extras ----
    def prefill_paged(self, params, pools, tokens, lens, tables, rng,
                      temperatures, top_k=None, top_p=None):
        """TP twin of :func:`repro.serve.paged_model.prefill_paged`,
        routed through the shared-prefix kernel with zero coverage
        (q_starts = write_from = 0): full causal prefill over the paged
        KV with one batch-wide PRNG split, like the single-device
        original."""
        import jax.numpy as jnp
        n = tokens.shape[0]
        zeros = jnp.zeros((n,), jnp.int32)
        ones = (jnp.ones((n,), jnp.float32) if top_p is None else top_p)
        tk = jnp.zeros((n,), jnp.int32) if top_k is None else top_k
        return self.prefill_shared(params, pools, tokens, lens, zeros,
                                   zeros, tables, rng, temperatures, tk,
                                   ones, None)

    def allreduce_bytes_per_step(self, batch: int) -> int:
        """Modeled GLOBAL payload bytes all-reduced per decode step:
        one fp32 (B, 1, d_model) activation per enabled psum site per
        layer.  Feed to :meth:`CollectiveService.wire_bytes` for the
        per-device wire estimate (benchmarks/bench_multipod.py)."""
        sites = int(self.shard_heads) + int(self.shard_mlp)
        return sites * self.cfg.n_layers * batch * self.cfg.d_model * 4
