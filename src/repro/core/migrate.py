"""Quiesce-and-migrate: live tenant relocation across shells.

Coyote v2's reconfiguration story is that services and user logic move
while the system keeps serving.  ``Shell.reconfigure`` already hot-swaps
ONE slot in place (drain -> snapshot -> load -> restore -> replay); this
module completes the story by moving a *paged serving tenant* between two
shells — the checkpoint-based relocation primitive of SYNERGY/RC3E built
on the same Port drain machinery:

  1. **Quiesce** — the source slot's port stops intake (new submissions
     are *held*, never rejected), the in-flight tail completes, and the
     tenant's billed link traffic drains (``scheduler.drain_tenant`` —
     tenant-aware: bystander tenants keep flowing untouched).
  2. **Snapshot** — a versioned, pickle-free state container in the safe
     bitstream format (``kind="migration"``): CSR file + cThread address
     map, the MMU page-table snapshot, in-flight/queued requests, the
     PRNG stream, and *the actual KV pool pages* — a device-side compact
     gather of the tenant's live pages into a transfer buffer
     (``repro.serve.paged_model.gather_kv_pages``), plus any payloads the
     evict-with-copy pager already holds on the host.
  3. **Restore** — fresh page allocation on the destination MMU
     (``MMU.restore_seqs``), KV payload scattered to the new physical
     pages, ``DeviceBlockTable`` rows rebuilt (dirty-row upload on the
     next device view), decode state and PRNG adopted, CSR/addr-map
     applied to the destination slot.
  4. **Replay** — invocations held at the source during the move are
     re-ticketed and dispatched on the DESTINATION port, resolving their
     original futures: zero lost, zero duplicated completions across the
     migration boundary.

Every ``migrate()`` round-trips the snapshot through the container
encode/decode, so what lands on the destination is exactly what a
wire/disk copy would carry — and the version check runs on every move.

:func:`migrate_precopy` is the low-downtime variant: **warm rounds**
ship KV pages through the chunked container stream while the source
keeps decoding (the MMU's dirty tracking tells each round which pages
changed since the last one — see ``MMU.dirty_snapshot``), landing them
in pages *reserved* on the destination (``MMU.reserve_pages``).  Only
the **freeze** pauses intake, and it snapshots just the final dirty
delta plus CSR/queue/PRNG state (``snapshot_tenant(only_pages=...)``) —
the destination adopts the staged pages during ``restore_seqs``, so the
service gap is O(dirty delta) instead of O(KV footprint).  A failure in
any warm round releases the staged pages and leaves the source serving,
untouched; freeze-phase failures contain exactly like ``migrate()``.

    from repro.core.migrate import migrate
    report = migrate(src_shell, dst_shell, "gold")      # tenant or slot
    print(report.downtime_s, report.payload_bytes)

Demo: ``PYTHONPATH=src python examples/migrate_shell.py``; bench:
``PYTHONPATH=src python -m benchmarks.run --only live_migrate``.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core import bitstream as B
from repro.core.bitstream import BitstreamError
from repro.core.faults import FaultKind, maybe_fire
from repro.core.services.mmu import _share_key

# Bumped whenever the migration header/array layout changes; a snapshot
# from a different version is refused (BitstreamError), never guessed at.
# v2: shared-page dedup — ``header["pages"]`` lists each physical page
# once (``{"ppage"}`` entries, no per-seq duplicates), host payloads key
# by host slot (``"h:<slot>"``), and the MMU snapshot carries per-page
# host_slot + prefix-index chain hashes so restore rebuilds sharing.
MIGRATION_STATE_VERSION = 2


class MigrationError(RuntimeError):
    """Migration pipeline failure (the source is left serving)."""


@dataclass
class MigrationReport:
    """What one ``migrate()`` did and what it cost.

    ``downtime_s`` is the tenant-observed service gap: first intake hold
    at the source to held-invocation replay completing on the
    destination.  Bystander tenants see none of it."""
    tenant: Optional[str]
    src_slot: int
    dst_slot: int
    n_requests: int          # in-flight requests moved
    n_queued: int            # queued requests moved
    n_pages: int             # KV pages copied (device + host-preserved)
    payload_bytes: int       # encoded snapshot container size
    replayed: int            # held invocations replayed on the dst port
    quiesce_s: float
    snapshot_s: float
    restore_s: float
    replay_s: float
    downtime_s: float
    # pre-copy extras (zero for plain stop-and-copy migrate())
    precopy_rounds: int = 0      # warm rounds shipped before the freeze
    precopy_pages: int = 0       # page payloads shipped warm (re-ships count)
    precopy_bytes: int = 0       # warm-round container bytes on the wire
    delta_pages: int = 0         # pages in the frozen final delta

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


# ------------------------------------------------------ state container ----
def encode_snapshot(header: Dict[str, Any], arrays: Any) -> bytes:
    """Pack a tenant snapshot into the safe versioned bitstream container
    (``CYBS`` magic, ``kind="migration"``, npz payload, no pickle)."""
    hdr = {"state_version": MIGRATION_STATE_VERSION, **header}
    return B.encode("migration", hdr, arrays=arrays)


def decode_snapshot(blob: bytes) -> Tuple[Dict[str, Any], Any]:
    """Unpack + validate a migration snapshot.  Bad magic, unknown kind,
    container-version or state-version mismatch all raise
    :class:`BitstreamError` — a snapshot is never half-applied."""
    _, header, arrays = B.decode(blob, expect_kind="migration")
    ver = header.get("state_version")
    if ver != MIGRATION_STATE_VERSION:
        raise BitstreamError(
            f"migration state version {ver!r} does not match this "
            f"runtime ({MIGRATION_STATE_VERSION}); refusing to restore")
    return header, arrays or {}


def encode_snapshot_stream(header: Dict[str, Any], arrays: Any):
    """Chunked form of :func:`encode_snapshot` — yields bounded chunks,
    the payload is never duplicated in host memory."""
    hdr = {"state_version": MIGRATION_STATE_VERSION, **header}
    return B.encode_stream("migration", hdr, arrays=arrays)


def decode_snapshot_stream(chunks) -> Tuple[Dict[str, Any], Any]:
    """Chunked form of :func:`decode_snapshot` (integrity-verified
    incrementally as chunks arrive)."""
    _, header, arrays = B.decode_stream(chunks, expect_kind="migration")
    ver = header.get("state_version")
    if ver != MIGRATION_STATE_VERSION:
        raise BitstreamError(
            f"migration state version {ver!r} does not match this "
            f"runtime ({MIGRATION_STATE_VERSION}); refusing to restore")
    return header, arrays or {}


def save_snapshot(path: str, header: Dict[str, Any], arrays: Any) -> int:
    blob = encode_snapshot(header, arrays)
    Path(path).write_bytes(blob)
    return len(blob)


def load_snapshot(path: str) -> Tuple[Dict[str, Any], Any]:
    return decode_snapshot(Path(path).read_bytes())


# ------------------------------------------------------- snapshot side -----
def snapshot_tenant(shell, slot: int, *,
                    only_pages=None) -> Tuple[Dict[str, Any], Any]:
    """Snapshot the (already quiesced) serving tenant on ``slot``:
    engine paged state + slot port state (CSR file, cThread address map).
    Returns the ``(header, arrays)`` pair :func:`encode_snapshot` packs.
    ``only_pages`` restricts KV payloads to a share-key subset (the
    pre-copy freeze passes the final dirty delta)."""
    engine = shell.engines.get(slot)
    if engine is None:
        raise MigrationError(
            f"no serving engine bound to slot {slot} on this shell "
            "(migratable tenants are paged ServingEngines created with "
            "shell=...)")
    header, arrays = engine.snapshot_state(only_pages=only_pages)
    port = shell.attach(slot)
    psnap = port.snapshot()
    header["tenant"] = shell.vfpgas[slot].tenant
    header["port"] = {
        "csr": {str(reg): int(val)
                for reg, val in psnap.get("csr", {}).items()},
        "next_vaddr": int(psnap.get("next_vaddr", 0)),
        "app": psnap.get("app"),
    }
    addr_map = psnap.get("addr_map") or {}
    if addr_map:
        arrays["addr_map"] = {str(v): np.asarray(buf)
                              for v, buf in addr_map.items()}
    return header, arrays


def _restore_port_state(shell, slot: int, header: Dict[str, Any],
                        arrays: Any) -> None:
    """Apply the snapshotted CSR file and cThread address map to the
    destination slot (getMem buffers outlive the logic they feed)."""
    vf = shell.vfpgas[slot]
    pstate = header.get("port", {})
    for reg, val in pstate.get("csr", {}).items():
        vf.iface.csr.set_csr(int(val), int(reg))
    for vaddr, buf in (arrays.get("addr_map") or {}).items():
        vf._addr_map[int(vaddr)] = np.asarray(buf)
    nv = int(pstate.get("next_vaddr", 0))
    vf._next_vaddr = max(vf._next_vaddr, nv)


def _record_migration_fault(shell, exc: BaseException, *, slot: int,
                            tenant: Optional[str], stage: str) -> None:
    """Account a failed migration stage in the source shell's health
    ledger (the source keeps serving; the fault is informational)."""
    health = getattr(shell, "health", None)
    if health is not None:
        health.record_fault(
            getattr(exc, "kind", FaultKind.MIGRATION_FAIL), slot=slot,
            tenant=tenant, site=f"migrate.{stage}", strike=False,
            msg=str(exc))


# ------------------------------------------------------------ pipeline -----
def _resolve_slot(shell, target: Union[int, str]) -> int:
    if isinstance(target, int):
        return target
    for slot, eng in shell.engines.items():
        if eng.tenant == target:
            return slot
    for vf in shell.vfpgas:
        if vf.tenant == target and vf.slot in shell.engines:
            return vf.slot
    tenants = sorted({e.tenant for e in shell.engines.values()
                      if e.tenant is not None})
    raise MigrationError(
        f"no migratable tenant {target!r} on this shell "
        f"(tenants: {tenants})")


def _resolve_pair(src_shell, dst_shell, target: Union[int, str],
                  dst_slot: Optional[int]):
    """Resolve and validate a (source engine, destination engine) pair
    for a move: both slots must host engines with matching geometry."""
    slot = _resolve_slot(src_shell, target)
    engine = src_shell.engines.get(slot)
    if engine is None:
        raise MigrationError(
            f"no serving engine bound to source slot {slot}")
    dslot = slot if dst_slot is None else dst_slot
    dst_engine = dst_shell.engines.get(dslot)
    if dst_engine is None:
        raise MigrationError(
            f"no serving engine bound to destination slot {dslot} — "
            "load the app and create its engine before migrating onto it")
    if dst_engine.geometry() != engine.geometry():
        raise MigrationError(
            f"geometry mismatch: source {engine.geometry()} vs "
            f"destination {dst_engine.geometry()}")
    tenant = engine.tenant or src_shell.vfpgas[slot].tenant
    return slot, engine, dslot, dst_engine, tenant


def migrate(src_shell, dst_shell, target: Union[int, str], *,
            dst_slot: Optional[int] = None,
            drain_timeout: float = 30.0) -> MigrationReport:
    """Move a live paged serving tenant from ``src_shell`` to
    ``dst_shell`` with zero lost and zero duplicated completions.

    ``target`` is a vFPGA slot index or a tenant name on the source
    shell; ``dst_slot`` defaults to the same index.  The destination
    slot must already host a :class:`~repro.serve.engine.ServingEngine`
    with matching geometry (same model shape, page size, KV layout) and
    identical weights — migration moves *state*, the logic is loaded by
    the normal app-bitstream path.  On any failure the source port
    resumes and the tenant keeps serving where it was.

    Call between engine steps (a decode step is the atomic unit, exactly
    like the executor lanes' checkpoint boundaries): the port quiesce
    holds *port* traffic, and the snapshot assumes no ``step()`` is
    concurrently mutating the donated pools.
    """
    slot, engine, dslot, dst_engine, tenant = _resolve_pair(
        src_shell, dst_shell, target, dst_slot)
    src_port = src_shell.attach(slot)

    t0 = time.perf_counter()
    # -- 1. quiesce ---------------------------------------------------------
    # every drain result is checked: a snapshot taken while tenant work
    # is still in flight would be torn (CSR/addr-map mutating under it)
    if not src_port.quiesce(timeout=drain_timeout):
        src_port.resume()
        raise MigrationError(
            f"slot {slot} failed to quiesce within {drain_timeout}s "
            f"({src_port.inflight()} invocations in flight); migration "
            "aborted, intake resumed")
    if tenant is not None and not src_shell.scheduler.drain_tenant(
            tenant, timeout=drain_timeout):
        src_port.resume()
        raise MigrationError(
            f"tenant {tenant!r} still has link traffic in flight after "
            f"{drain_timeout}s; migration aborted, intake resumed")
    if not engine.flush_io(timeout=drain_timeout):
        src_port.resume()
        raise MigrationError(
            f"engine decode-IO futures did not drain within "
            f"{drain_timeout}s; migration aborted, intake resumed")
    t_q = time.perf_counter()

    # -- 2. snapshot (device KV gather + container round-trip) --------------
    try:
        maybe_fire(getattr(src_shell, "faults", None), "migrate.snapshot",
                   slot=slot, tenant=tenant)
        header, arrays = snapshot_tenant(src_shell, slot)
        blob = encode_snapshot(header, arrays)
    except BaseException as e:
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="snapshot")
        src_port.resume()
        raise
    t_s = time.perf_counter()

    # -- 3. restore on the destination --------------------------------------
    # the destination slot's QoS binding moves only now, after the source
    # snapshot is in hand — an aborted quiesce never touches the dst
    prev_tenant = dst_shell.vfpgas[dslot].tenant
    dst_port = dst_shell.attach(dslot, tenant=tenant)
    try:
        maybe_fire(getattr(src_shell, "faults", None), "migrate.restore",
                   slot=slot, tenant=tenant)
        rheader, rarrays = decode_snapshot(blob)
        stats = dst_engine.restore_state(rheader, rarrays)
        _restore_port_state(dst_shell, dslot, rheader, rarrays)
    except Exception as e:  # noqa: BLE001 — ANY restore failure (bad
        # container, geometry/capacity refusal, id collision) must leave
        # the source serving; nothing was freed there yet
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="restore")
        if prev_tenant is not None and prev_tenant != tenant:
            dst_shell.attach(dslot, tenant=prev_tenant)   # rebind back
        src_port.resume()
        raise MigrationError(f"restore failed on destination: {e}") from e
    t_r = time.perf_counter()

    # -- 4. evacuate the source, replay held work on the destination --------
    replayed = _evacuate_and_replay(src_shell, engine, src_port, dst_port,
                                    slot=slot, tenant=tenant)
    t_done = time.perf_counter()

    return MigrationReport(
        tenant=tenant, src_slot=slot, dst_slot=dslot,
        n_requests=stats["requests"], n_queued=stats["queued"],
        n_pages=stats["pages"], payload_bytes=len(blob),
        replayed=replayed,
        quiesce_s=t_q - t0, snapshot_s=t_s - t_q,
        restore_s=t_r - t_s, replay_s=t_done - t_r,
        downtime_s=t_done - t0)


def _evacuate_and_replay(src_shell, engine, src_port, dst_port, *,
                         slot: int, tenant: Optional[str]) -> int:
    """Final migration stage, shared by stop-and-copy and pre-copy:
    evacuate the source engine and replay held invocations on the
    destination port — exactly once each, whatever fails."""
    engine.evacuate()
    pending = list(src_port.take_held())
    replayed = 0
    try:
        maybe_fire(getattr(src_shell, "faults", None), "migrate.replay",
                   slot=slot, tenant=tenant)
        # one at a time, so a mid-list failure knows EXACTLY which
        # invocations the destination consumed (dispatched or joined its
        # held FIFO) and which it never touched
        while pending:
            replayed += dst_port.replay_adopted(pending[:1])
            pending.pop(0)
    except Exception as e:  # noqa: BLE001 — e.g. the destination port
        # was closed by a racing cold_restart.  The tenant's state HAS
        # moved, but no held future may be dropped OR duplicated: only
        # the invocations the destination never touched re-hold at the
        # source (re-ticketed) and replay there on resume — exactly
        # once either way, nothing wedged QUIESCED.
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="replay")
        src_port.restore_held(pending)
        src_port.resume()
        raise MigrationError(
            f"replay on destination port failed after restore: {e}; "
            f"{len(pending)} untouched invocation(s) replayed at the "
            "source, which no longer holds the tenant's paged state"
        ) from e
    src_port.resume()                     # slot reusable, nothing held
    return replayed


# ----------------------------------------------------- pre-copy pipeline ----
def _key_str(key: Tuple) -> str:
    """JSON-safe spelling of an MMU share key: ("d", 3) -> "d:3"."""
    return ":".join(str(x) for x in key)


def _gather_page_payloads(engine, keys) -> Dict[Tuple, Dict[str, Any]]:
    """Gather KV payloads for a set of MMU share keys: one batched
    device gather for the ("d", ppage) keys (same compact-gather kernel
    the full snapshot uses) plus the preserved host payloads for
    ("h", hslot) keys.  Keys with no materialized bytes ("u" legacy
    pages, host slots evicted without a pager) are skipped — exactly
    what a full snapshot would skip."""
    from repro.serve.paged_model import flat_page_indices, gather_kv_pages
    mmu = engine.mmu
    out: Dict[Tuple, Dict[str, Any]] = {}
    dpages = sorted(k[1] for k in keys if k[0] == "d")
    if dpages:
        flat = flat_page_indices(dpages, engine.cfg.n_layers,
                                 mmu.config.n_pages)
        kv = gather_kv_pages(engine.pools, flat)
        L = engine.cfg.n_layers
        kk = np.asarray(kv["k"]).reshape(L, len(dpages),
                                         *np.asarray(kv["k"]).shape[1:])
        vv = np.asarray(kv["v"]).reshape(L, len(dpages),
                                         *np.asarray(kv["v"]).shape[1:])
        for i, pp in enumerate(dpages):
            out[("d", pp)] = {"k": kk[:, i], "v": vv[:, i]}
    for k in keys:
        if k[0] == "h":
            data = mmu.host_payload(k[1])
            if data is not None:
                out[k] = {"k": np.asarray(data["k"]),
                          "v": np.asarray(data["v"])}
    return out


def migrate_precopy(src_shell, dst_shell, target: Union[int, str], *,
                    dst_slot: Optional[int] = None,
                    drain_timeout: float = 30.0,
                    max_rounds: int = 6, dirty_floor: int = 1,
                    decode_between_rounds: int = 1) -> MigrationReport:
    """Pre-copy live migration: O(dirty delta) downtime.

    Warm rounds run with the source port fully open: each round ships
    the pages that are new or were dirtied since the previous round
    (``MMU.dirty_snapshot``) through the chunked container stream into
    pages *reserved* on the destination MMU, then lets the source decode
    ``decode_between_rounds`` steps.  Rounds stop when the dirty set
    converges to ``dirty_floor`` pages (or ``max_rounds`` hits — a write
    rate above the copy rate can never converge; the freeze bounds it).
    The freeze then quiesces exactly like :func:`migrate` but snapshots
    only the final dirty delta; ``restore_state(staged=...)`` makes the
    destination adopt the pre-staged pages, the delta overwrites the few
    that changed, and held invocations replay.  Downtime covers the
    freeze only.

    Failure containment: a warm-round failure (including an injected
    ``"migrate.precopy"`` fault) releases every staged page and raises —
    the source was never paused.  Freeze-phase failures release the
    staging (unless the destination already adopted it) and resume the
    source, exactly like stop-and-copy.
    """
    slot, engine, dslot, dst_engine, tenant = _resolve_pair(
        src_shell, dst_shell, target, dst_slot)
    mmu, dst_mmu = engine.mmu, dst_engine.mmu
    faults = getattr(src_shell, "faults", None)
    src_port = src_shell.attach(slot)

    # -- warm rounds: source keeps serving ----------------------------------
    staged: Dict[Tuple, int] = {}
    rounds = precopy_pages = precopy_bytes = 0
    try:
        while rounds < max_rounds:
            # PEEK the dirty set first: if we break here, unshipped
            # dirty flags must survive into the freeze's final delta
            dirty = mmu.dirty_snapshot()
            live = mmu.live_page_keys()
            to_ship = (live - staged.keys()) | (dirty & live)
            if not to_ship or (rounds > 0
                               and len(to_ship) <= dirty_floor):
                break
            maybe_fire(faults, "migrate.precopy", slot=slot,
                       tenant=tenant)
            mmu.clear_dirty()
            payloads = _gather_page_payloads(engine, to_ship)
            chunks = list(B.encode_stream(
                "migration",
                {"state_version": MIGRATION_STATE_VERSION,
                 "precopy_round": rounds},
                arrays={"pages": {_key_str(k): v
                                  for k, v in payloads.items()}}))
            precopy_bytes += sum(len(c) for c in chunks)
            _, _, rarr = B.decode_stream(chunks,
                                         expect_kind="migration")
            new_keys = sorted(k for k in payloads if k not in staged)
            if new_keys:
                staged.update(zip(new_keys,
                                  dst_mmu.reserve_pages(len(new_keys))))
            for k in sorted(payloads):
                dst_engine._pager_scatter(staged[k],
                                          rarr["pages"][_key_str(k)])
            precopy_pages += len(payloads)
            rounds += 1
            for _ in range(decode_between_rounds):
                engine.step()             # the source keeps decoding
    except BaseException as e:
        if staged:
            dst_mmu.release_pages(list(staged.values()))
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="precopy")
        raise MigrationError(
            f"pre-copy warm phase failed: {e}; the source was never "
            "paused and keeps serving") from e

    def _abort_freeze(msg: str) -> MigrationError:
        if staged:
            dst_mmu.release_pages(list(staged.values()))
        src_port.resume()
        return MigrationError(msg)

    t0 = time.perf_counter()
    # -- freeze: quiesce (same checks as migrate()) -------------------------
    if not src_port.quiesce(timeout=drain_timeout):
        raise _abort_freeze(
            f"slot {slot} failed to quiesce within {drain_timeout}s "
            f"({src_port.inflight()} invocations in flight); migration "
            "aborted, intake resumed")
    if tenant is not None and not src_shell.scheduler.drain_tenant(
            tenant, timeout=drain_timeout):
        raise _abort_freeze(
            f"tenant {tenant!r} still has link traffic in flight after "
            f"{drain_timeout}s; migration aborted, intake resumed")
    if not engine.flush_io(timeout=drain_timeout):
        raise _abort_freeze(
            f"engine decode-IO futures did not drain within "
            f"{drain_timeout}s; migration aborted, intake resumed")
    t_q = time.perf_counter()

    # -- final delta snapshot: O(pages dirtied since the last round) --------
    try:
        maybe_fire(faults, "migrate.snapshot", slot=slot, tenant=tenant)
        final_dirty = mmu.dirty_snapshot()
        live = mmu.live_page_keys()
        delta = (live - staged.keys()) | (final_dirty & live)
        header, arrays = snapshot_tenant(src_shell, slot,
                                         only_pages=delta)
        chunks = list(encode_snapshot_stream(header, arrays))
        payload_bytes = sum(len(c) for c in chunks)
    except BaseException as e:
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="snapshot")
        if staged:
            dst_mmu.release_pages(list(staged.values()))
        src_port.resume()
        raise
    t_s = time.perf_counter()

    # -- restore: adopt staged pages, overwrite the delta -------------------
    snap_sids = [int(sd["seq_id"]) for sd in header["mmu"]["seqs"]]
    prev_tenant = dst_shell.vfpgas[dslot].tenant
    dst_port = dst_shell.attach(dslot, tenant=tenant)
    try:
        maybe_fire(faults, "migrate.restore", slot=slot, tenant=tenant)
        rheader, rarrays = decode_snapshot_stream(chunks)
        stats = dst_engine.restore_state(rheader, rarrays,
                                         staged=dict(staged))
        _restore_port_state(dst_shell, dslot, rheader, rarrays)
    except Exception as e:  # noqa: BLE001 — same containment as
        # migrate(); additionally the staging is released UNLESS the
        # destination MMU already adopted it into live sequences (then
        # the pages belong to those mappings, not the reservation)
        _record_migration_fault(src_shell, e, slot=slot, tenant=tenant,
                                stage="restore")
        if staged and not dst_mmu.live_page_keys(snap_sids):
            dst_mmu.release_pages(list(staged.values()))
        if prev_tenant is not None and prev_tenant != tenant:
            dst_shell.attach(dslot, tenant=prev_tenant)   # rebind back
        src_port.resume()
        raise MigrationError(f"restore failed on destination: {e}") from e
    # staged pages the final snapshot no longer references (their page
    # was freed or evicted at the source between warm round and freeze)
    # go back to the free pool — adopted ones are owned by sequences now
    used = set()
    for sd in rheader["mmu"]["seqs"]:
        for p in sd["pages"]:
            used.add(_share_key(int(sd["seq_id"]), p))
    stale = [pp for k, pp in staged.items() if k not in used]
    if stale:
        dst_mmu.release_pages(stale)
    t_r = time.perf_counter()

    # -- evacuate + replay (shared with migrate()) --------------------------
    replayed = _evacuate_and_replay(src_shell, engine, src_port, dst_port,
                                    slot=slot, tenant=tenant)
    t_done = time.perf_counter()

    return MigrationReport(
        tenant=tenant, src_slot=slot, dst_slot=dslot,
        n_requests=stats["requests"], n_queued=stats["queued"],
        n_pages=len(used), payload_bytes=payload_bytes,
        replayed=replayed,
        quiesce_s=t_q - t0, snapshot_s=t_s - t_q,
        restore_s=t_r - t_s, replay_s=t_done - t_r,
        downtime_s=t_done - t0,
        precopy_rounds=rounds, precopy_pages=precopy_pages,
        precopy_bytes=precopy_bytes, delta_pages=len(delta))


# --------------------------------------------------- local slot recovery ----
@dataclass
class RecoveryReport:
    """What one :func:`recover_tenant_local` did and what it cost.
    ``downtime_s`` is intake-hold to held-invocation replay completing —
    the recovered tenant's observed service gap."""
    slot: int
    tenant: Optional[str]
    n_requests: int          # in-flight requests restored
    n_queued: int            # queued requests restored
    n_pages: int             # KV pages preserved across the restart
    payload_bytes: int       # encoded snapshot container size
    failed_inflight: int     # wedged in-flight invocations force-failed
    replayed: int            # held invocations replayed after recovery
    quiesce_s: float
    snapshot_s: float
    restart_s: float
    restore_s: float
    downtime_s: float

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def recover_tenant_local(shell, slot: int, *,
                         drain_timeout: float = 5.0) -> RecoveryReport:
    """Self-healing restart of ONE slot on ONE shell — the watchdog's
    recovery verb (``Shell.recover_slot`` wraps it).

    The local reuse of the migration container: quiesce the slot's port
    (a wedged in-flight tail that cannot complete is force-failed with
    typed errors, held submissions are kept), snapshot the tenant's
    paged state through the same versioned ``CYBS`` container a
    cross-shell move uses, cold-reset the engine's device soft state
    (fresh block-table view, zeroed decode vectors, TLB flush — the
    "restart"), then restore from the container: fresh page allocation,
    KV payloads (device gather + refcounted host payloads) scattered
    back, decode state and PRNG re-adopted.  Held invocations replay on
    resume.  Decoding then continues token-for-token where it left off —
    the KV pages survived the restart.
    """
    engine = shell.engines.get(slot)
    if engine is None:
        raise MigrationError(
            f"no serving engine bound to slot {slot}; recover_tenant_local "
            "only heals paged serving tenants (ServingEngine, shell=...)")
    tenant = engine.tenant or shell.vfpgas[slot].tenant
    port = shell.attach(slot)

    t0 = time.perf_counter()
    # -- 1. quiesce; a wedged tail may never complete: force-fail it -------
    failed = 0
    if not port.quiesce(timeout=drain_timeout, resume_on_timeout=False):
        failed = port.fail_inflight()
        if not port.quiesce(timeout=drain_timeout,
                            resume_on_timeout=False):
            port.resume()
            raise MigrationError(
                f"slot {slot} would not quiesce even after force-failing "
                f"{failed} in-flight invocation(s); recovery aborted, "
                "intake resumed")
    if tenant is not None:
        shell.scheduler.drain_tenant(tenant, timeout=drain_timeout)
    engine.flush_io(timeout=drain_timeout)
    t_q = time.perf_counter()

    # -- 2. snapshot through the migration container ------------------------
    try:
        header, arrays = snapshot_tenant(shell, slot)
        blob = encode_snapshot(header, arrays)
    except BaseException as e:
        _record_migration_fault(shell, e, slot=slot, tenant=tenant,
                                stage="snapshot")
        port.resume()
        raise
    t_s = time.perf_counter()

    # -- 3. the "restart": evacuate + cold-reset device soft state ----------
    engine.evacuate()
    engine.reset_decode_state()
    t_restart = time.perf_counter()

    # -- 4. restore from the container, replay held work --------------------
    try:
        rheader, rarrays = decode_snapshot(blob)
        stats = engine.restore_state(rheader, rarrays)
        _restore_port_state(shell, slot, rheader, rarrays)
    except Exception as e:  # noqa: BLE001 — the engine is already reset;
        # resume so held work fails/replays against the empty engine
        # rather than wedging, and surface the loss loudly
        _record_migration_fault(shell, e, slot=slot, tenant=tenant,
                                stage="restore")
        port.resume()
        raise MigrationError(
            f"local restore failed on slot {slot}: {e} (the tenant's "
            "state is intact in the snapshot container, but the live "
            "engine was reset)") from e
    replayed = port.resume()
    t_done = time.perf_counter()

    return RecoveryReport(
        slot=slot, tenant=tenant,
        n_requests=stats["requests"], n_queued=stats["queued"],
        n_pages=stats["pages"], payload_bytes=len(blob),
        failed_inflight=failed, replayed=replayed,
        quiesce_s=t_q - t0, snapshot_s=t_s - t_q,
        restart_s=t_restart - t_s, restore_s=t_done - t_restart,
        downtime_s=t_done - t0)
