"""Seeded, deterministic fault injection for the shell (robustness layer).

Coyote v2 promises that a slot can be lost or reconfigured without taking
down the shell; RC3E frames the cloud version, where a controller must
*detect* unhealthy virtual FPGAs and recover tenants automatically.  That
machinery is untestable without a way to make things fail on demand — and
fail *the same way every run*.  This module is that way:

  * :class:`FaultKind` — ONE taxonomy of typed fault kinds shared by the
    serving shell and the trainer (``repro.train.loop.SimulatedFailure``
    is a :class:`InjectedFault` of kind ``NODE_FAILURE``).
  * :class:`FaultSpec` — one armed fault: a kind, a named injection
    ``site``, skip/fire counts (``after``/``count``), an optional firing
    probability ``p``, and slot/tenant filters.
  * :class:`FaultPlan` — an ordered set of specs plus a seeded RNG.  The
    shell's instrumented paths call :meth:`FaultPlan.fire` at named sites
    (e.g. ``"lane.execute"``, ``"pager.gather"``); an armed matching spec
    raises :class:`InjectedFault` there.  Behavioural faults (the
    page-fault storm) use :meth:`FaultPlan.force`, which returns the spec
    instead of raising so the call site can *simulate* pressure (forced
    eviction churn) rather than crash.

Determinism contract: with the same plan (specs + seed) and the same
sequence of ``fire``/``force`` calls, the same faults fire at the same
hits.  Probabilistic specs draw from the plan's own
``np.random.RandomState`` — never from global randomness.

Injection sites wired in this repo (see docs/api.md):

    port.dispatch     Port._safe_dispatch (any invocation kind)
    lane.execute      ShellScheduler._execute_batch, SG work
    io.complete       ShellScheduler._execute_batch, pure-IO batches
    service.call      ServicePort method execution
    pager.gather      MMU evict-with-copy gather (evict + CoW paths)
    pager.scatter     MMU fault-back-in scatter
    mmu.page_storm    MMU._take_device_page (force mode: eviction churn)
    reconfig.load     Shell.reconfigure, between snapshot and load
    migrate.precopy   migrate_precopy(), each warm copy round
    migrate.snapshot  migrate(), stage 2
    migrate.restore   migrate(), stage 3
    migrate.replay    migrate(), stage 4
    train.step        Trainer._run_inner, once per step
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


class FaultKind(str, Enum):
    """Typed fault kinds — the one taxonomy every injector and every
    health record uses (``str`` mixin: JSON-safe, comparable to its
    value)."""
    LANE_CRASH = "lane_crash"            # executor-lane body exception
    IO_ERROR = "io_error"                # DMA/IO completion error
    DISPATCH = "dispatch"                # port dispatch-path exception
    SERVICE_CALL = "service_call"        # service method raised
    PAGER_GATHER = "pager_gather"        # evict-with-copy gather failed
    PAGER_SCATTER = "pager_scatter"      # fault-back-in scatter failed
    PAGE_FAULT_STORM = "page_fault_storm"  # forced eviction churn
    RECONFIG_ABORT = "reconfig_abort"    # hot-swap aborted mid-load
    MIGRATION_FAIL = "migration_fail"    # migration failed mid-container
    NODE_FAILURE = "node_failure"        # whole-node crash (trainer)
    WEDGE = "wedge"                      # watchdog: stale heartbeat + work
    QUIESCE_TIMEOUT = "quiesce_timeout"  # drain did not converge
    IO_FLUSH_TIMEOUT = "io_flush_timeout"  # flush_io did not drain
    QUARANTINED = "quarantined"          # typed rejection of a bad tenant
    SLO_INFEASIBLE = "slo_infeasible"    # gateway: deadline can't be met
    SLO_EXPIRED = "slo_expired"          # gateway: deadline passed queued
    GATEWAY_FULL = "gateway_full"        # gateway: admission queue bound


# Kinds that are transient by nature: a bounded re-dispatch of the same
# invocation is expected to succeed (the Port retry machinery consults
# this through ``InjectedFault.retryable``).  Aborts/wedges/rejections
# are terminal — retrying them would just repeat the failure.
DEFAULT_RETRYABLE = frozenset({
    FaultKind.LANE_CRASH, FaultKind.IO_ERROR, FaultKind.DISPATCH,
    FaultKind.SERVICE_CALL, FaultKind.PAGER_GATHER,
    FaultKind.PAGER_SCATTER, FaultKind.PAGE_FAULT_STORM,
    # a full gateway queue is load, not damage: back off and resubmit
    FaultKind.GATEWAY_FULL,
})

# Default injection site per kind, for the FaultPlan.single() shorthand.
DEFAULT_SITES: Dict[FaultKind, str] = {
    FaultKind.LANE_CRASH: "lane.execute",
    FaultKind.IO_ERROR: "io.complete",
    FaultKind.DISPATCH: "port.dispatch",
    FaultKind.SERVICE_CALL: "service.call",
    FaultKind.PAGER_GATHER: "pager.gather",
    FaultKind.PAGER_SCATTER: "pager.scatter",
    FaultKind.PAGE_FAULT_STORM: "mmu.page_storm",
    FaultKind.RECONFIG_ABORT: "reconfig.load",
    FaultKind.MIGRATION_FAIL: "migrate.restore",
    FaultKind.NODE_FAILURE: "train.step",
}


class InjectedFault(RuntimeError):
    """A typed, injected failure.  Carries enough context for the Port
    layer to build a structured ``PortError`` (kind, site, slot, tenant,
    retryable) and for the health monitor to account it."""

    def __init__(self, message: str = "", *,
                 kind: FaultKind = FaultKind.NODE_FAILURE,
                 site: str = "", slot: Optional[int] = None,
                 tenant: Optional[str] = None,
                 retryable: Optional[bool] = None):
        self.kind = FaultKind(kind)
        self.site = site
        self.slot = slot
        self.tenant = tenant
        self.retryable = (retryable if retryable is not None
                          else self.kind in DEFAULT_RETRYABLE)
        super().__init__(message or f"injected {self.kind.value} at "
                         f"{site or DEFAULT_SITES.get(self.kind, '?')}")


@dataclass
class FaultSpec:
    """One armed fault.  Matching is positional and deterministic: the
    spec matches its ``site`` (and optional slot/tenant filters); the
    first ``after`` matching hits pass through unharmed, then the next
    ``count`` hits fire (each gated by probability ``p`` drawn from the
    plan's seeded RNG)."""
    kind: FaultKind
    site: str = ""                       # default: DEFAULT_SITES[kind]
    after: int = 0                       # matching hits to skip first
    count: int = 1                       # fires before the spec disarms
    p: float = 1.0                       # per-hit firing probability
    slot: Optional[int] = None           # only this slot (None = any)
    tenant: Optional[str] = None         # only this tenant (None = any)
    retryable: Optional[bool] = None     # override DEFAULT_RETRYABLE
    message: str = ""
    # runtime counters (owned by the plan, under its lock)
    hits: int = field(default=0, compare=False)
    fired: int = field(default=0, compare=False)

    def __post_init__(self) -> None:
        self.kind = FaultKind(self.kind)
        if not self.site:
            self.site = DEFAULT_SITES.get(self.kind, "")
        if not self.site:
            raise ValueError(f"FaultSpec({self.kind}) needs a site")


class FaultPlan:
    """A deterministic, seeded set of armed faults.

        plan = FaultPlan([FaultSpec(FaultKind.LANE_CRASH, after=2)],
                         seed=7)
        shell.set_fault_plan(plan)

    Instrumented shell paths call ``plan.fire(site, slot=, tenant=)``;
    an armed matching spec raises :class:`InjectedFault`.  Thread-safe:
    lanes, the scheduler worker, and engine threads all probe the same
    plan.
    """

    def __init__(self, specs: Iterable[FaultSpec] = (), *, seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self._rng = np.random.RandomState(seed)
        self._lock = threading.Lock()
        self.fired: List[Dict[str, Any]] = []      # audit log of firings

    @classmethod
    def single(cls, kind: FaultKind, *, seed: int = 0,
               **spec_kw: Any) -> "FaultPlan":
        """One-spec shorthand: ``FaultPlan.single(FaultKind.IO_ERROR,
        after=3)``."""
        return cls([FaultSpec(kind=kind, **spec_kw)], seed=seed)

    def arm(self, spec: FaultSpec) -> FaultSpec:
        with self._lock:
            self.specs.append(spec)
        return spec

    # ------------------------------------------------------------ firing ---
    def _match(self, site: str, slot: Optional[int],
               tenant: Optional[str]) -> Optional[FaultSpec]:
        with self._lock:
            for spec in self.specs:
                if spec.site != site:
                    continue
                if spec.slot is not None and slot is not None \
                        and spec.slot != slot:
                    continue
                if spec.tenant is not None and tenant is not None \
                        and spec.tenant != tenant:
                    continue
                spec.hits += 1
                if spec.fired >= spec.count or spec.hits <= spec.after:
                    continue
                if spec.p < 1.0 and self._rng.random_sample() >= spec.p:
                    continue
                spec.fired += 1
                self.fired.append({"kind": spec.kind.value, "site": site,
                                   "slot": slot, "tenant": tenant,
                                   "hit": spec.hits})
                return spec
        return None

    def fire(self, site: str, *, slot: Optional[int] = None,
             tenant: Optional[str] = None, **ctx: Any) -> None:
        """Raise :class:`InjectedFault` if an armed spec matches this hit
        (extra ``ctx`` keys are accepted for call-site convenience and
        folded into the message)."""
        spec = self._match(site, slot, tenant)
        if spec is None:
            return
        detail = "".join(f" {k}={v}" for k, v in sorted(ctx.items()))
        raise InjectedFault(
            spec.message or f"injected {spec.kind.value} at {site} "
            f"(hit {spec.hits}, slot={slot}, tenant={tenant}{detail})",
            kind=spec.kind, site=site, slot=slot, tenant=tenant,
            retryable=spec.retryable)

    def force(self, site: str, *, slot: Optional[int] = None,
              tenant: Optional[str] = None) -> Optional[FaultSpec]:
        """Non-raising probe for behavioural faults: the matching spec is
        consumed and RETURNED, and the call site simulates the failure
        mode itself (e.g. the MMU treats the pool as exhausted to force
        a real evict/fault-in cycle)."""
        return self._match(site, slot, tenant)

    # ------------------------------------------------------------- stats ---
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "seed": self.seed,
                "specs": [{"kind": s.kind.value, "site": s.site,
                           "after": s.after, "count": s.count,
                           "hits": s.hits, "fired": s.fired}
                          for s in self.specs],
                "fired_total": len(self.fired),
            }

    def exhausted(self) -> bool:
        """True once every armed spec has fired its full count."""
        with self._lock:
            return all(s.fired >= s.count for s in self.specs)


def maybe_fire(plan: Optional["FaultPlan"], site: str, *,
               slot: Optional[int] = None, tenant: Optional[str] = None,
               **ctx: Any) -> None:
    """``plan.fire`` guarded against ``plan is None`` — the shape every
    instrumented call site uses so uninstrumented runs cost one attribute
    load and one comparison."""
    if plan is not None:
        plan.fire(site, slot=slot, tenant=tenant, **ctx)
