"""Per-slot health tracking: heartbeats, fault windows, quarantine.

The observability half of the self-healing shell (the RC3E "detect
unhealthy vFPGAs" loop).  :class:`HealthMonitor` is a passive, thread-safe
ledger the shell's datapaths feed:

  * **Heartbeats** — executor lanes beat once per executed batch and
    ``ServingEngine.step`` beats once per decode step.  A slot whose last
    beat is older than ``heartbeat_timeout_s`` *while it still has
    pending work* is **wedged** (an idle slot is never wedged — silence
    without work is just silence).
  * **Fault windows** — every typed fault is counted by kind and, when
    attributable, struck against its tenant.  ``quarantine_after``
    strikes inside ``quarantine_window_s`` quarantines the tenant:
    further submissions are rejected fast with a typed
    ``PortError(kind=QUARANTINED)`` while bystanders keep their SLOs.
  * **Events** — a bounded deque of recent health events (faults,
    recoveries, quarantines, quiesce/IO-flush timeouts) for
    ``Shell.status()["health"]``.

:class:`Watchdog` is the active half: a daemon thread that periodically
calls ``shell.check_health(auto_recover=...)`` so wedged slots are
detected and recovered without anyone polling.  It is opt-in
(``Shell.start_watchdog``) — tests mostly drive ``check_health``
directly for determinism.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.core.faults import FaultKind


class HealthMonitor:
    """Thread-safe health ledger: heartbeats, fault counts, quarantines."""

    def __init__(self, *, heartbeat_timeout_s: float = 2.0,
                 quarantine_after: int = 3,
                 quarantine_window_s: float = 30.0,
                 max_events: int = 256):
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.quarantine_after = quarantine_after
        self.quarantine_window_s = quarantine_window_s
        self._lock = threading.Lock()
        self._beats: Dict[int, float] = {}          # slot -> perf_counter
        self._fault_counts: Dict[str, int] = {}
        self._strikes: Dict[str, List[float]] = {}  # tenant -> fault times
        self._quarantined: Dict[str, str] = {}      # tenant -> reason
        self._events: Deque[Dict[str, Any]] = deque(maxlen=max_events)
        self.faults_total = 0
        self.recoveries = 0
        self.rejections = 0                          # quarantine rejections

    # --------------------------------------------------------- heartbeats --
    def beat(self, slot: int) -> None:
        with self._lock:
            self._beats[slot] = time.perf_counter()

    def last_beat_age(self, slot: int) -> Optional[float]:
        """Seconds since the slot's last heartbeat (None = never beat)."""
        with self._lock:
            t = self._beats.get(slot)
        return None if t is None else time.perf_counter() - t

    def wedged(self, pending: Dict[int, bool]) -> List[int]:
        """Slots with pending work whose heartbeat is stale.  A slot that
        never beat gets a grace beat on first sight, so freshly loaded
        slots are not declared dead before their first step."""
        now = time.perf_counter()
        out = []
        with self._lock:
            for slot, has_work in pending.items():
                if not has_work:
                    continue
                t = self._beats.get(slot)
                if t is None:
                    self._beats[slot] = now          # grace period starts
                    continue
                if now - t > self.heartbeat_timeout_s:
                    out.append(slot)
        return out

    # ------------------------------------------------------------- faults --
    def record_fault(self, kind: Any, *, slot: Optional[int] = None,
                     tenant: Optional[str] = None, site: str = "",
                     msg: str = "", strike: bool = True) -> bool:
        """Account one typed fault; returns True when this fault NEWLY
        quarantined its tenant (``strike=False`` records without counting
        toward quarantine — used for informational events)."""
        kind = FaultKind(kind).value if not isinstance(kind, str) else kind
        newly = False
        now = time.perf_counter()
        with self._lock:
            self.faults_total += 1
            self._fault_counts[kind] = self._fault_counts.get(kind, 0) + 1
            self._events.append({"t": now, "event": "fault", "kind": kind,
                                 "slot": slot, "tenant": tenant,
                                 "site": site, "msg": msg})
            if strike and tenant is not None:
                times = self._strikes.setdefault(tenant, [])
                times.append(now)
                floor = now - self.quarantine_window_s
                times[:] = [t for t in times if t >= floor]
                if (len(times) >= self.quarantine_after
                        and tenant not in self._quarantined):
                    self._quarantined[tenant] = (
                        f"{len(times)} {kind} fault(s) within "
                        f"{self.quarantine_window_s:.0f}s")
                    self._events.append({"t": now, "event": "quarantine",
                                         "tenant": tenant, "kind": kind})
                    newly = True
        return newly

    def record_event(self, event: str, **fields: Any) -> None:
        """Informational health event (recovery detail, flush timeout...)
        — visible in ``status()["events"]``, no fault accounting."""
        with self._lock:
            self._events.append({"t": time.perf_counter(), "event": event,
                                 **fields})

    def record_recovery(self, slot: int, tenant: Optional[str],
                        downtime_s: float) -> None:
        with self._lock:
            self.recoveries += 1
            self._events.append({"t": time.perf_counter(),
                                 "event": "recovery", "slot": slot,
                                 "tenant": tenant,
                                 "downtime_s": downtime_s})

    # --------------------------------------------------------- quarantine --
    def is_quarantined(self, tenant: Optional[str]) -> bool:
        if tenant is None:
            return False
        with self._lock:
            return tenant in self._quarantined

    def quarantine(self, tenant: str, reason: str = "manual") -> None:
        with self._lock:
            self._quarantined[tenant] = reason
            self._events.append({"t": time.perf_counter(),
                                 "event": "quarantine", "tenant": tenant,
                                 "reason": reason})

    def unquarantine(self, tenant: str) -> bool:
        """Lift a quarantine (operator verb); clears the strike window so
        the next fault starts a fresh count."""
        with self._lock:
            was = self._quarantined.pop(tenant, None) is not None
            self._strikes.pop(tenant, None)
            if was:
                self._events.append({"t": time.perf_counter(),
                                     "event": "unquarantine",
                                     "tenant": tenant})
        return was

    def record_rejection(self, tenant: Optional[str]) -> None:
        with self._lock:
            self.rejections += 1

    def recent_faults(self, window_s: float = 30.0) -> int:
        """Fault events recorded within the trailing window — the fleet
        controller's hotspot/health signal when scoring placements.
        Bounded by the event ring (``max_events``), which is fine: a
        member with a saturated ring is not a placement candidate."""
        floor = time.perf_counter() - window_s
        with self._lock:
            return sum(1 for e in self._events
                       if e.get("event") == "fault" and e["t"] >= floor)

    # -------------------------------------------------------------- status --
    def status(self) -> Dict[str, Any]:
        now = time.perf_counter()
        with self._lock:
            return {
                "faults_total": self.faults_total,
                "fault_counts": dict(self._fault_counts),
                "recoveries": self.recoveries,
                "rejections": self.rejections,
                "quarantined": dict(self._quarantined),
                "last_heartbeat_age_s": {
                    slot: now - t for slot, t in self._beats.items()},
                "events": list(self._events)[-20:],
            }


class Watchdog:
    """Daemon thread: periodically runs ``shell.check_health`` so wedged
    slots are detected (and optionally recovered) without polling."""

    def __init__(self, shell: Any, *, interval_s: float = 0.25,
                 auto_recover: bool = True):
        self.shell = shell
        self.interval_s = interval_s
        self.auto_recover = auto_recover
        self.sweeps = 0
        self.last_result: Dict[str, Any] = {}
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._loop,
                                       name="shell-watchdog", daemon=True)
        self.thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.last_result = self.shell.check_health(
                    auto_recover=self.auto_recover)
            except Exception as e:  # noqa: BLE001 — the watchdog must
                # outlive whatever it finds; a failed sweep is an event,
                # not a watchdog death
                self.shell.health.record_event("watchdog_error",
                                               error=str(e))
            self.sweeps += 1

    def stop(self, timeout: float = 2.0) -> None:
        self._stop.set()
        self.thread.join(timeout=timeout)
