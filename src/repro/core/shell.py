"""The shell: dynamic (services) layer + application layer (paper §3/§4).

``Shell`` composes the three-layer design:

  static layer   (never reconfigured)  — StaticLayer: host link, compile
                                         cache, interrupts, reconfig ctrl
  dynamic layer  (reconfigurable)      — ServiceRegistry: MMU, collectives,
                                         compression, encryption, sniffer
  app layer      (reconfigurable)      — VFpga slots behind the unified
                                         interface, shared via cThreads

Reconfiguration contract (paper §4): a *shell* reconfiguration swaps
services and relinks apps (refusing configurations that strand a loaded
app); an *app* reconfiguration touches one slot only.  Both are an order of
magnitude cheaper than :meth:`cold_restart`, the full-reprogramming
analogue (Table 3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import credits as C
from repro.core.cthread import CThread
from repro.core.faults import FaultKind, FaultPlan
from repro.core.health import HealthMonitor, Watchdog
from repro.core.interfaces import Oper
from repro.core.port import (Port, SERVICE_SLOT_BASE, ServicePort,
                             VFpgaPort)
from repro.core.scheduler import ShellScheduler, Tenant
from repro.core.services.base import Service, ServiceRegistry
from repro.core.services.collectives import CollectiveConfig, CollectiveService
from repro.core.services.compression import CompressionConfig, GradCompression
from repro.core.services.encryption import AESConfig, AESService
from repro.core.services.mmu import MMU, MMUConfig
from repro.core.services.sniffer import SnifferConfig, TrafficSniffer
from repro.core.static_layer import IRQ_PAGE_FAULT, StaticLayer
from repro.core.vfpga import AppArtifact, VFpga

SERVICE_TYPES = {
    "mmu": (MMU, MMUConfig),
    "collectives": (CollectiveService, CollectiveConfig),
    "compression": (GradCompression, CompressionConfig),
    "encryption": (AESService, AESConfig),
    "sniffer": (TrafficSniffer, SnifferConfig),
}


@dataclass(frozen=True)
class ShellConfig:
    """Compile-time shell parametrization (paper §4: 'a shell is fully
    parametrized by its services and the user applications')."""
    services: Tuple[Tuple[str, Any], ...] = ()
    n_vfpgas: int = 4
    n_streams: int = 4
    packet_bytes: int = 4096
    stream_depth: int = 64
    hbm_budget: int = 1 << 32
    pcie_gbps: float = 12e9
    # per-slot executor lanes (False serializes all execution on the
    # scheduler worker — the pre-lane baseline, kept for A/B benches)
    executor_lanes: bool = True

    @staticmethod
    def make(services: Dict[str, Any] = None, **kw) -> "ShellConfig":
        svc = tuple(sorted((services or {}).items(), key=lambda x: x[0]))
        return ShellConfig(services=svc, **kw)


@dataclass
class BuildReport:
    flow: str
    components: Dict[str, Dict[str, float]] = field(default_factory=dict)
    total_s: float = 0.0
    cache_hits: int = 0

    def add(self, name: str, lower_s: float, compile_s: float,
            hit: bool) -> None:
        self.components[name] = {"lower_s": lower_s, "compile_s": compile_s,
                                 "cached": float(hit)}
        self.cache_hits += int(hit)


class Shell:
    def __init__(self, config: ShellConfig,
                 static: Optional[StaticLayer] = None, mesh=None,
                 name: Optional[str] = None):
        self.config = config
        # fleet identity: how a FleetController addresses this member
        self.name = name or f"shell-{id(self) & 0xFFFF:04x}"
        self.static = static or StaticLayer(mesh, pcie_gbps=config.pcie_gbps)
        self.mesh = mesh
        self.services = ServiceRegistry()
        self.vfpgas: List[VFpga] = []
        self.arbiter = C.WeightedRRArbiter(self.static.pcie,
                                           packet_bytes=config.packet_bytes)
        self.scheduler = ShellScheduler(self.arbiter,
                                        packet_bytes=config.packet_bytes,
                                        stream_depth=config.stream_depth,
                                        lanes=config.executor_lanes)
        self.ports: Dict[str, Port] = {}     # unified port registry (v2)
        # slot -> serving engine bound to that slot (ServingEngine
        # registers itself): how repro.core.migrate finds the paged
        # state behind a slot
        self.engines: Dict[int, Any] = {}
        self.built = False
        # robustness layer: passive health ledger (heartbeats, fault
        # counts, quarantines) plus an optional armed fault plan, both
        # shared with the scheduler/MMU via set_fault_plan
        self.health = HealthMonitor()
        self.faults: Optional[FaultPlan] = None
        self._watchdog: Optional[Watchdog] = None
        self.scheduler.health = self.health

    # ==================================================== build ("synthesis")
    def build(self, *, flow: str = "shell") -> BuildReport:
        """Synthesize the shell.  ``flow='shell'`` builds services + slots;
        ``flow='app'`` assumes service artifacts are already in the compile
        cache (the nested build flow, Fig 7b) and only prepares slots."""
        t0 = time.perf_counter()
        report = BuildReport(flow=flow)
        self._instantiate_services()
        for name in self.services.names():
            svc = self.services.get(name)
            for aname, stats in self._build_service(svc).items():
                report.add(f"{name}/{aname}", stats["lower_s"],
                           stats["compile_s"], stats["cached"])
        if not self.vfpgas:
            for slot in range(self.config.n_vfpgas):
                self.vfpgas.append(VFpga(
                    slot, self.static, n_streams=self.config.n_streams,
                    hbm_budget=self.config.hbm_budget))
                self.vfpgas[-1].shell = self
        report.total_s = time.perf_counter() - t0
        self.built = True
        return report

    def _instantiate_services(self) -> None:
        for name, svc_cfg in self.config.services:
            cls, _cfg_cls = SERVICE_TYPES[name]
            if name in self.services:
                existing = self.services.get(name)
                if existing.config != svc_cfg:
                    existing.configure(svc_cfg)
                continue
            if name == "mmu":
                svc = cls(svc_cfg, interrupt_post=lambda slot, v:
                          self.static.interrupts.post(slot, IRQ_PAGE_FAULT, v))
            else:
                svc = cls(svc_cfg)
            if name == "sniffer":
                svc.attach(self.static.pcie)
            self.services.add(svc)
        # drop services not in the new config
        wanted = {n for n, _ in self.config.services}
        for name in list(self.services.names()):
            if name not in wanted:
                self.services.remove(name)
        # (re)arm the pager fault hooks on whatever MMU instance the
        # build produced — set_fault_plan before OR after build both work
        mmu = self.services.get("mmu")
        if mmu is not None:
            mmu.faults = self.faults

    def _build_service(self, svc: Service) -> Dict[str, Dict[str, float]]:
        """Compile a service's device artifacts through the compile cache."""
        out: Dict[str, Dict[str, float]] = {}
        for aname, fn, args in self._service_kernels(svc):
            key = self.static.compile_cache.make_key(
                f"svc:{svc.NAME}:{aname}", svc.config, self.mesh,
                args)

            def build(fn=fn, args=args):
                b0 = time.perf_counter()
                lowered = jax.jit(fn).lower(*args)
                b1 = time.perf_counter()
                compiled = lowered.compile()
                b2 = time.perf_counter()
                return compiled, b1 - b0, b2 - b1

            entry, hit = self.static.compile_cache.get_or_build(key, build)
            out[aname] = {"lower_s": entry.lower_s,
                          "compile_s": entry.compile_s, "cached": hit}
            setattr(svc, f"kernel_{aname}", entry.compiled)
        return out

    def _service_kernels(self, svc: Service):
        """Device kernels each service contributes to the shell bitstream."""
        if svc.NAME == "mmu":
            c: MMUConfig = svc.config
            pool = jax.ShapeDtypeStruct((c.n_pages, c.page_size, 8, 64),
                                        jnp.bfloat16)
            table = jax.ShapeDtypeStruct((8, 16), jnp.int32)

            def gather_pages(pool, table):
                safe = jnp.maximum(table, 0)
                return jnp.take(pool, safe.reshape(-1), axis=0)
            yield "gather_pages", gather_pages, (pool, table)
        elif svc.NAME == "encryption":
            from repro.core.services import encryption as E
            blocks = jax.ShapeDtypeStruct((4096, 16), jnp.uint8)
            keys = jax.ShapeDtypeStruct((11, 16), jnp.uint8)
            yield "aes_ecb", E.encrypt_block, (blocks, keys)
            iv = jax.ShapeDtypeStruct((64, 16), jnp.uint8)
            mb = jax.ShapeDtypeStruct((64, 256, 16), jnp.uint8)
            yield "aes_cbc_ms", E.aes_cbc_multistream, (mb, iv, keys)
        elif svc.NAME == "compression":
            from repro.core.services.compression import _quantize_blockwise
            g = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
            yield "quantize", lambda x: _quantize_blockwise(
                x, svc.config.block, svc.config.bits)[:2], (g,)
        elif svc.NAME == "collectives":
            x = jax.ShapeDtypeStruct((1 << 16,), jnp.float32)
            yield "allreduce_probe", lambda x: x * 2.0, (x,)

    # ================================================= reconfiguration =====
    def reconfigure_shell(self, new_config: ShellConfig, *,
                          bitstream_path: Optional[str] = None
                          ) -> Dict[str, float]:
        """Swap the dynamic layer (Table 3).  Loaded apps are re-linked
        against the new services first; a violation aborts the swap."""
        t_total0 = time.perf_counter()
        if bitstream_path is not None:
            # stream the shell bitstream through the utility channel
            _, kernel_io_s, _, _ = self.static.reconfig.load_bitstream(
                bitstream_path, slot=0)
        t_k0 = time.perf_counter()
        # fail-safe: dry-check every loaded app against the new services
        trial = Shell(new_config, static=self.static, mesh=self.mesh)
        trial._instantiate_services = Shell._instantiate_services.__get__(trial)
        probe = ServiceRegistry()
        for name, svc_cfg in new_config.services:
            cls, _ = SERVICE_TYPES[name]
            probe.add(cls(svc_cfg))
        for vf in self.vfpgas:
            if vf.app is not None:
                for req in vf.app.requires:
                    if not probe.check(req):
                        raise RuntimeError(
                            f"shell reconfiguration would strand app "
                            f"{vf.app.name!r} in slot {vf.slot} "
                            f"(missing {req.service}:{req.constraints})")
        self.config = new_config
        self.build(flow="shell")
        # relink loaded apps against the new shell
        for vf in self.vfpgas:
            if vf.app is not None:
                art = vf.app
                vf.load(art, self.services, self.mesh)
        t1 = time.perf_counter()
        return {"kernel_s": t1 - t_k0, "total_s": t1 - t_total0}

    def reconfigure_app(self, slot: int, artifact: AppArtifact
                        ) -> Dict[str, float]:
        """App-only partial reconfiguration: one slot, services untouched.
        Deprecated shim over :meth:`reconfigure` (now drain-aware)."""
        return self.reconfigure(slot, artifact)

    def reconfigure(self, slot: int, bitstream, *,
                    drain_timeout: float = 30.0) -> Dict[str, float]:
        """Drain-aware hot-swap of ONE slot (Port API v2).

        ``bitstream`` is an :class:`AppArtifact` or a path to an app
        bitstream file (safe npz+JSON format, ``repro.core.reconfig``).
        The slot's port is quiesced first — intake held, every in-flight
        invocation completed — then the slot state (CSR file, cThread
        address map) is snapshotted, the new logic is loaded, state is
        restored, and invocations submitted during the swap are replayed
        in FIFO order against the new logic.  No completion is ever lost
        or duplicated; other slots' traffic is never paused.
        """
        t0 = time.perf_counter()
        if isinstance(bitstream, AppArtifact):
            artifact = bitstream
        else:
            from repro.core.reconfig import load_app_bitstream
            artifact = load_app_bitstream(str(bitstream))
        port = self.attach(slot)
        t_d0 = time.perf_counter()
        if not port.quiesce(timeout=drain_timeout):
            port.resume()                 # reopen intake; nothing was lost
            raise RuntimeError(
                f"slot {slot} failed to quiesce within {drain_timeout}s "
                f"({port.inflight()} invocations still in flight); "
                f"hot-swap aborted and intake resumed")
        drain_s = time.perf_counter() - t_d0
        snap = port.snapshot()
        try:
            if self.faults is not None:
                self.faults.fire("reconfig.load", slot=slot)
            stats = self.vfpgas[slot].load(artifact, self.services,
                                           self.mesh)
            port.restore(snap)
        except BaseException as e:
            # failed swap must not wedge the slot: reopen intake (held
            # invocations replay against whatever logic is loaded)
            self.health.record_fault(
                getattr(e, "kind", FaultKind.RECONFIG_ABORT), slot=slot,
                site="reconfig.load", strike=False, msg=str(e))
            port.resume()
            raise
        replayed = port.resume()
        stats["kernel_s"] = stats["total_s"]
        stats.update({
            "total_s": time.perf_counter() - t0,
            "drain_s": drain_s,
            "replayed": float(replayed),
        })
        return stats

    def cold_restart(self) -> Dict[str, float]:
        """Full re-programming analogue (Vivado flow + hot-plug): drop
        every executable and service, clear all caches, rebuild, reload."""
        t0 = time.perf_counter()
        apps = [(vf.slot, vf.app) for vf in self.vfpgas if vf.app]
        for vf in self.vfpgas:
            vf.unload()
        for name in list(self.services.names()):
            self.services.remove(name)
        self.static.compile_cache.clear()
        jax.clear_caches()
        self.vfpgas.clear()
        # every pre-restart port wraps a torn-down slot/service: close
        # them (externally held references fail fast instead of silently
        # dispatching against dead objects) and empty the registry —
        # Shell.attach() hands out live ports against the rebuilt shell.
        for p in self.ports.values():
            p.close()
        self.ports.clear()
        self.engines.clear()                 # engines wrap torn-down slots
        self.build(flow="shell")
        for slot, art in apps:
            self.vfpgas[slot].load(art, self.services, self.mesh)
        return {"total_s": time.perf_counter() - t0}

    # ================================================= app/thread access ====
    def load_app(self, slot: int, artifact: AppArtifact) -> Dict[str, float]:
        if not self.built:
            self.build()
        return self.vfpgas[slot].load(artifact, self.services, self.mesh)

    def attach_thread(self, slot: int, pid: int,
                      tenant: Optional[str] = None) -> CThread:
        if tenant is not None:
            self.scheduler.bind_slot(slot, tenant)
        t = CThread(self.vfpgas[slot], pid)
        return t

    # ================================================= ports (API v2) =======
    def attach(self, target, *, tenant: Optional[str] = None) -> Port:
        """Attach to a slot's or a service's unified Port.

        ``target`` is a vFPGA slot index (int) or a service name (str).
        The port's capability descriptor (streams, CSR map, memory model)
        is registered in the shell's port table — the capability handshake
        of the paper's unified interface.  Optionally binds the port's
        traffic to a QoS ``tenant``.
        """
        if isinstance(target, int):
            if not self.built:
                self.build()
            if tenant is not None:
                self.scheduler.bind_slot(target, tenant)
                self.vfpgas[target].tenant = tenant
            return self.vfpgas[target].attach_port()
        svc = self.services.get(target)
        if svc is None:
            raise KeyError(
                f"no service {target!r} in this shell "
                f"(have: {self.services.names()})")
        port = self.ports.get(target)
        if not isinstance(port, ServicePort) or port.service is not svc:
            port = ServicePort(
                svc, shell=self,
                slot=SERVICE_SLOT_BASE + self.services.names().index(target),
                tenant=tenant)
            self._register_port(port)
        elif tenant is not None:
            port.tenant = tenant
        return port

    def port(self, slot: int) -> VFpgaPort:
        """Shorthand: the unified port of one application slot."""
        return self.attach(slot)

    def _register_port(self, port: Port) -> None:
        self.ports[port.name] = port

    # ================================================= tenants / QoS ========
    def register_tenant(self, name: str, weight: float = 1.0,
                        slots: Tuple[int, ...] = ()) -> Tenant:
        """Create a bandwidth tenant with a QoS weight; optionally bind it
        to vFPGA slots (a slot's traffic bills to its bound tenant)."""
        t = self.scheduler.register_tenant(name, weight)
        for slot in slots:
            self.scheduler.bind_slot(slot, name)
            if slot < len(self.vfpgas):
                self.vfpgas[slot].tenant = name
        return t

    # ================================================= health / recovery ====
    def set_fault_plan(self, plan: Optional[FaultPlan]) -> None:
        """Arm (or disarm, with ``None``) a seeded fault plan across
        every instrumented layer: port dispatch, executor lanes and IO
        completion, service calls, the MMU pager, reconfigure, and
        migration.  Deterministic: same plan + same workload => same
        faults at the same hits."""
        self.faults = plan
        self.scheduler.faults = plan
        mmu = self.services.get("mmu")
        if mmu is not None:
            mmu.faults = plan

    def check_health(self, auto_recover: bool = False) -> Dict[str, Any]:
        """One watchdog sweep: a slot with pending work (queued/active
        engine requests or in-flight port invocations) whose heartbeat
        is stale is WEDGED — recorded as a typed fault and, with
        ``auto_recover``, recovered in place via
        quiesce-snapshot-restart-restore (:meth:`recover_slot`)."""
        pending: Dict[int, bool] = {}
        for slot, eng in list(self.engines.items()):
            pending[slot] = bool(eng.pending())
        for port in self.vfpga_ports():
            slot = port.vfpga.slot
            pending[slot] = pending.get(slot, False) or port.inflight() > 0
        wedged = self.health.wedged(pending)
        recovered: List[int] = []
        failed: List[int] = []
        for slot in wedged:
            tenant = (self.vfpgas[slot].tenant
                      if slot < len(self.vfpgas) else None)
            self.health.record_fault(
                FaultKind.WEDGE, slot=slot, tenant=tenant,
                site="watchdog", strike=False,
                msg=f"slot {slot} has pending work but a stale heartbeat")
            if not auto_recover:
                continue
            try:
                self.recover_slot(slot)
                recovered.append(slot)
            except Exception as e:  # noqa: BLE001 — one unrecoverable
                # slot must not stop the sweep over the others
                failed.append(slot)
                self.health.record_event("recovery_failed", slot=slot,
                                         error=str(e))
        return {"pending": pending, "wedged": wedged,
                "recovered": recovered, "failed": failed}

    def vfpga_ports(self) -> List[VFpgaPort]:
        return [p for p in self.ports.values() if isinstance(p, VFpgaPort)]

    def recover_slot(self, slot: int, *, drain_timeout: float = 5.0):
        """Recover ONE slot in place: quiesce (force-failing a stuck
        in-flight tail), snapshot the tenant through the PR-5 migration
        container, cold-reset the engine's device soft state, restore —
        KV pages (device + refcounted host payloads) survive and
        decoding resumes token-for-token.  Returns a
        :class:`~repro.core.migrate.RecoveryReport`."""
        from repro.core.migrate import recover_tenant_local
        report = recover_tenant_local(self, slot,
                                      drain_timeout=drain_timeout)
        self.health.record_recovery(slot, report.tenant,
                                    report.downtime_s)
        self.health.beat(slot)        # fresh grace period post-recovery
        return report

    def start_watchdog(self, *, interval_s: float = 0.25,
                       auto_recover: bool = True) -> Watchdog:
        """Start (idempotently) the background health sweeper."""
        if self._watchdog is None:
            self._watchdog = Watchdog(self, interval_s=interval_s,
                                      auto_recover=auto_recover)
        return self._watchdog

    def stop_watchdog(self) -> None:
        if self._watchdog is not None:
            self._watchdog.stop()
            self._watchdog = None

    # ================================================= datapath =============
    def kick(self, slot: int) -> None:
        """Legacy datapath: drain a slot's raw send queues into the
        scheduler.  ``CThread.invoke`` no longer uses the send queues (it
        is a shim over ``port.submit``); this remains for code that still
        pushes SG entries into ``iface.sq_read``/``sq_write`` directly."""
        vf = self.vfpgas[slot]
        for sq, cq in ((vf.iface.sq_read, vf.iface.cq_read),
                       (vf.iface.sq_write, vf.iface.cq_write)):
            while True:
                item = sq.pop(timeout=0)
                if item is None:
                    break
                ticket, sg = item
                self.scheduler.submit(
                    slot=slot, stream=sg.src_stream, ticket=ticket, sg=sg,
                    execute=vf.execute_sg, complete=cq.complete)

    def drain(self) -> None:
        """Block until every accepted submission has fully completed."""
        self.scheduler.drain()
        self.arbiter.drain()          # legacy direct-arbiter submissions

    def close(self) -> None:
        self.stop_watchdog()
        self.scheduler.close()

    def status(self) -> Dict[str, Any]:
        return {
            "services": self.services.status(),
            "slots": [vf.status() for vf in self.vfpgas],
            "ports": {name: {**p.stats(),
                             "capabilities": p.capabilities().to_dict()}
                      for name, p in self.ports.items()},
            "compile_cache": self.static.compile_cache.stats(),
            "link_bytes": self.static.pcie.bytes_moved,
            "fairness": self.arbiter.fairness(),
            "scheduler": self.scheduler.stats(),
            "health": self.health.status(),
        }
