"""Bitstream packaging + the two build flows (paper §4, §9.2, §9.3).

A "partial bitstream" here is a serialized artifact blob in the safe
npz+JSON container of :mod:`repro.core.bitstream` (magic ``CYBS``,
versioned header, no pickle): the shell config (for shell bitstreams) or
an app artifact with its weights (for app bitstreams).
``ReconfigController.load_bitstream`` streams them from disk through the
utility channel; :class:`repro.core.shell.Shell` applies them —
``Shell.reconfigure(slot, path)`` performs the drain-aware hot-swap.
"""
from __future__ import annotations

import importlib
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import bitstream as B
from repro.core.bitstream import BitstreamError
from repro.core.port import PortCapabilities
from repro.core.services.base import ServiceRequirement
from repro.core.shell import SERVICE_TYPES, Shell, ShellConfig
from repro.core.vfpga import AppArtifact


# ------------------------------------------------------- config codecs ----
def _encode_shell_config(config: ShellConfig) -> Dict[str, Any]:
    d = asdict(config)
    d["services"] = [{"name": name, "config": B.jsonable(asdict(cfg))
                      if hasattr(cfg, "__dataclass_fields__")
                      else B.jsonable(cfg)}
                     for name, cfg in config.services]
    return d


def _decode_shell_config(d: Dict[str, Any]) -> ShellConfig:
    services = {}
    for entry in d.get("services", ()):
        name = entry["name"]
        if name not in SERVICE_TYPES:
            raise BitstreamError(
                f"shell bitstream names unknown service {name!r} "
                f"(known: {sorted(SERVICE_TYPES)})")
        _cls, cfg_cls = SERVICE_TYPES[name]
        cfg = entry["config"]
        services[name] = (cfg_cls(**cfg) if isinstance(cfg, dict) else cfg)
    kw = {k: v for k, v in d.items() if k != "services"}
    kw["hbm_budget"] = int(kw.get("hbm_budget", 1 << 32))
    return ShellConfig.make(services=services, **kw)


# ----------------------------------------------------------- shell side ----
def save_shell_bitstream(path: str, config: ShellConfig,
                         weights: Any = None) -> int:
    """Write a shell 'partial bitstream' (config + optional weight arrays)
    in the safe versioned container."""
    arrays = None
    if weights is not None:
        arrays = jax.tree.map(np.asarray, weights)
    blob = B.encode("shell", {"config": _encode_shell_config(config)},
                    arrays=arrays)
    Path(path).write_bytes(blob)
    return len(blob)


def load_shell_bitstream(path: str) -> Tuple[ShellConfig, Any]:
    """Parse a shell bitstream -> (ShellConfig, weight arrays or None).
    Unknown kind/container version raise :class:`BitstreamError`."""
    _, header, arrays = B.decode(Path(path).read_bytes(),
                                 expect_kind="shell")
    return _decode_shell_config(header["config"]), arrays


# ------------------------------------------------------------- app side ----
def save_app_bitstream(path: str, artifact: AppArtifact) -> int:
    """Write an app 'partial bitstream'.  The fn is stored by reference
    (module:qualname) — user logic is code, weights are data."""
    caps = artifact.capabilities
    header = {
        "name": artifact.name,
        "version": artifact.version,
        "fn_ref": f"{artifact.fn.__module__}:{artifact.fn.__qualname__}",
        "requires": [{"service": r.service,
                      "constraints": B.jsonable(r.constraints)}
                     for r in artifact.requires],
        "config_repr": B.jsonable(artifact.config_repr),
        "capabilities": caps.to_dict() if caps is not None else None,
    }
    arrays = (jax.tree.map(np.asarray, artifact.weights)
              if artifact.weights is not None else None)
    blob = B.encode("app", header, arrays=arrays)
    Path(path).write_bytes(blob)
    return len(blob)


def load_app_bitstream(path: str) -> AppArtifact:
    _, header, arrays = B.decode(Path(path).read_bytes(), expect_kind="app")
    mod_name, qual = header["fn_ref"].split(":")
    fn = importlib.import_module(mod_name)
    for part in qual.split("."):
        fn = getattr(fn, part)
    caps = header.get("capabilities")
    return AppArtifact(
        name=header["name"], fn=fn,
        version=header.get("version", "0"),
        weights=arrays,
        requires=[ServiceRequirement(r["service"], r["constraints"])
                  for r in header.get("requires", ())],
        config_repr=header.get("config_repr"),
        capabilities=PortCapabilities.from_dict(caps) if caps else None)


# --------------------------------------------------------- build flows ----
@dataclass
class FlowTiming:
    flow: str
    build_s: float
    components: Dict[str, Dict[str, float]]
    cache_hits: int


def shell_flow(config: ShellConfig, *, static=None, mesh=None
               ) -> Tuple[Shell, FlowTiming]:
    """Full flow: synthesize services AND slots from scratch."""
    shell = Shell(config, static=static, mesh=mesh)
    t0 = time.perf_counter()
    report = shell.build(flow="shell")
    dt = time.perf_counter() - t0
    return shell, FlowTiming("shell", dt, report.components,
                             report.cache_hits)


def app_flow(shell: Shell, slot: int, artifact: AppArtifact
             ) -> Tuple[Dict[str, float], FlowTiming]:
    """Nested flow: link ONE app against the already-routed shell.  The
    service artifacts hit the compile cache; only the app compiles."""
    t0 = time.perf_counter()
    stats = shell.load_app(slot, artifact)
    dt = time.perf_counter() - t0
    return stats, FlowTiming("app", dt, {artifact.name: stats},
                             int(stats.get("compile_cache_hit", 0)))
