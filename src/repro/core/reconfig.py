"""Bitstream packaging + the two build flows (paper §4, §9.2, §9.3).

A "partial bitstream" here is a serialized artifact blob: the shell config
(for shell bitstreams) or an app artifact with its weights (for app
bitstreams).  ``ReconfigController.load_bitstream`` streams them from disk
through the utility channel; :class:`repro.core.shell.Shell` applies them.
"""
from __future__ import annotations

import pickle
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.shell import Shell, ShellConfig
from repro.core.vfpga import AppArtifact


def save_shell_bitstream(path: str, config: ShellConfig,
                         weights: Any = None) -> int:
    """Write a shell 'partial bitstream' (config + optional weight arrays)."""
    arrays = None
    if weights is not None:
        arrays = jax.tree.map(np.asarray, weights)
    payload = {"kind": "shell", "config": config, "arrays": arrays}
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    Path(path).write_bytes(blob)
    return len(blob)


def save_app_bitstream(path: str, artifact: AppArtifact) -> int:
    """Write an app 'partial bitstream'.  The fn is stored by reference
    (module:qualname) — user logic is code, weights are data."""
    payload = {
        "kind": "app",
        "name": artifact.name,
        "version": artifact.version,
        "fn_ref": f"{artifact.fn.__module__}:{artifact.fn.__qualname__}",
        "arrays": (jax.tree.map(np.asarray, artifact.weights)
                   if artifact.weights is not None else None),
        "requires": artifact.requires,
        "config_repr": artifact.config_repr,
    }
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    Path(path).write_bytes(blob)
    return len(blob)


def load_app_bitstream(path: str) -> AppArtifact:
    payload = pickle.loads(Path(path).read_bytes())
    assert payload["kind"] == "app"
    mod_name, qual = payload["fn_ref"].split(":")
    import importlib
    fn = importlib.import_module(mod_name)
    for part in qual.split("."):
        fn = getattr(fn, part)
    return AppArtifact(name=payload["name"], fn=fn,
                       version=payload["version"],
                       weights=payload["arrays"],
                       requires=payload["requires"],
                       config_repr=payload["config_repr"])


@dataclass
class FlowTiming:
    flow: str
    build_s: float
    components: Dict[str, Dict[str, float]]
    cache_hits: int


def shell_flow(config: ShellConfig, *, static=None, mesh=None
               ) -> Tuple[Shell, FlowTiming]:
    """Full flow: synthesize services AND slots from scratch."""
    shell = Shell(config, static=static, mesh=mesh)
    t0 = time.perf_counter()
    report = shell.build(flow="shell")
    dt = time.perf_counter() - t0
    return shell, FlowTiming("shell", dt, report.components,
                             report.cache_hits)


def app_flow(shell: Shell, slot: int, artifact: AppArtifact
             ) -> Tuple[Dict[str, float], FlowTiming]:
    """Nested flow: link ONE app against the already-routed shell.  The
    service artifacts hit the compile cache; only the app compiles."""
    t0 = time.perf_counter()
    stats = shell.load_app(slot, artifact)
    dt = time.perf_counter() - t0
    return stats, FlowTiming("app", dt, {artifact.name: stats},
                             int(stats.get("compile_cache_hit", 0)))
