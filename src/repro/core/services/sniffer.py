"""Traffic-sniffer service (paper §8, Fig 6): ibdump/tcpdump for the shell.

Two capture planes, matching the adaptation in DESIGN.md:

  * **live plane** — subscribes to :class:`repro.core.credits.Link` events
    (every packet the arbiter moves) with a CSR-controlled filter; records
    land in a ring buffer ("HBM buffer") and export as PCAP-like dicts for
    offline analysis.
  * **compiled plane** — captures the *collective* traffic of a compiled
    program from its HLO (the ICI "packets"), via the trip-count-aware
    walker.  This is the network debugger for pjit programs.

Control mirrors the paper: the filter and start/stop are CSRs, headers-only
capture is supported, and the service is insertable/removable at run time
(reconfiguration scenario #3 in Table 3).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.credits import Link, LinkEvent
from repro.core.interfaces import ControlRegisters
from repro.core.services.base import Service

CSR_SNIFFER_ENABLE = 0x100
CSR_SNIFFER_HEADERS_ONLY = 0x101
CSR_SNIFFER_FILTER_ID = 0x102


@dataclass(frozen=True)
class SnifferConfig:
    buffer_packets: int = 65536
    headers_only: bool = False
    src_filter: str = ""          # substring match, "" = all
    dst_filter: str = ""


@dataclass
class CaptureRecord:
    ts: float
    src: str
    dst: str
    nbytes: int
    tag: str
    payload_meta: Dict[str, Any] = field(default_factory=dict)


class TrafficSniffer(Service):
    NAME = "sniffer"
    PORT_METHODS = ("start", "stop", "to_records", "clear", "status",
                    "configure")
    PORT_CSR_MAP = {"enable": CSR_SNIFFER_ENABLE,
                    "headers_only": CSR_SNIFFER_HEADERS_ONLY,
                    "filter_id": CSR_SNIFFER_FILTER_ID}
    PORT_MEM_MODEL = "host"

    def __init__(self, config: Optional[SnifferConfig] = None):
        if config is None:
            config = SnifferConfig()
        super().__init__(config)
        self._ring: deque = deque(maxlen=config.buffer_packets)
        self._running = False
        self._attached: List[Link] = []
        self.dropped = 0
        self.csr = ControlRegisters()
        self.csr.on_write(CSR_SNIFFER_ENABLE,
                          lambda v: self.start() if v else self.stop())

    # -- lifecycle -------------------------------------------------------------
    def attach(self, link: Link) -> None:
        """Insert the filter between the stacks and the CMAC (Fig 6)."""
        link.on_event(self._on_event)
        self._attached.append(link)

    def start(self) -> None:
        self._running = True

    def stop(self) -> None:
        self._running = False

    def configure(self, config: SnifferConfig) -> None:
        super().configure(config)
        self._ring = deque(self._ring, maxlen=config.buffer_packets)

    # -- data plane ---------------------------------------------------------------
    def _on_event(self, ev: LinkEvent) -> None:
        if not self._running:
            return
        c: SnifferConfig = self.config
        if c.src_filter and c.src_filter not in ev.src:
            return
        if c.dst_filter and c.dst_filter not in ev.dst:
            return
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        rec = CaptureRecord(ts=ev.t, src=ev.src, dst=ev.dst,
                            nbytes=0 if c.headers_only else ev.nbytes,
                            tag=ev.tag)
        if c.headers_only:
            rec.payload_meta = {"len": ev.nbytes}
        self._ring.append(rec)

    # -- sync back to host + export (the software parser) ---------------------------
    def to_records(self) -> List[Dict[str, Any]]:
        """PCAP-like export for analysis with standard tooling."""
        return [{"ts": r.ts, "src": r.src, "dst": r.dst, "len": r.nbytes,
                 "tag": r.tag, **r.payload_meta} for r in self._ring]

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0

    # -- compiled plane ---------------------------------------------------------------
    @staticmethod
    def capture_compiled(compiled) -> List[Dict[str, Any]]:
        """Collective 'packets' of a compiled pjit program."""
        from repro.telemetry import hlo_cost
        totals = hlo_cost.analyze_text(compiled.as_text())
        out = []
        for op, count in sorted(totals.coll_counts.items()):
            out.append({
                "op": op,
                "count": int(count),
                "bytes": int(totals.coll_bytes_naive.get(op, 0)),
                "wire_bytes": int(totals.coll_bytes_wire.get(op, 0)),
            })
        return out

    def status(self) -> Dict[str, Any]:
        s = super().status()
        s.update(running=self._running, captured=len(self._ring),
                 dropped=self.dropped, links=len(self._attached))
        return s
