"""Collective service: the RoCE-v2 RDMA stack analogue (paper §6.2).

BALBOA gives Coyote v2 a reusable, reconfigurable 100G networking service
that talks to commodity fabrics.  On a TPU pod the fabric is ICI and the
"stack" is the collective schedule.  This service owns:

  * schedule selection — flat ring vs hierarchical (reduce-scatter intra-pod,
    all-reduce across the `pod` axis, all-gather back), switchable at run
    time like swapping TCP/IP <-> RDMA in the paper;
  * shard_map-level primitives usable inside pjit programs;
  * an RDMA-style queue-pair registry (connect/send semantics over
    collective_permute) used for pod-to-pod hand-off;
  * wire-byte estimates per schedule for the roofline analysis.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.services.base import Service


@dataclass(frozen=True)
class CollectiveConfig:
    schedule: str = "auto"        # auto | flat | hierarchical
    data_axis: str = "data"
    pod_axis: str = "pod"
    # chunk (bytes) for bucketed gradient reduction overlap
    bucket_bytes: int = 32 << 20


class CollectiveService(Service):
    NAME = "collectives"
    PORT_METHODS = ("pick_schedule", "create_qp", "qp_permutation",
                    "wire_bytes", "status", "configure")
    PORT_MEM_MODEL = "device"

    def __init__(self, config: Optional[CollectiveConfig] = None):
        super().__init__(config if config is not None
                         else CollectiveConfig())
        self._qps: Dict[int, Tuple[int, int]] = {}   # qp id -> (src, dst)
        self._next_qp = 1

    # -- schedule selection ---------------------------------------------------
    def pick_schedule(self, mesh) -> str:
        c: CollectiveConfig = self.config
        if c.schedule != "auto":
            return c.schedule
        return ("hierarchical" if c.pod_axis in mesh.axis_names
                else "flat")

    # -- shard_map primitives ---------------------------------------------------
    def all_reduce(self, x, mesh, axes: Optional[Tuple[str, ...]] = None
                   ) -> jnp.ndarray:
        """Schedule-aware all-reduce for use INSIDE shard_map bodies.

        Default (``axes=None``): reduce over the data-parallel axes with
        the configured schedule (flat psum vs hierarchical RS/AR/AG) —
        the gradient path.  ``axes=(...,)`` overrides the axis set and
        always reduces flat: the tensor-parallel serving path sums
        attention/MLP partials over the ``model`` axis this way
        (``repro.serve.tp``), where the reduction is tiny (one activation
        vector) and latency-bound, so schedule games don't pay.
        """
        if axes is not None:
            axes = tuple(a for a in axes if a in mesh.axis_names)
            return jax.lax.psum(x, axes) if axes else x
        sched = self.pick_schedule(mesh)
        c: CollectiveConfig = self.config
        if sched == "hierarchical" and c.pod_axis in mesh.axis_names:
            return self._hierarchical_ar(x, c.data_axis, c.pod_axis)
        axes = tuple(a for a in (c.pod_axis, c.data_axis)
                     if a in mesh.axis_names)
        return jax.lax.psum(x, axes)

    @staticmethod
    def _hierarchical_ar(x, data_axis: str, pod_axis: str):
        """reduce-scatter(data) -> all-reduce(pod) -> all-gather(data).

        Inter-pod traffic drops by the data-axis size versus a flat
        all-reduce over (pod, data): only 1/|data| of the tensor crosses
        the pod boundary."""
        orig_shape = x.shape
        n_elems = int(np.prod(orig_shape)) if orig_shape else 1
        flat = x.reshape(-1)
        n = jax.lax.psum(1, data_axis)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        part = jax.lax.psum_scatter(flat, data_axis, scatter_dimension=0,
                                    tiled=True)
        part = jax.lax.psum(part, pod_axis)
        full = jax.lax.all_gather(part, data_axis, axis=0, tiled=True)
        return full[:n_elems].reshape(orig_shape)

    # -- QP registry (RDMA verbs analogue) --------------------------------------
    def create_qp(self, src_pod: int, dst_pod: int) -> int:
        qp = self._next_qp
        self._next_qp += 1
        self._qps[qp] = (src_pod, dst_pod)
        return qp

    def qp_permutation(self, qp: int, n_pods: int) -> List[Tuple[int, int]]:
        """collective_permute pairs implementing this QP's one-way write."""
        src, dst = self._qps[qp]
        return [(src, dst)]

    def rdma_write(self, x, qp: int, *, pod_axis: Optional[str] = None):
        """One-sided write to the peer pod (inside shard_map over `pod`)."""
        c: CollectiveConfig = self.config
        perm = self.qp_permutation(qp, 2)
        return jax.lax.ppermute(x, pod_axis or c.pod_axis, perm)

    # -- roofline estimates -------------------------------------------------------
    @staticmethod
    def wire_bytes(schedule: str, nbytes: int, data: int, pods: int,
                   pod_links: int = 1) -> Dict[str, float]:
        """Modeled per-device wire bytes for an all-reduce of `nbytes`."""
        if schedule == "flat":
            g = data * pods
            return {"intra": 2 * (g - 1) / g * nbytes, "inter": 0.0}
        rs = (data - 1) / data * nbytes
        ag = (data - 1) / data * nbytes
        inter = 2 * (pods - 1) / pods * (nbytes / data)
        return {"intra": rs + ag, "inter": inter}

    def status(self) -> Dict[str, Any]:
        s = super().status()
        s["open_qps"] = len(self._qps)
        return s
