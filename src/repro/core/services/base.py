"""Service framework: reusable, *reconfigurable* shell services (Req. 1).

A service is shell-resident infrastructure (MMU, networking, compression,
encryption, sniffer).  Unlike prior shells, services are not static: the
shell can swap a service configuration at run time (paper §4), and apps
declare the services + constraints they require so a reconfiguration can
never strand a running app (the paper's fail-safe linking rule)."""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional


class Service(abc.ABC):
    """Base class.  Subclasses define NAME and a config dataclass.

    Port API v2: ``PORT_METHODS`` is the allowlist of operations a
    :class:`repro.core.port.ServicePort` may dispatch
    (``port.submit(Invocation.call("method", ...))``); anything else
    completes with ``ok=False``.  ``port_capabilities()`` is the
    capability descriptor registered at ``Shell.attach()``.
    """

    NAME: str = "service"
    PORT_METHODS: tuple = ("status", "configure")
    PORT_CSR_MAP: dict = {}
    PORT_MEM_MODEL: str = "none"

    def __init__(self, config: Any = None):
        self.config = config
        self.generation = 0              # bumped on every reconfigure
        self.loaded_at = time.perf_counter()

    def port_capabilities(self):
        from repro.core.port import PortCapabilities
        return PortCapabilities(
            name=self.NAME, kind="service", streams=0,
            csr_map=dict(self.PORT_CSR_MAP),
            mem_model=self.PORT_MEM_MODEL, ops=tuple(self.PORT_METHODS))

    # -- lifecycle -----------------------------------------------------------
    def configure(self, config: Any) -> None:
        """Run-time reconfiguration: apply a new config in place."""
        self.config = config
        self.generation += 1

    def unload(self) -> None:
        """Release resources when the shell drops this service."""

    # -- introspection -------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        return {"name": self.NAME, "generation": self.generation,
                "config": repr(self.config)}

    def satisfies(self, constraints: Dict[str, Any]) -> bool:
        """Whether this service instance meets an app's requirements.

        Constraints match attributes on the config: {"page_size": 2048}
        requires config.page_size == 2048; {"min_page_size": 1024} requires
        config.page_size >= 1024 (min_/max_ prefixes compare)."""
        for key, want in constraints.items():
            if key.startswith("min_"):
                have = getattr(self.config, key[4:], None)
                if have is None or have < want:
                    return False
            elif key.startswith("max_"):
                have = getattr(self.config, key[4:], None)
                if have is None or have > want:
                    return False
            else:
                have = getattr(self.config, key, None)
                if have != want:
                    return False
        return True


@dataclass
class ServiceRequirement:
    """An app's declared dependency on a shell service."""
    service: str
    constraints: Dict[str, Any] = field(default_factory=dict)


class ServiceRegistry:
    """The dynamic layer's service table."""

    def __init__(self):
        self._services: Dict[str, Service] = {}

    def add(self, svc: Service) -> None:
        self._services[svc.NAME] = svc

    def remove(self, name: str) -> Optional[Service]:
        svc = self._services.pop(name, None)
        if svc is not None:
            svc.unload()
        return svc

    def get(self, name: str) -> Optional[Service]:
        return self._services.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._services

    def names(self):
        return sorted(self._services)

    def check(self, req: ServiceRequirement) -> bool:
        svc = self.get(req.service)
        return svc is not None and svc.satisfies(req.constraints)

    def status(self) -> Dict[str, Any]:
        return {n: s.status() for n, s in self._services.items()}
