"""MMU service: shared virtual memory with configurable paging (paper §6.1).

Coyote v2's MMU is "implemented in a hybrid manner: TLBs in on-chip SRAM,
the rest in the host-side driver", with parametrizable page size / TLB size /
associativity, GPU-style page-fault migration, and striping across HBM
channels.  The TPU adaptation is a *paged KV-cache manager*:

  * virtual address  = (sequence id, token position)
  * physical address = (page id, offset)       [page id -> pool slot]
  * page table       = per-sequence page list (host side, "driver")
  * TLB              = set-associative SRAM cache of hot translations
  * page fault       = pool page miss -> host callback allocates/migrates,
                       raises IRQ_PAGE_FAULT on the interrupt bus
  * striping         = pages round-robined over N channels (HBM banks)
  * huge pages       = page_size is fully parametric (the 1 GB analogue is
                       a whole-sequence page)
  * shared pages     = physical pages are REFCOUNTED: sequences with a
                       common prompt prefix map the same pages
                       (content-keyed prefix index consulted by
                       ``alloc_seq(prompt_tokens=...)``), and a write
                       translation to a shared page copy-on-writes
                       (``translate(for_write=True)``)

The device-side consumer is the paged-attention Pallas kernel
(``repro.kernels.paged_attention``), which walks ``block_table()`` output —
the hardware TLB lookup of the paper, reshaped for the MXU.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.services.base import Service

try:                                  # device view is optional: the MMU
    import jax                       # driver half works without a device
    import jax.numpy as jnp
except ImportError:                  # pragma: no cover
    jax = None
    jnp = None


@dataclass(frozen=True)
class MMUConfig:
    page_size: int = 256                 # tokens per page (parametric)
    n_pages: int = 4096                  # device pool size
    tlb_entries: int = 256
    tlb_assoc: int = 4
    n_channels: int = 8                  # striping channels (HBM banks)
    host_pool_pages: int = 16384         # host "swap" capacity
    prefix_sharing: bool = True          # content-keyed CoW page sharing


@dataclass
class PageTableEntry:
    vpage: int
    ppage: int                           # device pool slot, -1 if on host
    on_host: bool = False
    host_slot: int = -1


def _chain_hash(prev: str, block: Sequence[int]) -> str:
    """Content key of a token page, chained over the whole prefix: page
    j's hash covers tokens [0, (j+1)*page_size) — exactly the tokens the
    page's KV depends on under causal attention, so equal hash implies
    byte-equal KV for any two sequences."""
    h = hashlib.blake2b(digest_size=16)
    h.update(prev.encode("ascii"))
    h.update(np.asarray(list(block), np.int64).tobytes())
    return h.hexdigest()


@dataclass
class SeqEntry:
    seq_id: int
    length: int = 0
    pages: List[PageTableEntry] = field(default_factory=list)


class TLB:
    """Set-associative translation cache with LRU within each set."""

    def __init__(self, entries: int, assoc: int):
        assoc = max(1, min(assoc, entries))
        self.n_sets = max(1, entries // assoc)
        self.assoc = assoc
        # each set: list of (key, ppage, last_used)
        self._sets: List[List[Tuple[Tuple[int, int], int, int]]] = [
            [] for _ in range(self.n_sets)]
        self._tick = 0
        self.hits = 0
        self.misses = 0

    def _set_of(self, key: Tuple[int, int]) -> int:
        return hash(key) % self.n_sets

    def lookup(self, seq_id: int, vpage: int) -> Optional[int]:
        key = (seq_id, vpage)
        s = self._sets[self._set_of(key)]
        self._tick += 1
        for i, (k, p, _) in enumerate(s):
            if k == key:
                s[i] = (k, p, self._tick)
                self.hits += 1
                return p
        self.misses += 1
        return None

    def insert(self, seq_id: int, vpage: int, ppage: int) -> None:
        key = (seq_id, vpage)
        s = self._sets[self._set_of(key)]
        self._tick += 1
        for i, (k, _, _) in enumerate(s):
            if k == key:
                s[i] = (key, ppage, self._tick)
                return
        if len(s) >= self.assoc:
            s.remove(min(s, key=lambda e: e[2]))     # LRU evict
        s.append((key, ppage, self._tick))

    def invalidate(self, seq_id: Optional[int] = None) -> int:
        n = 0
        for s in self._sets:
            keep = [e for e in s
                    if seq_id is not None and e[0][0] != seq_id]
            n += len(s) - len(keep)
            s[:] = keep
        return n

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 1.0


class PageFaultError(Exception):
    pass


def _share_key(sid: int, p: Dict[str, Any]) -> Tuple:
    """Physical identity of a snapshotted page: snapshot entries with the
    same key were one physical page at the source and restore to one
    page at the destination.  Host pages without a recorded slot (legacy
    snapshots) are conservatively treated as private."""
    if p["on_host"]:
        hslot = int(p.get("host_slot", -1))
        return ("h", hslot) if hslot >= 0 else ("u", sid, int(p["vpage"]))
    return ("d", int(p["ppage"]))


class MMU(Service):
    """The paged-memory service.  Thread-safe; the 'driver' half."""

    NAME = "mmu"
    PORT_METHODS = ("alloc_seq", "extend_seq", "free_seq", "translate",
                    "block_table", "seq_lens", "utilization", "status",
                    "configure", "snapshot_seqs")
    PORT_MEM_MODEL = "paged"

    def __init__(self, config: Optional[MMUConfig] = None,
                 interrupt_post: Optional[Callable[[int, int], None]] = None):
        # None sentinel, NOT `config=MMUConfig()`: a dataclass default in
        # the signature is one shared instance across every default-
        # constructed MMU, so a later in-place configure() could alias
        # shells (frozen today, but the aliasing is a trap)
        super().__init__(config if config is not None else MMUConfig())
        self._lock = threading.RLock()
        self._post = interrupt_post or (lambda slot, val: None)
        # evict-with-copy pager (registered by the page-data owner, e.g.
        # the serving engine): survives reconfigure — it belongs to the
        # owner's lifetime, not the pool's
        self._pager_gather: Optional[Callable[[int], Any]] = None
        self._pager_scatter: Optional[Callable[[int, Any], None]] = None
        self._pager_owner: Any = None
        # armed FaultPlan (wired by Shell.set_fault_plan): probed at the
        # pager sites ("pager.gather"/"pager.scatter") and in force mode
        # at "mmu.page_storm" (simulated pool pressure -> real eviction
        # churn).  Survives configure() — it belongs to the shell.
        self.faults: Optional[Any] = None
        self._in_storm = False        # re-entrancy guard (storm fault-in)
        self._init_pools()

    def _init_pools(self) -> None:
        c: MMUConfig = self.config
        self.tlb = TLB(c.tlb_entries, c.tlb_assoc)
        self._free = list(range(c.n_pages - 1, -1, -1))
        self._host_free = list(range(c.host_pool_pages - 1, -1, -1))
        self._seqs: Dict[int, SeqEntry] = {}
        # per-sequence mapping version: bumped whenever a sequence's page
        # list changes (alloc/extend/evict/migrate), so cached device
        # block-table views re-upload only the rows that actually moved.
        self._map_version: Dict[int, int] = {}
        # host-resident page payloads, keyed by host slot: filled by the
        # pager's gather on evict, drained by scatter on fault-back-in
        self._host_data: Dict[int, Any] = {}
        # copy-on-write prefix sharing: physical pages are refcounted —
        # a device page (or a host slot, after eviction) may back the
        # same vpage of many sequences.  The prefix index maps a chain
        # hash of full prompt-token pages to the canonical physical page
        # holding that prefix's KV; alloc_seq() consults it.
        self._ref: Dict[int, int] = {}            # device ppage -> refs
        self._host_ref: Dict[int, int] = {}       # host slot -> refs
        self._prefix_index: Dict[str, int] = {}   # chain hash -> ppage
        self._page_hash: Dict[int, str] = {}      # ppage -> chain hash
        # pre-copy dirty tracking: physical pages whose CONTENT may have
        # changed since the last ``clear_dirty()``.  Keys match
        # ``_share_key``: ("d", ppage) for device pages, ("h", hslot)
        # for host-resident payloads.  Marked on fresh allocation, token
        # appends (``extend_seq`` tail pages), write translations, CoW
        # copies and prefill writes (``mark_dirty_range``); transferred
        # device<->host on evict/fault-in; dropped when the last
        # reference dies.  One MMU backs one paged engine (enforced by
        # ``register_pager``), so the set is per-tenant.
        self._dirty: set = set()
        self.page_faults = 0
        self.migrations_out = 0
        self.migrations_in = 0
        self.prefix_hits = 0                      # pages mapped shared
        self.cow_faults = 0                       # CoW page copies

    def _bump_map(self, seq_id: int) -> None:
        self._map_version[seq_id] = self._map_version.get(seq_id, 0) + 1

    # -- reconfiguration (paper scenario #1: swap 2 MB -> 1 GB pages) -------
    def configure(self, config: MMUConfig) -> None:
        with self._lock:
            if self._seqs:
                raise RuntimeError(
                    "MMU reconfigure with live sequences; drain first "
                    "(the shell checks app requirements before this)")
            super().configure(config)
            self._init_pools()

    # -- allocation -----------------------------------------------------------
    def alloc_seq(self, seq_id: int, n_tokens: int = 0, *, slot: int = 0,
                  prompt_tokens: Optional[Sequence[int]] = None,
                  publish: bool = True) -> int:
        """Allocate a sequence of ``n_tokens``; returns the number of
        prompt tokens whose pages were mapped SHARED (0 without sharing).

        With ``prompt_tokens`` and ``config.prefix_sharing``, every full
        page of the prompt is looked up in the content-keyed prefix
        index: a hit maps the existing physical page with
        ``refcount += 1`` instead of allocating — the caller may then
        skip prefill compute for the covered prefix entirely.  Full
        pages that miss are allocated privately and REGISTERED under
        their chain hash; the allocator owns filling them with the
        prefix's KV in the same admission pass (the serving engine's
        prefill does), which is what makes them canonical for later
        sequences.

        ``publish=False`` defers that registration: the sequence still
        CONSUMES existing shared pages, but its own pages only become
        canonical when the caller invokes :meth:`publish_prefix` — the
        contract chunked prefill needs, where page *mappings* exist at
        admission but their KV *content* lands over several later steps
        and must not be consumed by other sequences in between.
        """
        hashes: List[str] = []
        if prompt_tokens is not None and self.config.prefix_sharing:
            ps = self.config.page_size
            h = ""
            for j in range(len(prompt_tokens) // ps):
                h = _chain_hash(h, prompt_tokens[j * ps:(j + 1) * ps])
                hashes.append(h)
        covered = 0
        with self._lock:
            if seq_id in self._seqs:
                raise KeyError(f"seq {seq_id} already allocated")
            se = SeqEntry(seq_id=seq_id)
            self._seqs[seq_id] = se
            self._map_version[seq_id] = 0
            for j, h in enumerate(hashes):
                pp = self._prefix_index.get(h)
                if pp is None:
                    break
                se.pages.append(PageTableEntry(vpage=j, ppage=pp))
                self._ref[pp] = self._ref.get(pp, 0) + 1
                covered += self.config.page_size
                self.prefix_hits += 1
            if covered:
                se.length = covered
                self._bump_map(seq_id)
        if n_tokens > covered:
            self.extend_seq(seq_id, n_tokens - covered, slot=slot)
        if hashes and publish:
            self._register_prefix(seq_id, hashes,
                                  covered // self.config.page_size)
        return covered

    def _register_prefix(self, seq_id: int, hashes: List[str],
                         first_page: int) -> None:
        """Make a sequence's private full prompt pages canonical for the
        prefix index (pages before ``first_page`` were mapped shared)."""
        with self._lock:
            se = self._seqs.get(seq_id)
            for j in range(first_page, len(hashes)):
                if se is None or j >= len(se.pages):
                    break
                pte = se.pages[j]
                if (pte.on_host or pte.ppage < 0
                        or pte.ppage in self._page_hash
                        or hashes[j] in self._prefix_index):
                    continue
                self._prefix_index[hashes[j]] = pte.ppage
                self._page_hash[pte.ppage] = hashes[j]

    def publish_prefix(self, seq_id: int,
                       prompt_tokens: Sequence[int]) -> None:
        """Deferred half of ``alloc_seq(..., publish=False)``: register
        the sequence's full prompt pages in the prefix index once their
        KV content is actually resident (the serving engine calls this
        when a chunked prefill lands its final chunk).  A no-op for
        freed sequences and with sharing disabled."""
        if not self.config.prefix_sharing:
            return
        ps = self.config.page_size
        hashes: List[str] = []
        h = ""
        for j in range(len(prompt_tokens) // ps):
            h = _chain_hash(h, prompt_tokens[j * ps:(j + 1) * ps])
            hashes.append(h)
        self._register_prefix(seq_id, hashes, 0)

    def probe_prefix(self, prompt_tokens: Sequence[int]) -> int:
        """How many leading prompt tokens the prefix index would map to
        shared pages RIGHT NOW, without allocating anything — admission
        control uses this to charge a templated request only for its
        uncovered suffix."""
        if not self.config.prefix_sharing:
            return 0
        ps = self.config.page_size
        covered = 0
        h = ""
        with self._lock:
            for j in range(len(prompt_tokens) // ps):
                h = _chain_hash(h, prompt_tokens[j * ps:(j + 1) * ps])
                if h not in self._prefix_index:
                    break
                covered += ps
        return covered

    def extend_seq(self, seq_id: int, n_tokens: int, *, slot: int = 0) -> None:
        """Grow a sequence; allocates pages on demand (the page-fault path
        when the pool is exhausted triggers host eviction)."""
        c: MMUConfig = self.config
        with self._lock:
            se = self._seqs[seq_id]
            se.length += n_tokens
            need = -(-se.length // c.page_size)          # ceil
            grew = len(se.pages) < need
            while len(se.pages) < need:
                ppage = self._take_device_page(seq_id, slot)
                se.pages.append(PageTableEntry(
                    vpage=len(se.pages), ppage=ppage))
            if grew:
                self._bump_map(seq_id)
            if n_tokens > 0 and se.pages:
                # an append means the engine just wrote (or is about to
                # write) KV at the tail: the page holding position
                # old_length-1 (the token the decode step landed) and
                # the new tail page are dirty for pre-copy purposes
                lo = max(se.length - n_tokens - 1, 0) // c.page_size
                for vp in range(lo, min(need, len(se.pages))):
                    p = se.pages[vp]
                    self._dirty.add(("h", p.host_slot) if p.on_host
                                    else ("d", p.ppage))

    def _take_device_page(self, seq_id: int, slot: int) -> int:
        if (self._free and self.faults is not None and not self._in_storm
                and self.faults.force("mmu.page_storm",
                                      slot=slot) is not None):
            # page-fault storm (behavioural fault): one FULL evict-with-
            # copy round trip — a victim page gathers out to the host
            # store and immediately faults back in (fresh page, payload
            # scattered back).  Real pager churn, real IRQs and counter
            # movement, byte-identical decode: the victim row never sees
            # a host-resident (-1) block-table entry.
            victim = self._pick_victim(exclude=seq_id)
            target = None
            if victim is not None:
                target = next((p for p in
                               reversed(self._seqs[victim].pages)
                               if not p.on_host), None)
            if target is not None:
                self._in_storm = True     # the fault-in allocates through
                try:                      # us again: no recursive storms
                    self.page_faults += 1
                    self._post(slot, seq_id)             # IRQ_PAGE_FAULT
                    self._evict_seq_page(victim)
                    if target.on_host:
                        self._fault_in(victim, target, slot)
                finally:
                    self._in_storm = False
        if not self._free:
            self.page_faults += 1
            self._post(slot, seq_id)                     # IRQ_PAGE_FAULT
            victim = self._pick_victim(exclude=seq_id)
            if victim is None:
                raise PageFaultError("device page pool exhausted and no "
                                     "victim sequence to evict")
            self._evict_seq_page(victim)
            if not self._free:
                raise PageFaultError("eviction failed to free a page")
        pp = self._free.pop()
        self._ref[pp] = 1
        self._dirty.add(("d", pp))    # fresh pages carry new content
        return pp

    def _pick_victim(self, exclude: int) -> Optional[int]:
        # evict from the longest resident sequence (simple, deterministic)
        best, best_len = None, -1
        for sid, se in self._seqs.items():
            if sid == exclude:
                continue
            resident = sum(1 for p in se.pages if not p.on_host)
            if resident > best_len and resident > 0:
                best, best_len = sid, resident
        return best

    # -- evict-with-copy pager ------------------------------------------------
    def register_pager(self, gather: Callable[[int], Any],
                       scatter: Callable[[int, Any], None],
                       owner: Any = None) -> None:
        """Register the page-data mover for REAL KV migration on evict.

        ``gather(ppage)`` returns the page's payload (e.g. the serving
        engine's (n_layers, page_size, K, hd) KV slab for that physical
        page) *before* the device page is freed; ``scatter(ppage, data)``
        writes a preserved payload into a freshly allocated device page
        on fault-back-in.  Without a pager, eviction falls back to the
        old mapping-only behaviour (page contents are lost and the row
        decodes degraded until re-prefilled).

        ONE pager per MMU — and this is enforced: the pager closes over
        the single paged-pool owner, so a second distinct ``owner``
        (e.g. a second ServingEngine sharing this MMU) is refused rather
        than silently gathering/scattering through the wrong pools and
        corrupting both tenants' KV.  Give each paged engine its own MMU
        instance, or :meth:`unregister_pager` the old owner first.
        """
        with self._lock:
            if (self._pager_owner is not None and owner is not None
                    and owner is not self._pager_owner):
                raise RuntimeError(
                    "this MMU already has an evict-with-copy pager "
                    f"(owner {self._pager_owner!r}); a second paged-pool "
                    "owner on one MMU would corrupt both pools on "
                    "evict — give each engine its own MMU, or "
                    "unregister_pager() the old owner first")
            self._pager_gather = gather
            self._pager_scatter = scatter
            self._pager_owner = owner

    def unregister_pager(self, owner: Any = None) -> None:
        """Drop the pager (the owner is being torn down/replaced).
        Already-preserved host payloads stay restorable only as raw
        data; future evictions fall back to mapping-only."""
        with self._lock:
            if owner is not None and owner is not self._pager_owner:
                return                       # not yours to drop
            self._pager_gather = None
            self._pager_scatter = None
            self._pager_owner = None

    def host_page_data(self, seq_id: int, vpage: int) -> Optional[Any]:
        """The preserved payload of a host-resident page (None when the
        page is device-resident or was evicted without a pager)."""
        with self._lock:
            se = self._seqs.get(seq_id)
            if se is None or vpage >= len(se.pages):
                return None
            pte = se.pages[vpage]
            if not pte.on_host:
                return None
            return self._host_data.get(pte.host_slot)

    def _evict_seq_page(self, seq_id: int) -> None:
        se = self._seqs[seq_id]
        for pte in reversed(se.pages):                   # evict tail first
            if not pte.on_host:
                if not self._host_free:
                    raise PageFaultError("host pool exhausted")
                pp = pte.ppage
                data = None
                if self._pager_gather is not None:
                    # REAL migration: copy the page payload to the host
                    # store before the device page is recycled.  Gather
                    # runs BEFORE any pool state mutates — a failing
                    # gather (or an injected "pager.gather" fault) leaves
                    # the mapping and both pools exactly as they were.
                    if self.faults is not None:
                        self.faults.fire("pager.gather", ppage=pp)
                    data = self._pager_gather(pp)
                hslot = self._host_free.pop()
                if data is not None:
                    self._host_data[hslot] = data
                # a shared page moves for EVERY sharer at once: one host
                # slot backs the group, refcount transfers device->host
                sharers = set()
                for sid2, se2 in self._seqs.items():
                    for p2 in se2.pages:
                        if not p2.on_host and p2.ppage == pp:
                            p2.on_host = True
                            p2.host_slot = hslot
                            p2.ppage = -1
                            sharers.add(sid2)
                self._host_ref[hslot] = max(self._ref.pop(pp, 1),
                                            len(sharers))
                # dirty state follows the content to its new identity;
                # the freed device page stops being dirty either way
                if ("d", pp) in self._dirty:
                    self._dirty.add(("h", hslot))
                self._dirty.discard(("d", pp))
                self._unregister_page(pp)    # evicted pages leave the
                self._free.append(pp)        # prefix index: no new shares
                self.migrations_out += 1
                for sid2 in sharers:
                    self.tlb.invalidate(sid2)
                    self._bump_map(sid2)
                return

    def _unregister_page(self, ppage: int) -> None:
        h = self._page_hash.pop(ppage, None)
        if h is not None and self._prefix_index.get(h) == ppage:
            self._prefix_index.pop(h, None)

    def _drop_host_ref(self, hslot: int) -> None:
        """Release one reference to a host slot; the stored payload is
        dropped only when the LAST reference dies (shared pages evicted
        to host stay restorable for every surviving sharer)."""
        n = self._host_ref.get(hslot, 1) - 1
        if n <= 0:
            self._host_ref.pop(hslot, None)
            self._host_free.append(hslot)
            self._host_data.pop(hslot, None)
            self._dirty.discard(("h", hslot))
        else:
            self._host_ref[hslot] = n

    def _drop_page_ref(self, ppage: int) -> None:
        """Release one reference to a device page; recycle it into the
        free pool only at refcount 0."""
        n = self._ref.get(ppage, 1) - 1
        if n <= 0:
            self._ref.pop(ppage, None)
            self._unregister_page(ppage)
            self._free.append(ppage)
            self._dirty.discard(("d", ppage))
        else:
            self._ref[ppage] = n

    def free_seq(self, seq_id: int) -> None:
        with self._lock:
            se = self._seqs.pop(seq_id)
            self._map_version.pop(seq_id, None)
            for pte in se.pages:
                if pte.on_host:
                    self._drop_host_ref(pte.host_slot)
                else:
                    self._drop_page_ref(pte.ppage)
            n = self.tlb.invalidate(seq_id)
            if n:
                self._post(0, seq_id)                    # TLB invalidation

    # -- translation -----------------------------------------------------------
    def translate(self, seq_id: int, token_pos: int, *,
                  slot: int = 0, for_write: bool = False) -> Tuple[int, int]:
        """(seq, pos) -> (physical page, offset).  TLB first, then the
        driver walk; host-resident pages fault back in.

        ``for_write`` declares intent to MUTATE the page: a translation
        that lands on a shared page (refcount > 1) then triggers
        copy-on-write — a fresh page is allocated, the payload is copied
        device-side through the registered pager hooks, this sequence is
        remapped to the private copy and the shared page's refcount
        drops.  Other sharers keep reading the original bytes.  Write
        translations bypass the TLB fast path (a cached translation
        cannot see the refcount)."""
        c: MMUConfig = self.config
        vpage, off = divmod(token_pos, c.page_size)
        if not for_write:
            ppage = self.tlb.lookup(seq_id, vpage)
            if ppage is not None:
                return ppage, off
        with self._lock:                                 # driver walk
            se = self._seqs.get(seq_id)
            if se is None or vpage >= len(se.pages):
                raise PageFaultError(f"unmapped: seq {seq_id} page {vpage}")
            pte = se.pages[vpage]
            if pte.on_host:                              # migrate back in
                self._fault_in(seq_id, pte, slot)
            if for_write and self._ref.get(pte.ppage, 1) > 1:
                self._cow(seq_id, pte, slot)
            if for_write:
                # declared mutation: the page is dirty for pre-copy
                self._dirty.add(("d", pte.ppage))
            self.tlb.insert(seq_id, vpage, pte.ppage)
            return pte.ppage, off

    def _fault_in(self, seq_id: int, pte: PageTableEntry,
                  slot: int) -> None:
        """Bring a host-resident page back onto the device — for EVERY
        sharer of its host slot at once (they reference the same bytes;
        one fresh page serves the group, refcount transfers host->device
        and the preserved payload is drained exactly once)."""
        self.page_faults += 1
        self._post(slot, seq_id)
        hslot = pte.host_slot
        new_pp = self._take_device_page(seq_id, slot)
        try:
            data = self._host_data.get(hslot)
            if data is not None and self._pager_scatter is not None:
                if self.faults is not None:
                    self.faults.fire("pager.scatter", slot=slot,
                                     hslot=hslot)
                # restore the preserved payload into the fresh page
                self._pager_scatter(new_pp, data)
        except BaseException:
            # a failed scatter (or injected "pager.scatter" fault) must
            # not leak the fresh page or drop the preserved payload: the
            # mapping stays host-resident and a later translate retries
            self._ref.pop(new_pp, None)
            self._free.append(new_pp)
            raise
        self._host_data.pop(hslot, None)
        sharers = set()
        for sid2, se2 in self._seqs.items():
            for p2 in se2.pages:
                if p2.on_host and p2.host_slot == hslot:
                    p2.on_host = False
                    p2.host_slot = -1
                    p2.ppage = new_pp
                    sharers.add(sid2)
        self._ref[new_pp] = max(self._host_ref.pop(hslot, 1),
                                len(sharers))
        self._host_free.append(hslot)
        # content moved to the (already-dirty) fresh device page
        self._dirty.discard(("h", hslot))
        self.migrations_in += 1
        for sid2 in sharers:
            self.tlb.invalidate(sid2)
            self._bump_map(sid2)

    def _cow(self, seq_id: int, pte: PageTableEntry, slot: int) -> None:
        """Copy-on-write: detach ``seq_id``'s mapping of a shared page
        onto a private copy.  The payload is gathered BEFORE the new
        page is taken — the allocation may evict the shared page (moving
        this very mapping to host), and the pre-gathered bytes stay
        valid either way."""
        old = pte.ppage
        payload = None
        if self._pager_gather is not None:
            # before any state mutates: a failing gather (or injected
            # "pager.gather" fault) leaves the shared mapping intact
            if self.faults is not None:
                self.faults.fire("pager.gather", slot=slot, ppage=old)
            payload = self._pager_gather(old)
        new_pp = self._take_device_page(seq_id, slot)
        if pte.on_host:
            # the allocation above evicted the shared group (us included)
            # to host: release our host reference, adopt the fresh page
            self._drop_host_ref(pte.host_slot)
            pte.on_host = False
            pte.host_slot = -1
        else:
            self._drop_page_ref(old)
        pte.ppage = new_pp
        if payload is not None and self._pager_scatter is not None:
            self._pager_scatter(new_pp, payload)
        self.cow_faults += 1
        self.tlb.invalidate(seq_id)
        self._bump_map(seq_id)

    # -- device-side views ------------------------------------------------------
    def block_table(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        """(n_seqs, max_pages) int32 physical page ids, -1 padded — the
        array the paged-attention kernel walks."""
        out = np.full((len(seq_ids), max_pages), -1, np.int32)
        with self._lock:
            for i, sid in enumerate(seq_ids):
                se = self._seqs.get(sid)
                if se is None:
                    continue
                for pte in se.pages[:max_pages]:
                    out[i, pte.vpage] = -1 if pte.on_host else pte.ppage
        return out

    def seq_lens(self, seq_ids: List[int]) -> np.ndarray:
        with self._lock:
            return np.array([self._seqs[s].length if s in self._seqs else 0
                             for s in seq_ids], np.int32)

    def seq_map_version(self, seq_id: int) -> int:
        """Monotone per-sequence mapping version (-1 = not allocated).
        Changes iff the sequence's page list changed."""
        with self._lock:
            return self._map_version.get(seq_id, -1)

    def block_table_device(self, n_slots: int, max_pages: int, *,
                           sharding=None) -> "DeviceBlockTable":
        """A cached device-resident block-table view over a fixed window
        of engine slots — the steady-state decode step reads a device
        array that is already there; only rows whose mapping changed
        (alloc/extend/free/evict deltas) are re-uploaded.  ``sharding``
        (a replicated ``NamedSharding``) pins the mirror to a mesh for
        tensor-parallel engines: one logical table, every shard reads
        the same copy."""
        if jnp is None:
            raise ImportError("jax is required for MMU device block-table "
                              "views (the host-side driver works without)")
        return DeviceBlockTable(self, n_slots, max_pages, sharding=sharding)

    def channel_of(self, ppage: int) -> int:
        """Striping: which channel (HBM bank) a page lives on."""
        return ppage % self.config.n_channels

    # -- migration snapshot / restore (quiesce-and-migrate) ---------------------
    def snapshot_seqs(self, seq_ids: List[int]) -> Dict[str, Any]:
        """JSON-safe page-table snapshot of a tenant's sequences — the
        MMU half of a migration state container.  Captures lengths and
        per-page mapping state (vpage order, device ppage, host
        residency + host slot so shared pages stay groupable, and the
        prefix-index chain hash when the page is content-registered);
        page *payloads* are gathered separately by the pool owner
        (``repro.serve.paged_model.gather_kv_pages``) — ONCE per
        physical page, however many sequences share it."""
        with self._lock:
            seqs = []
            for sid in seq_ids:
                se = self._seqs[sid]
                pages = []
                for p in se.pages:
                    pd = {"vpage": int(p.vpage), "ppage": int(p.ppage),
                          "on_host": bool(p.on_host),
                          "host_slot": int(p.host_slot)}
                    h = self._page_hash.get(p.ppage) if not p.on_host \
                        else None
                    if h is not None:
                        pd["hash"] = h
                    pages.append(pd)
                seqs.append({"seq_id": int(sid), "length": int(se.length),
                             "pages": pages})
            return {"page_size": int(self.config.page_size), "seqs": seqs}

    def restore_seqs(self, snap: Dict[str, Any], *, slot: int = 0,
                     staged: Optional[Dict[Tuple, int]] = None
                     ) -> Dict[int, List[Dict[str, int]]]:
        """Rebuild snapshotted sequences on THIS MMU with fresh device
        pages (every page comes back device-resident, including pages
        that were host-evicted at the source).

        Returns ``{seq_id: [{"vpage", "old_ppage", "new_ppage",
        "was_host", "host_slot"}, ...]}`` — the page map the caller uses
        to scatter the migrated KV payload into the destination pools
        (``old_ppage`` is -1 for pages that were host-resident).
        SHARING IS PRESERVED: snapshot pages backed by the same source
        physical page (same device ppage, or same host slot) restore to
        ONE destination page with the refcount rebuilt, so a migrated
        fleet of templated tenants never explodes capacity; pages
        carrying a prefix-index chain hash are re-registered so future
        allocations on this MMU share them too.  Page-size geometry must
        match; colliding sequence ids are refused (migrating tenants
        must use disjoint id ranges, ``ServingEngine(rid_base=...)``).

        ``staged`` is the pre-copy hand-off: ``{share_key: ppage}`` for
        pages already reserved (``reserve_pages``) and filled by warm
        rounds.  A snapshot page whose source share-key appears in
        ``staged`` ADOPTS that page instead of allocating a fresh one —
        its reservation reference becomes the first mapping reference,
        so the caller must NOT also release adopted pages.
        """
        if int(snap.get("page_size", -1)) != self.config.page_size:
            raise PageFaultError(
                f"page-size mismatch: snapshot has "
                f"{snap.get('page_size')}, this MMU has "
                f"{self.config.page_size} — cannot restore page tables "
                "across page geometries")
        mapping: Dict[int, List[Dict[str, int]]] = {}
        with self._lock:
            keys = set()
            for sd in snap["seqs"]:
                sid = int(sd["seq_id"])
                if sid in self._seqs:
                    raise KeyError(
                        f"seq {sid} already allocated on the destination "
                        "MMU (sequence id collision — use disjoint "
                        "rid_base ranges per tenant)")
                for p in sd["pages"]:
                    keys.add(_share_key(sid, p))
            # demand upfront capacity for the UNIQUE page set: restoring
            # THROUGH the eviction path could evict pages allocated
            # earlier in this very restore (the returned mapping would
            # dangle) — an incoming tenant must fit, it never steals
            # resident tenants' pages
            need = len(keys if staged is None
                       else keys - set(staged.keys()))
            if need > len(self._free):
                raise PageFaultError(
                    f"destination pool has {len(self._free)} free pages "
                    f"for a {need}-page incoming tenant; migration "
                    "needs upfront capacity (free sequences or use a "
                    "larger pool)")
            new_map: Dict[Tuple[str, int], int] = {}
            for sd in snap["seqs"]:
                sid = int(sd["seq_id"])
                se = SeqEntry(seq_id=sid, length=int(sd["length"]))
                pages = []
                for p in sorted(sd["pages"], key=lambda x: x["vpage"]):
                    hslot = int(p.get("host_slot", -1))
                    key = _share_key(sid, p)
                    if key in new_map:                 # shared at source:
                        new_pp = new_map[key]          # re-share here
                        self._ref[new_pp] = self._ref.get(new_pp, 0) + 1
                    else:
                        if staged is not None and key in staged:
                            # adopt the warm-round page: its reservation
                            # ref (1) becomes this first mapping ref
                            new_pp = staged[key]
                        else:
                            new_pp = self._take_device_page(sid, slot)
                        new_map[key] = new_pp
                        h = p.get("hash")
                        if h and h not in self._prefix_index:
                            self._prefix_index[h] = new_pp
                            self._page_hash[new_pp] = h
                    se.pages.append(PageTableEntry(vpage=int(p["vpage"]),
                                                   ppage=new_pp))
                    pages.append({"vpage": int(p["vpage"]),
                                  "old_ppage": int(p["ppage"]),
                                  "new_ppage": new_pp,
                                  "was_host": bool(p["on_host"]),
                                  "host_slot": hslot})
                self._seqs[sid] = se
                self._map_version[sid] = 0
                self._bump_map(sid)
                mapping[sid] = pages
        return mapping

    # -- pre-copy dirty tracking / staging ---------------------------------------
    def mark_dirty_range(self, seq_id: int, start: int, end: int) -> None:
        """Mark the pages covering token positions ``[start, end)`` as
        dirty.  The engine calls this after landing prefill KV writes —
        those writes go straight through the pager into pages allocated
        earlier, so allocation-time marks alone could be cleared by a
        pre-copy round that runs between the alloc and the write."""
        if end <= start:
            return
        c: MMUConfig = self.config
        with self._lock:
            se = self._seqs.get(seq_id)
            if se is None:
                return
            for vp in range(start // c.page_size,
                            min(-(-end // c.page_size), len(se.pages))):
                p = se.pages[vp]
                self._dirty.add(("h", p.host_slot) if p.on_host
                                else ("d", p.ppage))

    def dirty_snapshot(self) -> set:
        """The current dirty-page key set (a copy; does NOT clear —
        pre-copy peeks first, then clears only once it commits to
        shipping this round)."""
        with self._lock:
            return set(self._dirty)

    def clear_dirty(self) -> None:
        with self._lock:
            self._dirty.clear()

    def live_page_keys(self, seq_ids: Optional[List[int]] = None) -> set:
        """Share keys (``("d", ppage)`` / ``("h", hslot)``) of every page
        currently mapped by ``seq_ids`` (default: all sequences)."""
        with self._lock:
            out = set()
            sids = self._seqs.keys() if seq_ids is None else seq_ids
            for sid in sids:
                se = self._seqs.get(sid)
                if se is None:
                    continue
                for p in se.pages:
                    if p.on_host:
                        out.add(("h", p.host_slot) if p.host_slot >= 0
                                else ("u", sid, p.vpage))
                    else:
                        out.add(("d", p.ppage))
            return out

    def reserve_pages(self, n: int) -> List[int]:
        """Take ``n`` device pages out of the free pool for pre-copy
        staging (refcount 1, no sequence mapping).  Never applies
        eviction pressure — staging must not disturb resident tenants —
        so it raises ``PageFaultError`` when the free pool is short."""
        with self._lock:
            if n > len(self._free):
                raise PageFaultError(
                    f"cannot reserve {n} staging pages: only "
                    f"{len(self._free)} free (pre-copy staging never "
                    "evicts resident tenants)")
            pps = [self._free.pop() for _ in range(n)]
            for pp in pps:
                self._ref[pp] = 1
            return pps

    def release_pages(self, ppages: List[int]) -> None:
        """Return reserved staging pages (one reference each)."""
        with self._lock:
            for pp in ppages:
                self._drop_page_ref(pp)

    def host_payload(self, hslot: int) -> Optional[Any]:
        """The preserved payload stored in a host slot (None when the
        slot was evicted without a pager)."""
        with self._lock:
            return self._host_data.get(hslot)

    # -- introspection -----------------------------------------------------------
    def utilization(self) -> Dict[str, Any]:
        with self._lock:
            c: MMUConfig = self.config
            used = c.n_pages - len(self._free)
            return {
                "pages_used": used, "pages_total": c.n_pages,
                "host_pages_used": c.host_pool_pages - len(self._host_free),
                "sequences": len(self._seqs),
                "tlb_hit_rate": self.tlb.hit_rate,
                "page_faults": self.page_faults,
                "migrations_out": self.migrations_out,
                "migrations_in": self.migrations_in,
                # CoW prefix sharing: how much physical memory the
                # refcounts are multiplying
                "pages_shared": sum(1 for r in self._ref.values() if r > 1),
                "shared_mappings": sum(r - 1 for r in self._ref.values()
                                       if r > 1),
                "prefix_hits": self.prefix_hits,
                "cow_faults": self.cow_faults,
                "dirty_pages": len(self._dirty),
            }

    def status(self) -> Dict[str, Any]:
        s = super().status()
        s.update(self.utilization())
        return s


class DeviceBlockTable:
    """Incremental device mirror of the MMU block table for a slot window.

    The serving engine binds a sequence id to each slot; ``device_view()``
    returns a (n_slots, max_pages) int32 device array, re-uploading only
    the rows whose MMU mapping version changed since the last call.  In
    steady-state decode (no page-boundary crossing, no slot churn) the
    call is a pure cache hit: zero host->device traffic.
    """

    def __init__(self, mmu: "MMU", n_slots: int, max_pages: int, *,
                 sharding=None):
        self.mmu = mmu
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.sharding = sharding          # replicated NamedSharding or None
        self._seq = [-1] * n_slots                    # slot -> seq id
        self._ver = [-2] * n_slots                    # last-seen map version
        self._host = np.full((n_slots, max_pages), -1, np.int32)
        self._dev = None
        self._stale = set(range(n_slots))
        self.row_uploads = 0                          # rows re-uploaded
        self.hits = 0                                 # pure cache hits
        self.last_updated_rows: list = []             # rows synced last view

    def bind(self, slot: int, seq_id: int) -> None:
        self._seq[slot] = seq_id
        self._ver[slot] = -2                          # force refresh
        self._stale.add(slot)

    def unbind(self, slot: int) -> None:
        self._seq[slot] = -1
        self._ver[slot] = -2
        self._host[slot] = -1
        self._stale.add(slot)

    def device_view(self):
        """(n_slots, max_pages) int32 device array, incrementally synced."""
        for i, sid in enumerate(self._seq):
            if sid < 0:
                continue
            v = self.mmu.seq_map_version(sid)
            if v != self._ver[i]:
                self._host[i] = self.mmu.block_table(
                    [sid], self.max_pages)[0]
                self._ver[i] = v
                self._stale.add(i)
        if self._dev is None:
            self._dev = (jax.device_put(self._host, self.sharding)
                         if self.sharding is not None
                         else jnp.asarray(self._host))
            self.row_uploads += self.n_slots
            self.last_updated_rows = list(range(self.n_slots))
            self._stale.clear()
        elif self._stale:
            rows = sorted(self._stale)
            self._dev = self._dev.at[jnp.asarray(rows, jnp.int32)].set(
                jnp.asarray(self._host[rows]))
            if self.sharding is not None:
                # keep the mirror pinned replicated across the mesh (the
                # scatter above follows the committed input, but be
                # explicit: the TP decode jit keys on this sharding)
                self._dev = jax.device_put(self._dev, self.sharding)
            self.row_uploads += len(rows)
            self.last_updated_rows = rows
            self._stale.clear()
        else:
            self.hits += 1
            self.last_updated_rows = []
        return self._dev
