"""Dynamic-layer services: reusable, reconfigurable shell infrastructure."""
from repro.core.services.base import Service, ServiceRegistry, ServiceRequirement
from repro.core.services.collectives import CollectiveConfig, CollectiveService
from repro.core.services.compression import CompressionConfig, GradCompression
from repro.core.services.encryption import AESConfig, AESService
from repro.core.services.mmu import MMU, MMUConfig, PageFaultError, TLB
from repro.core.services.sniffer import SnifferConfig, TrafficSniffer

__all__ = [
    "Service", "ServiceRegistry", "ServiceRequirement",
    "CollectiveConfig", "CollectiveService",
    "CompressionConfig", "GradCompression",
    "AESConfig", "AESService",
    "MMU", "MMUConfig", "PageFaultError", "TLB",
    "SnifferConfig", "TrafficSniffer",
]
