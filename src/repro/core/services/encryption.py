"""AES-128 encryption service, pure JAX (paper §9.4/9.5 workloads).

The paper uses AES two ways: as a shell *service* (encryption cores for the
RDMA stack) and as the multi-tenant / multi-threaded macro-benchmark.  This
module is the core math; ``repro.apps.aes`` wraps it as a vFPGA app.

Implementation notes (TPU-minded):
  * the state is uint8 (..., 16), column-major like FIPS-197;
  * SubBytes is a 256-entry table gather (VMEM-resident on TPU);
  * MixColumns is xtime GF(2^8) arithmetic — shifts/xors, fully vectorised;
  * ECB vmaps over blocks (embarrassingly parallel);
  * CBC chains blocks with lax.scan — the sequential-dependence pipeline
    the paper fills with cThreads (Fig 9/10): vmapping the scan over
    independent streams is exactly the multi-threading claim.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.services.base import Service

# ----------------------------------------------------------- tables -------
_SBOX_NP = np.array([
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b,
    0xfe, 0xd7, 0xab, 0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0,
    0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26,
    0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2,
    0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0,
    0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed,
    0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f,
    0x50, 0x3c, 0x9f, 0xa8, 0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5,
    0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec,
    0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14,
    0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c,
    0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d,
    0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f,
    0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e,
    0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e, 0xe1, 0xf8, 0x98, 0x11,
    0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f,
    0xb0, 0x54, 0xbb, 0x16], dtype=np.uint8)

_RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b,
                  0x36], dtype=np.uint8)

# ShiftRows permutation for flat column-major state: new[r+4c]=old[r+4((c+r)%4)]
_SHIFT_IDX = np.array([(r + 4 * ((c + r) % 4)) for c in range(4)
                       for r in range(4)], dtype=np.int32)
# flat index helper: position p = r + 4c -> r = p % 4, c = p // 4
_SHIFT_IDX = np.array([(p % 4) + 4 * (((p // 4) + (p % 4)) % 4)
                       for p in range(16)], dtype=np.int32)


def expand_key(key: np.ndarray) -> np.ndarray:
    """key (16,) uint8 -> round keys (11, 16) uint8 (host-side, numpy)."""
    assert key.shape == (16,) and key.dtype == np.uint8
    w = [key[4 * i:4 * i + 4].copy() for i in range(4)]
    for i in range(4, 44):
        t = w[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = _SBOX_NP[t]
            t[0] ^= _RCON[i // 4 - 1]
        w.append(w[i - 4] ^ t)
    return np.concatenate(w).reshape(11, 16)


def _xtime(a):
    return ((a << 1) ^ ((a >> 7) * 0x1B)).astype(jnp.uint8)


def _mix_columns(s):
    """s (..., 16) flat column-major."""
    cols = s.reshape(s.shape[:-1] + (4, 4))           # (..., col, row)
    a0, a1, a2, a3 = (cols[..., 0], cols[..., 1], cols[..., 2], cols[..., 3])
    x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)


def encrypt_block(state, round_keys):
    """AES-128 on uint8 state (..., 16); round_keys (11, 16) uint8."""
    sbox = jnp.asarray(_SBOX_NP)
    shift = jnp.asarray(_SHIFT_IDX)
    s = state ^ round_keys[0]
    for rnd in range(1, 10):
        s = jnp.take(sbox, s.astype(jnp.int32), axis=0)   # SubBytes
        s = jnp.take(s, shift, axis=-1)                   # ShiftRows
        s = _mix_columns(s)                               # MixColumns
        s = s ^ round_keys[rnd]
    s = jnp.take(sbox, s.astype(jnp.int32), axis=0)
    s = jnp.take(s, shift, axis=-1)
    return s ^ round_keys[10]


@functools.partial(jax.jit, static_argnames=())
def aes_ecb(blocks, round_keys):
    """ECB: blocks (N, 16) uint8 — embarrassingly parallel."""
    return encrypt_block(blocks, round_keys)


@jax.jit
def aes_cbc(blocks, iv, round_keys):
    """CBC over one stream: blocks (N, 16); iv (16,).  Sequential chain —
    the pipeline-stall workload of paper Fig 9."""
    def step(prev_ct, pt):
        ct = encrypt_block(pt ^ prev_ct, round_keys)
        return ct, ct
    _, cts = jax.lax.scan(step, iv, blocks)
    return cts


@jax.jit
def aes_cbc_multistream(blocks, ivs, round_keys):
    """CBC over T independent streams: blocks (T, N, 16); ivs (T, 16).

    The vmap over streams is the cThread multithreading of Fig 10b: each
    scan step now carries T blocks through the 10-stage pipeline instead of
    one, eliminating the data-dependence bubbles."""
    return jax.vmap(lambda b, iv: aes_cbc(b, iv, round_keys))(blocks, ivs)


def bytes_to_blocks(data: np.ndarray) -> np.ndarray:
    flat = np.frombuffer(data.tobytes(), dtype=np.uint8)
    pad = (-flat.size) % 16
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    return flat.reshape(-1, 16)


@dataclass(frozen=True)
class AESConfig:
    key_hex: str = "000102030405060708090a0b0c0d0e0f"
    mode: str = "ecb"             # ecb | cbc


class AESService(Service):
    """Encryption as a reusable shell service (e.g. on the RDMA datapath)."""

    NAME = "encryption"
    PORT_METHODS = ("encrypt", "status", "configure")
    PORT_MEM_MODEL = "host"

    def __init__(self, config: Optional[AESConfig] = None):
        if config is None:
            config = AESConfig()
        super().__init__(config)
        self._set_key(config.key_hex)

    def _set_key(self, key_hex: str) -> None:
        key = np.frombuffer(bytes.fromhex(key_hex), dtype=np.uint8).copy()
        self.round_keys = jnp.asarray(expand_key(key))

    def configure(self, config: AESConfig) -> None:
        super().configure(config)
        self._set_key(config.key_hex)

    def encrypt(self, blocks, iv=None):
        if self.config.mode == "ecb":
            return aes_ecb(blocks, self.round_keys)
        if iv is None:
            iv = jnp.zeros((16,), jnp.uint8)
        if blocks.ndim == 3:
            return aes_cbc_multistream(blocks, iv, self.round_keys)
        return aes_cbc(blocks, iv, self.round_keys)
