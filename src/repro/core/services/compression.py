"""Gradient-compression service (paper Req. 1 names "compression cores").

Distributed-optimization trick for 1000+-node DP: gradients crossing the
slow (inter-pod) links are quantized to int8 with per-block scales and an
error-feedback accumulator, optionally top-k sparsified.  The service is
reconfigurable at run time (swap bits / block / top-k without touching the
apps), and the trainer consumes it as ``apply(grads, state)``.

All math is pure-jnp + jit so it fuses into the train step.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.services.base import Service


@dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8                 # 8 -> int8 quantization
    block: int = 256              # elements per scale block
    error_feedback: bool = True
    topk_frac: float = 0.0        # 0 -> dense; 0.01 -> keep top 1%


def _quantize_blockwise(x: jnp.ndarray, block: int, bits: int):
    """x (flat,) fp32 -> (q int8, scales fp32 (nblocks,))."""
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    xb = x.reshape(-1, block)
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale[:, 0], n


def _dequantize_blockwise(q, scale, n: int):
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n]


def _topk_mask(x: jnp.ndarray, frac: float):
    k = max(int(x.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(x), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


class GradCompression(Service):
    NAME = "compression"
    PORT_METHODS = ("init_state", "compress_leaf", "decompress_leaf",
                    "apply", "ratio_metrics", "status", "configure")
    PORT_MEM_MODEL = "device"

    def __init__(self, config: Optional[CompressionConfig] = None):
        super().__init__(config if config is not None
                         else CompressionConfig())
        self._apply_jit = None

    def init_state(self, params) -> Any:
        """Error-feedback residuals, one per leaf (zeros)."""
        if not self.config.error_feedback:
            return None
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress_leaf(self, g: jnp.ndarray):
        c: CompressionConfig = self.config
        flat = g.astype(jnp.float32).reshape(-1)
        if c.topk_frac > 0:
            flat = _topk_mask(flat, c.topk_frac)
        q, scale, n = _quantize_blockwise(flat, c.block, c.bits)
        return {"q": q, "scale": scale, "n": n, "shape": g.shape}

    def decompress_leaf(self, payload) -> jnp.ndarray:
        x = _dequantize_blockwise(payload["q"], payload["scale"],
                                  payload["n"])
        return x.reshape(payload["shape"])

    def apply(self, grads, state):
        """Quantize->dequantize every gradient leaf with error feedback —
        exactly what arrives after a compressed all-reduce.  Returns
        (grads_hat, new_state, metrics)."""
        c: CompressionConfig = self.config

        def one(g, e):
            gf = g.astype(jnp.float32)
            if e is not None:
                gf = gf + e
            flat = gf.reshape(-1)
            if c.topk_frac > 0:
                flat = _topk_mask(flat, c.topk_frac)
            q, scale, n = _quantize_blockwise(flat, c.block, c.bits)
            ghat = _dequantize_blockwise(q, scale, n).reshape(g.shape)
            new_e = (gf - ghat) if e is not None else None
            return ghat.astype(g.dtype), new_e

        if state is None:
            outs = jax.tree.map(lambda g: one(g, None)[0], grads)
            return outs, None, self.ratio_metrics(grads)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(state)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        ghat = jax.tree.unflatten(treedef, [p[0] for p in pairs])
        new_state = jax.tree.unflatten(treedef, [p[1] for p in pairs])
        return ghat, new_state, self.ratio_metrics(grads)

    def ratio_metrics(self, grads) -> Dict[str, float]:
        c: CompressionConfig = self.config
        raw = sum(g.size * 4 for g in jax.tree.leaves(grads))
        comp = sum(g.size * c.bits // 8 + (g.size // c.block + 1) * 4
                   for g in jax.tree.leaves(grads))
        if c.topk_frac > 0:
            comp = int(comp * c.topk_frac) + raw // 8  # indices bitmap
        return {"bytes_raw": float(raw), "bytes_compressed": float(comp),
                "compression_ratio": raw / max(comp, 1)}
