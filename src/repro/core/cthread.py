"""cThreads: software threads multiplexed onto one vFPGA pipeline (§7.3).

Mirrors the paper's Code 1 API: ``getMem`` (huge-page host allocation that
registers with the address map / TLB), ``setCSR``/``getCSR``, and
``invoke`` submitting scatter-gather work to the slot's send queues.  Many
cThreads share one vFPGA; the TID keeps their data apart on the parallel
streams, which is what fills the pipeline bubbles of sequential workloads
(AES-CBC, LLM decode — Fig 9/10).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.interfaces import Completion, Oper, SgEntry
from repro.core.vfpga import VFpga

_tid_counter = itertools.count()


class Alloc(Enum):
    REG = "regular"
    THP = "transparent_huge"
    HPF = "huge_page"         # 2 MB / 1 GB huge pages in the paper


@dataclass
class MemHandle:
    vaddr: int
    array: np.ndarray
    kind: Alloc


class CThread:
    """A Coyote thread bound to one vFPGA slot."""

    def __init__(self, vfpga: VFpga, pid: int, tid: Optional[int] = None):
        self.vfpga = vfpga
        self.pid = pid
        self.tid = next(_tid_counter) if tid is None else tid
        self._mem: Dict[int, MemHandle] = {}
        self._pending: Dict[int, float] = {}

    # -- memory (Code 1: getMem({Alloc::HPF, 4096})) ---------------------------
    def getMem(self, spec: Tuple[Alloc, int]) -> np.ndarray:
        kind, nbytes = spec
        # huge-page allocations are alignment-padded (2 MB analogue)
        align = (2 << 20) if kind == Alloc.HPF else 4096
        padded = -(-nbytes // align) * align if kind == Alloc.HPF else nbytes
        buf = np.zeros(max(padded, nbytes), dtype=np.uint8)[:nbytes]
        vaddr = self.vfpga.register_buffer(buf)
        self._mem[vaddr] = MemHandle(vaddr=vaddr, array=buf, kind=kind)
        return buf

    def freeMem(self, buf: np.ndarray) -> None:
        for vaddr, h in list(self._mem.items()):
            if h.array is buf:
                del self._mem[vaddr]
                return

    def vaddr_of(self, buf: np.ndarray) -> int:
        for vaddr, h in self._mem.items():
            if h.array is buf:
                return vaddr
        raise KeyError("buffer not allocated by this cThread")

    # -- control registers --------------------------------------------------------
    def setCSR(self, value: int, reg: int) -> None:
        self.vfpga.iface.csr.set_csr(value, reg)

    def getCSR(self, reg: int) -> int:
        return self.vfpga.iface.csr.get_csr(reg)

    # -- invocation ------------------------------------------------------------------
    @property
    def port(self):
        """The slot's unified Port (the v2 submission surface)."""
        return self.vfpga.attach_port()

    def invoke(self, oper: Oper, sg: SgEntry, *,
               wait: bool = True,
               timeout: Optional[float] = None) -> Optional[Completion]:
        """Deprecated shim over ``port.submit`` (Port API v2).

        Builds an :class:`~repro.core.port.Invocation` from the SG entry
        and routes it through the slot's port — the scheduler still
        batches, credits, and arbitrates, and completions still land on
        the legacy completion queues.  New code should call
        ``shell.attach(slot).submit(...)`` directly and keep the future.
        """
        from repro.core.port import Invocation
        sg.opcode = oper
        sg.tid = self.tid
        fut = self.port.submit(Invocation.from_sg(sg))
        self._pending[fut.ticket] = time.perf_counter()
        if not wait:
            return None
        comp = fut.completion(timeout=timeout)
        self._pending.pop(fut.ticket, None)
        return comp

    # -- interrupts --------------------------------------------------------------------
    def poll_interrupt(self, timeout: Optional[float] = None) -> Optional[int]:
        return self.vfpga.iface.irq.poll(timeout=timeout)

    def on_interrupt(self, cb) -> None:
        self.vfpga.iface.irq.on_interrupt(cb)
