"""cThreads: software threads multiplexed onto one vFPGA pipeline (§7.3).

Mirrors the paper's Code 1 API: ``getMem`` (huge-page host allocation that
registers with the address map / TLB), ``setCSR``/``getCSR``, and
``invoke`` submitting scatter-gather work to the slot's send queues.  Many
cThreads share one vFPGA; the TID keeps their data apart on the parallel
streams, which is what fills the pipeline bubbles of sequential workloads
(AES-CBC, LLM decode — Fig 9/10).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.interfaces import Completion, Oper, SgEntry
from repro.core.vfpga import VFpga

_tid_counter = itertools.count()


class Alloc(Enum):
    REG = "regular"
    THP = "transparent_huge"
    HPF = "huge_page"         # 2 MB / 1 GB huge pages in the paper


@dataclass
class MemHandle:
    vaddr: int
    array: np.ndarray
    kind: Alloc


class CThread:
    """A Coyote thread bound to one vFPGA slot."""

    def __init__(self, vfpga: VFpga, pid: int, tid: Optional[int] = None):
        self.vfpga = vfpga
        self.pid = pid
        self.tid = next(_tid_counter) if tid is None else tid
        self._mem: Dict[int, MemHandle] = {}
        self._pending: Dict[int, float] = {}

    # -- memory (Code 1: getMem({Alloc::HPF, 4096})) ---------------------------
    def getMem(self, spec: Tuple[Alloc, int]) -> np.ndarray:
        kind, nbytes = spec
        # huge-page allocations are alignment-padded (2 MB analogue)
        align = (2 << 20) if kind == Alloc.HPF else 4096
        padded = -(-nbytes // align) * align if kind == Alloc.HPF else nbytes
        buf = np.zeros(max(padded, nbytes), dtype=np.uint8)[:nbytes]
        vaddr = self.vfpga.register_buffer(buf)
        self._mem[vaddr] = MemHandle(vaddr=vaddr, array=buf, kind=kind)
        return buf

    def freeMem(self, buf: np.ndarray) -> None:
        for vaddr, h in list(self._mem.items()):
            if h.array is buf:
                del self._mem[vaddr]
                return

    def vaddr_of(self, buf: np.ndarray) -> int:
        for vaddr, h in self._mem.items():
            if h.array is buf:
                return vaddr
        raise KeyError("buffer not allocated by this cThread")

    # -- control registers --------------------------------------------------------
    def setCSR(self, value: int, reg: int) -> None:
        self.vfpga.iface.csr.set_csr(value, reg)

    def getCSR(self, reg: int) -> int:
        return self.vfpga.iface.csr.get_csr(reg)

    # -- invocation ------------------------------------------------------------------
    def invoke(self, oper: Oper, sg: SgEntry, *,
               wait: bool = True,
               timeout: Optional[float] = None) -> Optional[Completion]:
        sg.opcode = oper
        sg.tid = self.tid
        sq = (self.vfpga.iface.sq_write
              if oper in (Oper.LOCAL_OFFLOAD, Oper.REMOTE_WRITE)
              else self.vfpga.iface.sq_read)
        ticket = sq.submit(sg)
        self._pending[ticket] = time.perf_counter()
        # In the full shell, kick hands the entry to the async scheduler
        # (batching + weighted credits + arbiter on its own thread) and the
        # completion queue provides synchronization; standalone slots
        # execute inline.
        shell = getattr(self.vfpga, "shell", None)
        if shell is not None:
            shell.kick(self.vfpga.slot)
        else:
            item = sq.pop(timeout=0)
            if item is not None:
                t, s = item
                comp = self.vfpga.execute_sg(t, s)
                cq = (self.vfpga.iface.cq_write
                      if oper in (Oper.LOCAL_OFFLOAD, Oper.REMOTE_WRITE)
                      else self.vfpga.iface.cq_read)
                cq.complete(comp)
        if not wait:
            return None
        cq = (self.vfpga.iface.cq_write
              if oper in (Oper.LOCAL_OFFLOAD, Oper.REMOTE_WRITE)
              else self.vfpga.iface.cq_read)
        comp = cq.wait(ticket, timeout=timeout)
        self._pending.pop(ticket, None)
        return comp

    # -- interrupts --------------------------------------------------------------------
    def poll_interrupt(self, timeout: Optional[float] = None) -> Optional[int]:
        return self.vfpga.iface.irq.poll(timeout=timeout)

    def on_interrupt(self, cb) -> None:
        self.vfpga.iface.irq.on_interrupt(cb)
