"""Application layer: vFPGA slots (paper §7).

A :class:`VFpga` is one reconfigurable slot holding arbitrary user logic
behind the unified interface.  Slots are untrusted: each gets an HBM budget
(the floor-planning constraint of partial reconfiguration mapped to memory),
per-slot credit accounts, and its requests are checked against the shell's
services before load — the fail-safe that keeps a running app from losing a
service it depends on (paper §4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.interfaces import (AppInterface, Completion, Oper, SgEntry)
from repro.core.services.base import ServiceRegistry, ServiceRequirement
from repro.core.static_layer import IRQ_USER, StaticLayer


class SlotState(Enum):
    EMPTY = "empty"
    LOADED = "loaded"
    RUNNING = "running"


@dataclass
class AppArtifact:
    """A 'partial bitstream': everything needed to (re)configure a slot.

    ``fn`` is the user logic — a host callable ``fn(iface, vfpga, **invoke
    kwargs)`` for streaming apps, or a pure JAX function when
    ``abstract_args`` is provided (then it is jit-compiled through the
    static layer's compile cache and invoked with device arrays).

    ``capabilities`` is the Port API v2 capability descriptor
    (:class:`repro.core.port.PortCapabilities`): streams, CSR map and
    memory model, registered with the shell at ``Shell.attach()``."""
    name: str
    fn: Callable
    version: str = "0"
    weights: Any = None
    requires: List[ServiceRequirement] = field(default_factory=list)
    abstract_args: Optional[Tuple[Any, ...]] = None
    in_shardings: Any = None
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()
    config_repr: Any = None
    capabilities: Any = None               # Optional[PortCapabilities]

    def weight_bytes(self) -> int:
        if self.weights is None:
            return 0
        return sum(x.nbytes for x in jax.tree.leaves(self.weights))


class LinkError(RuntimeError):
    pass


class VFpga:
    """One application slot."""

    def __init__(self, slot: int, static: StaticLayer, *,
                 n_streams: int = 4, hbm_budget: int = 1 << 32):
        self.slot = slot
        self.static = static
        self.iface = AppInterface.create(n_streams=n_streams)
        self.state = SlotState.EMPTY
        self.app: Optional[AppArtifact] = None
        self.compiled: Optional[Any] = None
        self.device_weights: Any = None
        self.hbm_budget = hbm_budget
        self.hbm_used = 0
        self.load_history: List[Tuple[str, float]] = []
        self.tenant: Optional[str] = None   # QoS principal (shell scheduler)
        self.preemptions = 0                # checkpoint yields taken here
        self._addr_map: Dict[int, np.ndarray] = {}   # cThread buffers
        self._next_vaddr = 0x1000
        self._port = None                   # lazily-created unified port
        static.interrupts.register(slot, self.iface.irq)

    # -- unified port (Port API v2) ---------------------------------------------
    def attach_port(self):
        """The slot's unified typed interface (one per slot, lazily
        created).  Registered with the owning shell's port table when one
        exists, so capability descriptors surface in ``Shell.status()``."""
        if self._port is None:
            from repro.core.port import VFpgaPort
            self._port = VFpgaPort(self)
        shell = getattr(self, "shell", None)
        if shell is not None:
            shell._register_port(self._port)
        return self._port

    # -- cooperative preemption (executor lanes) --------------------------------
    def checkpoint(self) -> int:
        """Preemption point for long-running user logic: call between
        natural units of work (a decode step, one stream batch).  If
        strictly-higher-priority granted work waits on this slot's
        executor lane it runs now, on this thread, and this invocation
        resumes afterwards (hold-and-resume).  Returns the number of
        preempting batches run; 0 outside a lane or with lanes off."""
        shell = getattr(self, "shell", None)
        if shell is None:
            return 0
        ran = shell.scheduler.checkpoint(self.slot)
        self.preemptions += ran
        return ran

    def preempt_requested(self) -> bool:
        """Cheap probe: does higher-priority work wait on this slot's
        lane?  Lets logic choose a cheaper checkpoint cadence."""
        shell = getattr(self, "shell", None)
        return (shell is not None
                and shell.scheduler.preempt_requested(self.slot))

    # -- partial reconfiguration ------------------------------------------------
    def check_link(self, artifact: AppArtifact,
                   services: ServiceRegistry) -> None:
        """The linking rule: every required service must be present and
        satisfy the app's constraints (paper §4 fail-safe)."""
        for req in artifact.requires:
            if not services.check(req):
                raise LinkError(
                    f"app {artifact.name!r} requires service "
                    f"{req.service!r} with {req.constraints}; shell does "
                    f"not provide it")
        if artifact.weight_bytes() > self.hbm_budget:
            raise LinkError(
                f"app {artifact.name!r} weights ({artifact.weight_bytes()}"
                f" B) exceed slot {self.slot} HBM budget {self.hbm_budget}")

    def load(self, artifact: AppArtifact, services: ServiceRegistry,
             mesh=None) -> Dict[str, float]:
        """Reconfigure this slot: link-check, migrate weights, compile (or
        cache-hit) the executable.  Other slots keep running."""
        t0 = time.perf_counter()
        self.check_link(artifact, services)
        self.unload()
        t_mig = 0.0
        if artifact.weights is not None:
            m0 = time.perf_counter()
            self.device_weights, _ = self.static.engine.migrate_tree(
                artifact.weights)
            t_mig = time.perf_counter() - m0
            self.hbm_used = artifact.weight_bytes()
        t_comp = 0.0
        hit = True
        if artifact.abstract_args is not None:
            key = self.static.compile_cache.make_key(
                artifact.name, artifact.config_repr, mesh,
                artifact.abstract_args)

            def build():
                b0 = time.perf_counter()
                jitted = jax.jit(artifact.fn,
                                 in_shardings=artifact.in_shardings,
                                 out_shardings=artifact.out_shardings,
                                 donate_argnums=artifact.donate_argnums)
                lowered = jitted.lower(*artifact.abstract_args)
                b1 = time.perf_counter()
                compiled = lowered.compile()
                b2 = time.perf_counter()
                return compiled, b1 - b0, b2 - b1

            c0 = time.perf_counter()
            entry, hit = self.static.compile_cache.get_or_build(key, build)
            t_comp = time.perf_counter() - c0
            self.compiled = entry.compiled
        self.app = artifact
        self.state = SlotState.LOADED
        self.load_history.append((artifact.name, time.perf_counter()))
        return {"total_s": time.perf_counter() - t0, "migrate_s": t_mig,
                "compile_s": t_comp, "compile_cache_hit": float(hit)}

    def unload(self) -> None:
        # a serving engine bound to this slot dies with the logic: drop
        # it from the shell registry and release its MMU pager so the
        # replacement app can register its own pool owner
        shell = getattr(self, "shell", None)
        if shell is not None:
            eng = shell.engines.pop(self.slot, None)
            if eng is not None:
                eng.mmu.unregister_pager(eng)
        self.app = None
        self.compiled = None
        self.device_weights = None
        self.hbm_used = 0
        self.state = SlotState.EMPTY

    # -- execution ------------------------------------------------------------------
    def invoke_kernel(self, *args) -> Any:
        """Direct kernel launch (compiled JAX app)."""
        if self.compiled is not None:
            return self.compiled(*args)
        if self.app is None:
            raise RuntimeError(f"slot {self.slot} is empty")
        self.state = SlotState.RUNNING
        try:
            return self.app.fn(self.iface, self, *args)
        finally:
            self.state = SlotState.LOADED

    def execute_sg(self, ticket: int, sg: SgEntry) -> Completion:
        """Process one scatter-gather descriptor (the DMA datapath)."""
        t0 = time.perf_counter()
        result = None
        ok = True
        try:
            if sg.opcode in (Oper.LOCAL_TRANSFER, Oper.KERNEL):
                src = self.resolve(sg.src)
                result = self.invoke_kernel(src) if self.app else src
                if sg.dst is not None:
                    dst = self.resolve(sg.dst)
                    out = np.asarray(result).view(dst.dtype)[:dst.size]
                    dst.flat[:out.size] = out.reshape(-1)[:dst.size]
            elif sg.opcode == Oper.LOCAL_OFFLOAD:
                result, _ = self.static.engine.upload(
                    np.asarray(self.resolve(sg.src)))
            elif sg.opcode == Oper.LOCAL_SYNC:
                result, _ = self.static.engine.download(sg.src)
            else:
                raise NotImplementedError(sg.opcode)
        except Exception as e:   # noqa: BLE001 — fault -> interrupt, not crash
            ok = False
            result = e
            self.static.interrupts.post(self.slot, IRQ_USER, 0xDEAD)
        return Completion(ticket=ticket, tid=sg.tid, opcode=sg.opcode,
                          nbytes=sg.length, t_submit=t0,
                          t_done=time.perf_counter(), ok=ok, result=result)

    # -- cThread buffer registry (getMem-backed address map) --------------------------
    def register_buffer(self, buf: np.ndarray) -> int:
        vaddr = self._next_vaddr
        self._next_vaddr += max(buf.nbytes, 4096)
        self._addr_map[vaddr] = buf
        return vaddr

    def resolve(self, ref) -> Any:
        if isinstance(ref, int) and ref in self._addr_map:
            return self._addr_map[ref]
        return ref

    def status(self) -> Dict[str, Any]:
        return {"slot": self.slot, "state": self.state.value,
                "app": self.app.name if self.app else None,
                "tenant": self.tenant,
                "preemptions": self.preemptions,
                "hbm_used": self.hbm_used, "hbm_budget": self.hbm_budget,
                **self.iface.stats()}
