"""Unified generic application interface (paper §7.1, Fig 5).

Every vFPGA slot gets the same interface bundle, mirroring Coyote v2's
AXI-based spec mapped onto host-framework constructs:

  * control bus        -> :class:`ControlRegisters` (CSR map, user-space)
  * interrupt channel  -> :class:`InterruptQueue` (eventfd-style callbacks)
  * parallel host/card/net streams -> :class:`StreamEndpoint` xN, TID-tagged
  * read/write send queues + completion queues -> :class:`SendQueue`,
    :class:`CompletionQueue` (HW-initiated DMA without host involvement)

Streams carry numpy/JAX arrays split into packets by the credit layer; the
TID field (AXI TID analogue) keeps cThreads apart on shared pipelines.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Tuple


class Oper(Enum):
    LOCAL_TRANSFER = "local_transfer"    # host <-> vFPGA stream
    LOCAL_OFFLOAD = "local_offload"      # host -> card memory
    LOCAL_SYNC = "local_sync"            # card memory -> host
    REMOTE_WRITE = "remote_write"        # RDMA write
    REMOTE_READ = "remote_read"          # RDMA read
    KERNEL = "kernel"                    # invoke compute, streams pre-wired


@dataclass
class SgEntry:
    """Scatter-gather descriptor (paper Code 1)."""
    src: Any = None                      # array or buffer handle
    dst: Any = None
    length: int = 0
    src_stream: int = 0
    dst_stream: int = 0
    tid: int = 0                         # cThread id (AXI TID)
    opcode: Oper = Oper.LOCAL_TRANSFER
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Completion:
    ticket: int
    tid: int
    opcode: Oper
    nbytes: int
    t_submit: float
    t_done: float
    ok: bool = True
    result: Any = None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class ControlRegisters:
    """Memory-mapped CSR analogue: user-space get/set with change hooks."""

    def __init__(self):
        self._regs: Dict[int, int] = {}
        self._hooks: Dict[int, List[Callable[[int], None]]] = {}
        self._lock = threading.Lock()

    def set_csr(self, value: int, reg: int) -> None:
        with self._lock:
            self._regs[reg] = value
            hooks = list(self._hooks.get(reg, ()))
        for h in hooks:
            h(value)

    def get_csr(self, reg: int, default: int = 0) -> int:
        with self._lock:
            return self._regs.get(reg, default)

    def on_write(self, reg: int, hook: Callable[[int], None]) -> None:
        with self._lock:
            self._hooks.setdefault(reg, []).append(hook)

    def snapshot(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._regs)


class InterruptQueue:
    """User interrupts: hardware raises arbitrary values; host polls via an
    eventfd-style queue or registers a callback (paper §7.1)."""

    def __init__(self):
        self._q: "queue.Queue[Tuple[int, float]]" = queue.Queue()
        self._callbacks: List[Callable[[int], None]] = []
        self.raised = 0

    def raise_irq(self, value: int) -> None:
        self.raised += 1
        self._q.put((value, time.perf_counter()))
        for cb in list(self._callbacks):
            cb(value)

    def poll(self, timeout: Optional[float] = None) -> Optional[int]:
        try:
            v, _ = self._q.get(timeout=timeout)
            return v
        except queue.Empty:
            return None

    def on_interrupt(self, cb: Callable[[int], None]) -> None:
        self._callbacks.append(cb)

    def pending(self) -> int:
        return self._q.qsize()


class StreamKind(Enum):
    HOST = "host"
    CARD = "card"
    NET = "net"


@dataclass
class Packet:
    tid: int
    seq_no: int
    payload: Any                         # ndarray view / bytes
    nbytes: int
    last: bool
    stream_id: int = 0
    src: str = ""
    dst: str = ""


class StreamEndpoint:
    """One parallel AXI-stream analogue.  FIFO of packets, TID-tagged."""

    def __init__(self, kind: StreamKind, stream_id: int, depth: int = 64):
        self.kind = kind
        self.stream_id = stream_id
        self.depth = depth
        self._q: "queue.Queue[Packet]" = queue.Queue(maxsize=depth)
        self.bytes_in = 0
        self.bytes_out = 0

    def push(self, pkt: Packet, timeout: Optional[float] = None) -> bool:
        try:
            self._q.put(pkt, timeout=timeout)
            self.bytes_in += pkt.nbytes
            return True
        except queue.Full:
            return False

    def pop(self, timeout: Optional[float] = None) -> Optional[Packet]:
        try:
            pkt = self._q.get(timeout=timeout)
            self.bytes_out += pkt.nbytes
            return pkt
        except queue.Empty:
            return None

    def free_slots(self) -> int:
        return self.depth - self._q.qsize()

    def __len__(self):
        return self._q.qsize()


class SendQueue:
    """HW-initiated DMA request queue (read/write send queues, Fig 5)."""

    def __init__(self):
        self._q: "queue.Queue[Tuple[int, SgEntry]]" = queue.Queue()
        self._ticket = itertools.count()

    def submit(self, sg: SgEntry) -> int:
        t = next(self._ticket)
        self._q.put((t, sg))
        return t

    def pop(self, timeout: Optional[float] = None):
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def __len__(self):
        return self._q.qsize()


class CompletionQueue:
    """Completion records + host-visible writeback counter (paper §5.1:
    'writeback mechanism enables efficient completion tracking by updating
    host memory counters when transfers finish')."""

    def __init__(self):
        self._q: "queue.Queue[Completion]" = queue.Queue()
        self.writeback_counter = 0       # host-mapped counter analogue
        self._by_ticket: Dict[int, Completion] = {}
        self._lock = threading.Lock()

    def complete(self, c: Completion) -> None:
        with self._lock:
            self.writeback_counter += 1
            self._by_ticket[c.ticket] = c
        self._q.put(c)

    def writeback(self, c: Completion) -> None:
        """Record a completion in the host-visible writeback counter
        WITHOUT retaining the record.  Port-mediated submissions use
        this: their synchronization object is the PortFuture, so parking
        the Completion in the queue as well would leak one record per
        invocation (nothing ever ``wait()``s for it) and its ticket (a
        per-port counter) could shadow a SendQueue ticket for legacy
        ``wait(ticket)`` callers on the same queue."""
        with self._lock:
            self.writeback_counter += 1

    def wait(self, ticket: Optional[int] = None,
             timeout: Optional[float] = None) -> Optional[Completion]:
        if ticket is None:
            try:
                return self._q.get(timeout=timeout)
            except queue.Empty:
                return None
        deadline = None if timeout is None else time.perf_counter() + timeout
        while True:
            with self._lock:
                if ticket in self._by_ticket:
                    return self._by_ticket.pop(ticket)
            try:
                remaining = (None if deadline is None
                             else max(deadline - time.perf_counter(), 0.0))
                c = self._q.get(timeout=remaining if remaining else 0.05)
                with self._lock:
                    self._by_ticket[c.ticket] = c
            except queue.Empty:
                if deadline is not None and time.perf_counter() > deadline:
                    return None


@dataclass
class AppInterface:
    """The full per-vFPGA bundle (paper Fig 5)."""
    n_streams: int
    csr: ControlRegisters = field(default_factory=ControlRegisters)
    irq: InterruptQueue = field(default_factory=InterruptQueue)
    host_in: List[StreamEndpoint] = field(default_factory=list)
    host_out: List[StreamEndpoint] = field(default_factory=list)
    card_in: List[StreamEndpoint] = field(default_factory=list)
    card_out: List[StreamEndpoint] = field(default_factory=list)
    net_in: List[StreamEndpoint] = field(default_factory=list)
    net_out: List[StreamEndpoint] = field(default_factory=list)
    sq_read: SendQueue = field(default_factory=SendQueue)
    sq_write: SendQueue = field(default_factory=SendQueue)
    cq_read: CompletionQueue = field(default_factory=CompletionQueue)
    cq_write: CompletionQueue = field(default_factory=CompletionQueue)

    @classmethod
    def create(cls, n_streams: int = 4, depth: int = 64) -> "AppInterface":
        iface = cls(n_streams=n_streams)
        for i in range(n_streams):
            iface.host_in.append(StreamEndpoint(StreamKind.HOST, i, depth))
            iface.host_out.append(StreamEndpoint(StreamKind.HOST, i, depth))
            iface.card_in.append(StreamEndpoint(StreamKind.CARD, i, depth))
            iface.card_out.append(StreamEndpoint(StreamKind.CARD, i, depth))
            iface.net_in.append(StreamEndpoint(StreamKind.NET, i, depth))
            iface.net_out.append(StreamEndpoint(StreamKind.NET, i, depth))
        return iface

    def stats(self) -> Dict[str, int]:
        return {
            "host_bytes_in": sum(s.bytes_in for s in self.host_in),
            "host_bytes_out": sum(s.bytes_out for s in self.host_out),
            "card_bytes_in": sum(s.bytes_in for s in self.card_in),
            "card_bytes_out": sum(s.bytes_out for s in self.card_out),
            "net_bytes_in": sum(s.bytes_in for s in self.net_in),
            "net_bytes_out": sum(s.bytes_out for s in self.net_out),
            "interrupts": self.irq.raised,
            "completions": (self.cq_read.writeback_counter
                            + self.cq_write.writeback_counter),
        }
