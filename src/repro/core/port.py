"""Port API v2: ONE typed async interface for every vFPGA slot (§7.1).

Coyote v2's core claim is a *unified logic interface*: services and user
logic present the same bundle, which is what makes partial reconfiguration
and multi-tenancy composable.  Before this module the repro had three
divergent call paths — ``CThread.invoke`` sg-lists into send queues,
``ShellScheduler.submit_io`` for the serving engine's decode I/O, and
direct Python method calls into ``core/services/*``.  A :class:`Port`
collapses them into one surface:

    port = shell.attach(slot_or_service_name)       # capability handshake
    fut  = port.submit(Invocation(...))             # async, TID-multiplexed
    comp = fut.result(timeout)                      # Completion record

Every submission — app scatter-gather work, service method calls, raw
decode-step I/O — is credit-billed through the shell scheduler under the
port's tenant and lands back on the slot's completion queue, so QoS
accounting and synchronization are uniform across slot kinds.

Drain-aware lifecycle (the reconfiguration story): a port is ACTIVE,
DRAINING, or QUIESCED.  ``quiesce()`` stops intake (new submissions are
*held*, not rejected), awaits the in-flight tail, and freezes the slot;
``snapshot()``/``restore()`` move the CSR file and host address map across
a swap; ``resume()`` replays held invocations in FIFO order against the
newly loaded logic.  ``Shell.reconfigure(slot, bitstream)`` composes these
into hot-swap with zero lost or duplicated completions.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
# builtin TimeoutError only aliases this from Python 3.11 on
from concurrent.futures import TimeoutError as FuturesTimeoutError
from dataclasses import asdict, dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.faults import FaultKind, InjectedFault
from repro.core.interfaces import Completion, Oper, SgEntry
from repro.core.scheduler import SHARED_LANE_SLOT_BASE


class PortState(Enum):
    ACTIVE = "active"
    DRAINING = "draining"      # intake held, in-flight completing
    QUIESCED = "quiesced"      # no in-flight work; safe to swap the slot


@dataclass(frozen=True)
class PortCapabilities:
    """Capability descriptor registered at ``Shell.attach()``.

    The software analogue of the paper's interface bundle: how many
    parallel streams the logic exposes, its memory-mapped control
    registers (by name), and which memory model its state lives under.
    """
    name: str
    kind: str = "app"                      # app | service
    streams: int = 0
    csr_map: Mapping[str, int] = field(default_factory=dict)
    mem_model: str = "host"                # host | paged | device | none
    ops: Tuple[str, ...] = ()              # Oper values / service methods

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["csr_map"] = dict(self.csr_map)
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PortCapabilities":
        return cls(name=d["name"], kind=d.get("kind", "app"),
                   streams=d.get("streams", 0),
                   csr_map=dict(d.get("csr_map", {})),
                   mem_model=d.get("mem_model", "host"),
                   ops=tuple(d.get("ops", ())))


@dataclass
class Invocation:
    """One typed unit of work submitted to a port.

    ``kind`` selects the datapath:
      * ``"sg"``     — scatter-gather descriptor against the slot's user
                       logic (the ``CThread.invoke`` path);
      * ``"io"``     — raw link I/O with no execution behind it (the
                       serving engine's decode-step billing path);
      * ``"method"`` — a named operation on a service port, with
                       ``args``/``kwargs``.

    ``priority`` and ``deadline_s`` are the SLO hook: execution on the
    slot's lane runs higher priorities first (earliest relative deadline
    breaks ties among equals), and a long-running lower-priority batch
    yields to them at its checkpoint boundaries
    (:meth:`ShellScheduler.checkpoint`).  Neither field changes what the
    DWRR arbiter *grants* nor what the tenant is *billed* — fairness and
    accounting are priority-blind.
    """
    kind: str = "sg"
    op: Oper = Oper.KERNEL
    sg: Optional[SgEntry] = None
    method: str = ""
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)
    nbytes: int = 0
    stream: int = 0
    tid: int = 0
    tenant: Optional[str] = None
    priority: int = 0                       # higher runs first on the lane
    deadline_s: Optional[float] = None      # relative SLO (seconds)
    meta: Dict[str, Any] = field(default_factory=dict)
    ticket: int = -1                        # assigned by the port
    # Retry/backoff policy for RETRYABLE faults (lane crash, IO error,
    # pager failure...): up to ``max_retries`` re-dispatches, each
    # preceded by ``retry_backoff_s * 2**attempt`` of backoff, and never
    # past the invocation's absolute deadline (``deadline_s`` measured
    # from first acceptance).  Default 0: faults surface immediately —
    # existing Completion(ok=False) semantics are unchanged unless a
    # caller opts in.
    max_retries: int = 0
    retry_backoff_s: float = 0.0
    retries: int = 0                        # attempts consumed (runtime)
    t_accept: float = 0.0                   # first-submit time (runtime)

    @classmethod
    def from_sg(cls, sg: SgEntry, *, priority: int = 0,
                deadline_s: Optional[float] = None) -> "Invocation":
        return cls(kind="sg", op=sg.opcode, sg=sg, nbytes=max(sg.length, 1),
                   stream=sg.src_stream, tid=sg.tid, priority=priority,
                   deadline_s=deadline_s)

    @classmethod
    def io(cls, nbytes: int, *, stream: int = 0, tag: str = "io",
           tenant: Optional[str] = None, priority: int = 0,
           deadline_s: Optional[float] = None) -> "Invocation":
        return cls(kind="io", op=Oper.LOCAL_TRANSFER, nbytes=max(nbytes, 1),
                   stream=stream, tenant=tenant, meta={"tag": tag},
                   priority=priority, deadline_s=deadline_s)

    @classmethod
    def call(cls, method: str, *args: Any, nbytes: int = 0,
             priority: int = 0, deadline_s: Optional[float] = None,
             **kwargs: Any) -> "Invocation":
        return cls(kind="method", method=method, args=args, kwargs=kwargs,
                   nbytes=nbytes, priority=priority, deadline_s=deadline_s)

    def to_sg(self) -> SgEntry:
        if self.sg is not None:
            return self.sg
        return SgEntry(length=self.nbytes, src_stream=self.stream,
                       tid=self.tid, opcode=self.op, meta=dict(self.meta))


class PortFuture(Future):
    """Future[Completion] with the originating invocation attached."""

    def __init__(self, invocation: Invocation):
        super().__init__()
        self.invocation = invocation

    @property
    def ticket(self) -> int:
        return self.invocation.ticket

    def completion(self, timeout: Optional[float] = None
                   ) -> Optional[Completion]:
        """``result()`` that returns None on timeout (legacy contract)."""
        try:
            return self.result(timeout=timeout)
        except FuturesTimeoutError:
            return None


class PortError(RuntimeError):
    """Structured port failure: WHAT failed (``kind``, a
    :class:`~repro.core.faults.FaultKind` value), WHERE (``slot``,
    ``tenant``), and whether a re-dispatch could succeed (``retryable``).
    Message-only construction stays valid for generic refusals
    (closed port, disallowed method)."""

    def __init__(self, message: str, *, kind: Any = "error",
                 slot: Optional[int] = None, tenant: Optional[str] = None,
                 retryable: bool = False,
                 cause: Optional[BaseException] = None):
        super().__init__(message)
        self.kind = kind.value if isinstance(kind, FaultKind) else str(kind)
        self.slot = slot
        self.tenant = tenant
        self.retryable = retryable
        self.cause = cause

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "slot": self.slot,
                "tenant": self.tenant, "retryable": self.retryable,
                "message": str(self)}


class Port:
    """Base port: state machine, in-flight tracking, hold-and-replay.

    Subclasses implement ``_dispatch(inv, fut)`` (route one invocation
    into their datapath, eventually calling ``_finish``), plus
    ``capabilities()`` and the ``snapshot()``/``restore()`` pair.
    """

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._state = PortState.ACTIVE
        self._tickets = itertools.count()
        self._inflight: Dict[int, PortFuture] = {}
        self._held: List[Tuple[Invocation, PortFuture]] = []
        self.submitted = 0
        self.completed = 0
        self.failed = 0                   # futures failed with PortError
        self.retried = 0                  # retryable-fault re-dispatches
        self.replayed = 0
        self.held_peak = 0
        self._closed = False

    # ------------------------------------------------------------ intake ---
    @property
    def state(self) -> PortState:
        return self._state

    def submit(self, inv: Invocation) -> PortFuture:
        """Submit one invocation; returns a Future[Completion].

        Never blocks on the slot itself: while the port drains or sits
        quiesced across a reconfiguration, submissions are held and
        replayed (FIFO) on ``resume()`` — callers just see a future that
        resolves after the swap.
        """
        fut = PortFuture(inv)
        slot, default_tenant = self._fault_ctx()
        tenant = inv.tenant or default_tenant
        health = self._health()
        if health is not None and health.is_quarantined(tenant):
            # graceful degradation: a repeatedly-faulting tenant is
            # rejected FAST with a typed error — bystanders keep flowing
            health.record_rejection(tenant)
            raise PortError(
                f"tenant {tenant!r} is quarantined on port {self.name!r} "
                "(repeated faults within the quarantine window); "
                "Shell.health.unquarantine() to lift",
                kind=FaultKind.QUARANTINED, slot=slot, tenant=tenant,
                retryable=False)
        with self._lock:
            if self._closed:
                raise PortError(
                    f"port {self.name!r} is closed (its slot/service was "
                    "torn down, e.g. by cold_restart); re-attach through "
                    "Shell.attach() for a live port")
            if inv.ticket < 0:
                inv.ticket = next(self._tickets)
            if inv.t_accept == 0.0:
                inv.t_accept = time.perf_counter()
            self.submitted += 1
            if self._state is not PortState.ACTIVE:
                self._held.append((inv, fut))
                self.held_peak = max(self.held_peak, len(self._held))
                return fut
            self._inflight[inv.ticket] = fut
        self._safe_dispatch(inv, fut)
        return fut

    def call(self, inv: Invocation,
             timeout: Optional[float] = None) -> Completion:
        """Synchronous convenience: submit and wait."""
        comp = self.submit(inv).result(timeout=timeout)
        return comp

    # ------------------------------------------------------- completion ----
    def _finish(self, inv: Invocation, fut: PortFuture,
                comp: Completion) -> None:
        if (not comp.ok and isinstance(comp.result, BaseException)
                and self._should_retry(inv, comp.result)):
            # a retryable fault surfaced as a failed Completion (lane
            # crash, injected service fault): consume one retry and
            # re-dispatch the SAME invocation instead of resolving
            self._requeue_retry(inv, fut)
            return
        with self._lock:
            self._inflight.pop(inv.ticket, None)
            self.completed += 1
            self._cv.notify_all()
        if not fut.done():               # a future resolves exactly once
            fut.set_result(comp)

    # ---------------------------------------------- typed failure path -----
    def _safe_dispatch(self, inv: Invocation, fut: PortFuture) -> None:
        """Dispatch with a finally-safe failure path: ANY exception out
        of the datapath (including an injected ``port.dispatch`` fault)
        fails the future with a structured :class:`PortError` instead of
        leaving it unresolved forever."""
        try:
            plan = self._fault_plan()
            if plan is not None:
                slot, default_tenant = self._fault_ctx()
                plan.fire("port.dispatch", slot=slot,
                          tenant=inv.tenant or default_tenant,
                          ticket=inv.ticket)
            self._dispatch(inv, fut)
        except BaseException as e:  # noqa: BLE001 — the future IS the
            self._fail(inv, fut, e)  # error channel; nothing may hang

    def _as_port_error(self, inv: Invocation,
                       exc: BaseException) -> PortError:
        slot, default_tenant = self._fault_ctx()
        tenant = inv.tenant or default_tenant
        if isinstance(exc, PortError):
            return exc
        kind = getattr(exc, "kind", FaultKind.DISPATCH)
        retryable = bool(getattr(exc, "retryable", False))
        return PortError(
            f"invocation {inv.ticket} on port {self.name!r} failed: "
            f"{exc}", kind=kind, slot=slot, tenant=tenant,
            retryable=retryable, cause=exc)

    def _fail(self, inv: Invocation, fut: PortFuture,
              exc: BaseException) -> None:
        """Fail one in-flight invocation with a typed error — after the
        retry policy declines it.  Pops in-flight tracking (quiesce
        waiters see it leave) and records the fault in the shell's
        health ledger when one is attached."""
        err = self._as_port_error(inv, exc)
        if self._should_retry(inv, err):
            self._requeue_retry(inv, fut)
            return
        health = self._health()
        if health is not None:
            health.record_fault(err.kind, slot=err.slot, tenant=err.tenant,
                                site=getattr(exc, "site", ""),
                                msg=str(err))
        with self._lock:
            self._inflight.pop(inv.ticket, None)
            self.failed += 1
            self._cv.notify_all()
        if not fut.done():
            fut.set_exception(err)

    def _should_retry(self, inv: Invocation, exc: BaseException) -> bool:
        if inv.retries >= inv.max_retries:
            return False
        if not getattr(exc, "retryable", False):
            return False
        if self._closed:
            return False
        if inv.deadline_s is not None and inv.t_accept > 0.0:
            # deadline-aware: a retry that cannot finish before the SLO
            # deadline is not attempted (backoff counts against it)
            backoff = inv.retry_backoff_s * (2 ** inv.retries)
            if (time.perf_counter() + backoff
                    > inv.t_accept + inv.deadline_s):
                return False
        return True

    def _requeue_retry(self, inv: Invocation, fut: PortFuture) -> None:
        """Consume one retry and re-dispatch the SAME invocation (same
        ticket, same future).  Runs on whatever thread surfaced the
        fault; the bounded exponential backoff sleeps there."""
        backoff = inv.retry_backoff_s * (2 ** inv.retries)
        inv.retries += 1
        with self._lock:
            self.retried += 1
        if backoff > 0:
            time.sleep(min(backoff, 1.0))
        with self._lock:
            if self._closed:
                self._inflight.pop(inv.ticket, None)
                self.failed += 1
                self._cv.notify_all()
                if not fut.done():
                    fut.set_exception(PortError(
                        f"port {self.name!r} closed during retry of "
                        f"invocation {inv.ticket}",
                        kind=FaultKind.DISPATCH, retryable=False))
                return
            if self._state is not PortState.ACTIVE:
                # port started draining between fault and retry: the
                # invocation re-holds and replays on resume()
                self._inflight.pop(inv.ticket, None)
                self._held.append((inv, fut))
                self.held_peak = max(self.held_peak, len(self._held))
                self._cv.notify_all()
                return
            self._inflight[inv.ticket] = fut
        self._safe_dispatch(inv, fut)

    def fail_inflight(self, exc: Optional[BaseException] = None) -> int:
        """Force-fail every in-flight invocation with a typed error — the
        recovery path for a WEDGED slot whose completions will never
        arrive (its lane died or its logic hung).  Returns how many
        futures were failed; held invocations are untouched (they replay
        after recovery)."""
        with self._lock:
            futs = list(self._inflight.items())
            self._inflight.clear()
            self.failed += len(futs)
            self._cv.notify_all()
        slot, tenant = self._fault_ctx()
        base = exc or PortError(
            f"port {self.name!r}: in-flight work force-failed during "
            "slot recovery", kind=FaultKind.WEDGE, slot=slot,
            tenant=tenant, retryable=False)
        for _ticket, fut in futs:
            if not fut.done():
                fut.set_exception(base)
        return len(futs)

    def close(self) -> None:
        """Permanently invalidate the port (its backing slot/service is
        gone).  Held invocations fail fast rather than dispatch against
        a dead object."""
        with self._lock:
            self._closed = True
            held, self._held = self._held, []
        for inv, fut in held:
            if not fut.done():
                fut.set_exception(PortError(
                    f"port {self.name!r} closed while invocation "
                    f"{inv.ticket} was held"))

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def held(self) -> int:
        with self._lock:
            return len(self._held)

    # ------------------------------------------------- drain / hot-swap ----
    def quiesce(self, timeout: Optional[float] = 30.0, *,
                resume_on_timeout: bool = True) -> bool:
        """Stop intake and wait for every in-flight completion.

        Idempotent; returns True once the port is QUIESCED.  On timeout
        False is returned and — by default — intake is REOPENED
        (``resume()``: held submissions replay, the port is ACTIVE
        again), so a failed drain can never leave the port wedged
        DRAINING with its intake silently held.  The timeout is also
        recorded as a health event when a monitor is attached.
        ``resume_on_timeout=False`` restores the old contract for
        callers that take over recovery themselves (e.g.
        ``recover_tenant_local`` force-fails the stuck tail instead).
        """
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        timed_out = False
        with self._lock:
            if self._state is PortState.QUIESCED and not self._inflight:
                return True
            self._state = PortState.DRAINING
            while self._inflight:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    timed_out = True
                    break
                self._cv.wait(timeout=remaining if remaining else 0.25)
            if not timed_out:
                self._state = PortState.QUIESCED
                return True
        # timeout path, outside the lock (resume() re-takes it)
        health = self._health()
        if health is not None:
            slot, tenant = self._fault_ctx()
            health.record_fault(FaultKind.QUIESCE_TIMEOUT, slot=slot,
                                tenant=tenant, strike=False,
                                msg=f"port {self.name!r} quiesce timed "
                                    f"out with {self.inflight()} in flight")
        if resume_on_timeout:
            self.resume()
        return False

    def resume(self) -> int:
        """Replay held invocations in FIFO order, then reopen intake.
        Returns the number of replayed invocations.

        Intake flips to ACTIVE only once the held list is empty under
        the lock — a submission racing with the replay is held and
        drained by the next loop iteration, so no new invocation can
        overtake an older held one.
        """
        replayed = 0
        while True:
            with self._lock:
                if not self._held:
                    self._state = PortState.ACTIVE
                    return replayed
                held, self._held = self._held, []
                for inv, fut in held:
                    self._inflight[inv.ticket] = fut
            for inv, fut in held:
                self.replayed += 1
                replayed += 1
                self._safe_dispatch(inv, fut)

    def take_held(self) -> List[Tuple[Invocation, PortFuture]]:
        """Detach the held FIFO for replay on ANOTHER port — the
        cross-shell half of hold-and-replay (quiesce-and-migrate).  The
        port must be quiesced/draining; callers hand the list to the
        destination port's :meth:`replay_adopted` so every held
        submission still resolves its ORIGINAL future exactly once."""
        with self._lock:
            if self._state is PortState.ACTIVE:
                raise PortError(
                    f"take_held on ACTIVE port {self.name!r}: quiesce "
                    "first (held invocations only exist while intake is "
                    "stopped)")
            held, self._held = self._held, []
            return held

    def restore_held(self, held: List[Tuple[Invocation, PortFuture]]
                     ) -> None:
        """Re-attach invocations detached by :meth:`take_held` (a failed
        migration hands them back): they rejoin the FRONT of the held
        FIFO in their original order, re-ticketed in this port's space
        (a destination may have re-ticketed them before failing), and
        replay on the next ``resume()`` — still exactly once."""
        with self._lock:
            for inv, _fut in held:
                inv.ticket = next(self._tickets)
            self._held = list(held) + self._held
            self.held_peak = max(self.held_peak, len(self._held))

    def replay_adopted(self,
                       held: List[Tuple[Invocation, PortFuture]]) -> int:
        """Dispatch invocations quiesced on another port through THIS
        port, resolving their original futures — zero lost, zero
        duplicated completions across the migration boundary.  Each
        invocation is re-ticketed in this port's space (tickets are
        per-port); if this port is itself not ACTIVE the work joins its
        held FIFO and replays on its next ``resume()``."""
        n = 0
        for inv, fut in held:
            with self._lock:
                if self._closed:
                    raise PortError(
                        f"port {self.name!r} is closed; cannot adopt "
                        "migrated invocations")
                inv.ticket = next(self._tickets)
                self.submitted += 1
                if self._state is not PortState.ACTIVE:
                    # joins this port's held FIFO; its later resume()
                    # replays it (and counts it) exactly once
                    self._held.append((inv, fut))
                    self.held_peak = max(self.held_peak, len(self._held))
                    continue
                self._inflight[inv.ticket] = fut
                self.replayed += 1
            self._safe_dispatch(inv, fut)
            n += 1
        return n

    # ------------------------------------------------------------ hooks ----
    def _dispatch(self, inv: Invocation, fut: PortFuture) -> None:
        raise NotImplementedError

    def _fault_ctx(self) -> Tuple[Optional[int], Optional[str]]:
        """(slot, default tenant) for typed errors and health records."""
        return None, None

    def _fault_plan(self):
        """The attached :class:`~repro.core.faults.FaultPlan`, if any."""
        return None

    def _health(self):
        """The shell's :class:`~repro.core.health.HealthMonitor`, if
        this port is shell-bound."""
        return None

    def capabilities(self) -> PortCapabilities:
        raise NotImplementedError

    def snapshot(self) -> Dict[str, Any]:
        return {}

    def restore(self, snap: Dict[str, Any]) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "state": self._state.value,
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "retried": self.retried,
                "inflight": len(self._inflight),
                "held": len(self._held),
                "replayed": self.replayed,
            }


class VFpgaPort(Port):
    """The port of one application slot (user logic behind the unified
    interface).  SG work executes via ``VFpga.execute_sg`` under the shell
    scheduler (weighted credits + DWRR arbiter); completions land on the
    slot's read/write completion queues exactly as before, so legacy
    ticket-waiters and writeback counters keep working."""

    def __init__(self, vfpga: Any):
        super().__init__(f"vfpga{vfpga.slot}")
        self.vfpga = vfpga

    # ------------------------------------------------------ fault wiring ---
    def _fault_ctx(self) -> Tuple[Optional[int], Optional[str]]:
        return self.vfpga.slot, getattr(self.vfpga, "tenant", None)

    def _fault_plan(self):
        shell = getattr(self.vfpga, "shell", None)
        return getattr(shell, "faults", None)

    def _health(self):
        shell = getattr(self.vfpga, "shell", None)
        return getattr(shell, "health", None)

    # ---------------------------------------------------------- dispatch ---
    def _dispatch(self, inv: Invocation, fut: PortFuture) -> None:
        vf = self.vfpga
        shell = getattr(vf, "shell", None)
        if inv.kind == "io":
            self._dispatch_io(inv, fut, shell)
            return
        sg = inv.to_sg()
        write_side = inv.op in (Oper.LOCAL_OFFLOAD, Oper.REMOTE_WRITE)
        cq = vf.iface.cq_write if write_side else vf.iface.cq_read

        def complete(comp: Completion, inv=inv, fut=fut, cq=cq) -> None:
            cq.writeback(comp)           # counter only; the future is the
            self._finish(inv, fut, comp)  # synchronization object

        if shell is None:
            complete(vf.execute_sg(inv.ticket, sg))
        else:
            shell.scheduler.submit(
                slot=vf.slot, stream=sg.src_stream, ticket=inv.ticket,
                sg=sg, execute=vf.execute_sg, complete=complete,
                tenant=inv.tenant, priority=inv.priority,
                deadline_s=inv.deadline_s)

    def _dispatch_io(self, inv: Invocation, fut: PortFuture, shell) -> None:
        t0 = time.perf_counter()

        def done(err: Optional[BaseException] = None,
                 inv=inv, fut=fut, t0=t0) -> None:
            if err is not None:
                self._fail(inv, fut, err)
                return
            self._finish(inv, fut, Completion(
                ticket=inv.ticket, tid=inv.tid, opcode=Oper.LOCAL_TRANSFER,
                nbytes=inv.nbytes, t_submit=t0,
                t_done=time.perf_counter()))

        # the scheduler probes this before passing an IO error into the
        # callback (legacy on_done callbacks are zero-arg)
        done.accepts_error = True

        if shell is None:
            done()
            return
        shell.scheduler.submit_io(
            inv.nbytes, slot=self.vfpga.slot, stream=inv.stream,
            tenant=inv.tenant, tag=inv.meta.get("tag", "io"),
            wait=False, on_done=done, priority=inv.priority,
            deadline_s=inv.deadline_s)

    # ------------------------------------------------------ capabilities ---
    def capabilities(self) -> PortCapabilities:
        vf = self.vfpga
        art = vf.app
        if art is not None and getattr(art, "capabilities", None) is not None:
            caps = art.capabilities
            # slot-qualify the artifact's descriptor
            return PortCapabilities(
                name=self.name, kind="app", streams=caps.streams,
                csr_map=dict(caps.csr_map), mem_model=caps.mem_model,
                ops=caps.ops)
        return PortCapabilities(
            name=self.name, kind="app", streams=vf.iface.n_streams,
            csr_map={}, mem_model="host",
            ops=tuple(o.value for o in (Oper.LOCAL_TRANSFER, Oper.KERNEL,
                                        Oper.LOCAL_OFFLOAD,
                                        Oper.LOCAL_SYNC)))

    # ------------------------------------------------- snapshot / restore --
    def snapshot(self) -> Dict[str, Any]:
        """Freeze swap-surviving slot state: the CSR file and the cThread
        host address map (getMem buffers outlive the logic they feed)."""
        vf = self.vfpga
        return {
            "csr": vf.iface.csr.snapshot(),
            "addr_map": dict(vf._addr_map),
            "next_vaddr": vf._next_vaddr,
            "app": vf.app.name if vf.app else None,
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        vf = self.vfpga
        for reg, val in snap.get("csr", {}).items():
            vf.iface.csr.set_csr(val, reg)
        vf._addr_map.update(snap.get("addr_map", {}))
        vf._next_vaddr = max(vf._next_vaddr,
                             snap.get("next_vaddr", vf._next_vaddr))


# Synthetic "slot" ids for service ports: services are not application
# slots, but billing through the scheduler wants a stable requester key.
# Defined BY the scheduler's shared-lane threshold so service-call
# execution always rides the shared service lane instead of minting one
# lane thread per service.
SERVICE_SLOT_BASE = SHARED_LANE_SLOT_BASE


class ServicePort(Port):
    """Port over a dynamic-layer service: ``submit(Invocation.call(...))``
    runs one of the service's declared ``PORT_METHODS`` through the shell
    scheduler (so service control traffic is credit-billed like any other
    tenant traffic) and resolves with a Completion carrying the result."""

    def __init__(self, service: Any, *, shell: Any = None,
                 slot: int = SERVICE_SLOT_BASE,
                 tenant: Optional[str] = None):
        super().__init__(service.NAME)
        self.service = service
        self.shell = shell
        self.slot = slot
        self.tenant = tenant or f"svc.{service.NAME}"

    # ------------------------------------------------------ fault wiring ---
    def _fault_ctx(self) -> Tuple[Optional[int], Optional[str]]:
        return self.slot, self.tenant

    def _fault_plan(self):
        return getattr(self.shell, "faults", None)

    def _health(self):
        return getattr(self.shell, "health", None)

    def _dispatch(self, inv: Invocation, fut: PortFuture) -> None:
        svc = self.service
        allowed = getattr(svc, "PORT_METHODS", ())
        if inv.kind != "method" or inv.method not in allowed:
            # reject BEFORE billing: a disallowed call must not acquire
            # credits or burn an arbiter visit
            self._finish(inv, fut, Completion(
                ticket=inv.ticket, tid=inv.tid, opcode=Oper.KERNEL,
                nbytes=0, t_submit=time.perf_counter(),
                t_done=time.perf_counter(), ok=False,
                result=PortError(
                    f"service {svc.NAME!r} port does not expose "
                    f"{inv.method!r} (allowed: {sorted(allowed)})")))
            return

        def execute(ticket: int, sg: Optional[SgEntry],
                    inv=inv) -> Completion:
            t0 = time.perf_counter()
            ok, result = True, None
            try:
                plan = self._fault_plan()
                if plan is not None:
                    plan.fire("service.call", slot=self.slot,
                              tenant=inv.tenant or self.tenant,
                              method=inv.method)
                result = getattr(svc, inv.method)(*inv.args, **inv.kwargs)
            except Exception as e:    # noqa: BLE001 — fault -> completion
                ok, result = False, e
            return Completion(ticket=ticket, tid=inv.tid, opcode=Oper.KERNEL,
                              nbytes=inv.nbytes, t_submit=t0,
                              t_done=time.perf_counter(), ok=ok,
                              result=result)

        if self.shell is None:
            self._finish(inv, fut, execute(inv.ticket, None))
            return
        sg = SgEntry(length=max(inv.nbytes, 1), src_stream=0,
                     opcode=Oper.KERNEL,
                     meta={"method": inv.method, "service": svc.NAME})
        self.shell.scheduler.submit(
            slot=self.slot, stream=0, ticket=inv.ticket, sg=sg,
            execute=execute,
            complete=lambda comp, inv=inv, fut=fut:
                self._finish(inv, fut, comp),
            tenant=inv.tenant or self.tenant, priority=inv.priority,
            deadline_s=inv.deadline_s)

    def capabilities(self) -> PortCapabilities:
        svc = self.service
        caps = getattr(svc, "port_capabilities", None)
        if callable(caps):
            return caps()
        return PortCapabilities(
            name=svc.NAME, kind="service", streams=0, csr_map={},
            mem_model="none",
            ops=tuple(getattr(svc, "PORT_METHODS", ())))

    def snapshot(self) -> Dict[str, Any]:
        return {"generation": self.service.generation,
                "config": self.service.config}

    def restore(self, snap: Dict[str, Any]) -> None:
        """Reapply the snapshotted config if the service's config moved
        during the swap window (no-op — and no spurious generation bump —
        when nothing changed)."""
        cfg = snap.get("config")
        if cfg is not None and cfg != self.service.config:
            self.service.configure(cfg)
