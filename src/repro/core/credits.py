"""Multi-tenant fair sharing: packetization + credits + RR interleave (§6.3).

Coyote v2 divides every transfer into 4 KB packets (configurable), grants
each (vFPGA, stream) a credit budget bounded by its destination-queue depth,
and round-robins packets over the bandwidth-constrained link.  Requests
beyond the credit budget stall the *requester*, never the link — that is the
paper's back-pressure containment (§7.2).

The :class:`Link` here does double duty: it models a bandwidth-limited,
in-order link with a virtual clock (deterministic fairness benchmarks — the
Fig 8 reproduction), and it can wrap a real transfer callable so the same
arbiter drives actual host<->device movement.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

DEFAULT_PACKET_BYTES = 4096


# ------------------------------------------------------------ packetizer ---
def packetize(nbytes: int, packet_bytes: int = DEFAULT_PACKET_BYTES
              ) -> List[int]:
    """Split a transfer length into packet lengths (last may be short)."""
    if nbytes <= 0:
        return []
    full, rem = divmod(nbytes, packet_bytes)
    out = [packet_bytes] * full
    if rem:
        out.append(rem)
    return out


# ---------------------------------------------------------------- credits --
class CreditAccount:
    """Per-(vFPGA, stream) credit pool; capacity == destination queue depth.

    Requests acquire one credit per packet and block (back-pressure onto the
    requester) when exhausted; completions replenish.  ``on_release`` (if
    given) fires after every replenish, outside the account's lock — the
    shell scheduler uses it to wake its issue loop when an executor lane
    returns credits asynchronously."""

    def __init__(self, capacity: int,
                 on_release: Optional[Callable[[], None]] = None):
        self.capacity = capacity
        self._avail = capacity
        self._cv = threading.Condition()
        self.stalls = 0
        self.on_release = on_release

    def acquire(self, n: int = 1, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cv:
            while self._avail < n:
                self.stalls += 1
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(timeout=remaining)
            self._avail -= n
            return True

    def try_acquire(self, n: int = 1) -> bool:
        with self._cv:
            if self._avail < n:
                self.stalls += 1
                return False
            self._avail -= n
            return True

    def release(self, n: int = 1) -> None:
        with self._cv:
            self._avail = min(self._avail + n, self.capacity)
            self._cv.notify_all()
        if self.on_release is not None:
            self.on_release()       # outside the lock: the callback may
                                    # take the scheduler's own lock

    @property
    def available(self) -> int:
        with self._cv:
            return self._avail


# ------------------------------------------------------------------ link ---
@dataclass
class LinkEvent:
    t: float               # virtual completion time (s)
    src: str
    dst: str
    nbytes: int
    tag: str = ""


class Link:
    """Bandwidth-limited in-order link with a virtual clock.

    ``transfer(nbytes)`` advances the clock by nbytes/bandwidth and returns
    the completion time.  ``real_fn`` optionally performs an actual data
    movement (e.g. device_put) — the virtual clock still tracks modeled
    occupancy so fairness stats stay deterministic."""

    def __init__(self, name: str, bandwidth: float,
                 real_fn: Optional[Callable[[Any], Any]] = None):
        self.name = name
        self.bandwidth = bandwidth       # bytes/s (modeled)
        self.real_fn = real_fn
        self.clock = 0.0                 # virtual seconds of occupancy
        self.bytes_moved = 0
        self._lock = threading.Lock()
        self._listeners: List[Callable[[LinkEvent], None]] = []

    def on_event(self, cb: Callable[[LinkEvent], None]) -> None:
        self._listeners.append(cb)

    def transfer(self, nbytes: int, payload: Any = None, *, src: str = "",
                 dst: str = "", tag: str = "") -> Tuple[float, Any]:
        with self._lock:
            self.clock += nbytes / self.bandwidth
            self.bytes_moved += nbytes
            t = self.clock
        result = self.real_fn(payload) if self.real_fn is not None else None
        ev = LinkEvent(t=t, src=src, dst=dst, nbytes=nbytes, tag=tag)
        for cb in self._listeners:
            cb(ev)
        return t, result


# --------------------------------------------------------------- arbiter ---
@dataclass
class _Request:
    requester: str
    packets: Deque[int]
    tag: str
    on_done: Optional[Callable[[float], None]]
    t_enqueue: float
    bytes_total: int
    bytes_done: int = 0
    t_done: float = 0.0


class RRArbiter:
    """Round-robin packet interleaving across requesters (paper Fig 8).

    Each requester (a vFPGA stream) owns a FIFO of requests; the arbiter
    visits requesters cyclically, moving ONE packet per visit, guaranteeing
    equal bandwidth allocation while preserving per-requester ordering."""

    def __init__(self, link: Link,
                 packet_bytes: int = DEFAULT_PACKET_BYTES):
        self.link = link
        self.packet_bytes = packet_bytes
        self._queues: Dict[str, Deque[_Request]] = {}
        self._order: List[str] = []
        self._rr = 0
        self.delivered: Dict[str, int] = {}
        self.completions: List[Tuple[str, float, int]] = []

    def _effective_packet_bytes(self, nbytes: int) -> int:
        """Descriptor size for one request.  Plain RR moves exactly one
        packet per visit, so its equal-bandwidth guarantee requires a
        uniform packet size — no scaling here."""
        return self.packet_bytes

    def submit(self, requester: str, nbytes: int, *, tag: str = "",
               on_done: Optional[Callable[[float], None]] = None) -> None:
        if requester not in self._queues:
            self._queues[requester] = deque()
            self._order.append(requester)
            self.delivered.setdefault(requester, 0)
        pkts = deque(packetize(nbytes, self._effective_packet_bytes(nbytes)))
        self._queues[requester].append(_Request(
            requester=requester, packets=pkts, tag=tag, on_done=on_done,
            t_enqueue=self.link.clock, bytes_total=nbytes))

    def pending(self) -> bool:
        return any(q for q in self._queues.values())

    def backlogged(self, requester: str) -> bool:
        """True while the requester has queued (unsent) packets."""
        return bool(self._queues.get(requester))

    def step(self) -> bool:
        """Move one packet from the next non-empty requester.  False if
        nothing is pending."""
        n = len(self._order)
        for _ in range(n):
            name = self._order[self._rr % n]
            self._rr += 1
            q = self._queues[name]
            if not q:
                continue
            req = q[0]
            pkt = req.packets.popleft()
            t, _ = self.link.transfer(pkt, src=name, dst="link",
                                      tag=req.tag)
            req.bytes_done += pkt
            self.delivered[name] += pkt
            if not req.packets:
                q.popleft()
                req.t_done = t
                self.completions.append((name, t, req.bytes_total))
                if req.on_done is not None:
                    req.on_done(t)
            return True
        return False

    def drain(self) -> None:
        while self.step():
            pass

    def fairness(self) -> Dict[str, float]:
        """Fraction of link bytes each requester received."""
        total = sum(self.delivered.values()) or 1
        return {k: v / total for k, v in self.delivered.items()}


class WeightedRRArbiter(RRArbiter):
    """Deficit-weighted round robin (DWRR) over requesters.

    Each requester carries a weight; every visit grants it a byte quantum
    of ``weight * packet_bytes`` and it sends while its deficit covers the
    head packet.  Equal weights degenerate to plain RR (one packet per
    visit at uniform packet size), so all RRArbiter invariants — per
    requester FIFO ordering, every byte moved exactly once — carry over.
    Idle requesters forfeit their deficit: no banking bandwidth while
    the queue is empty (standard DWRR)."""

    # bound on descriptors per request, like a DMA descriptor ring: very
    # large transfers ride proportionally larger bursts instead of tens
    # of thousands of per-packet Python iterations.  Safe under DWRR
    # because arbitration is byte-deficit-based: a big packet just waits
    # more visits for its deficit, so weighted byte shares are unchanged.
    # Transfers under MAX_PACKETS * packet_bytes (1 MB at the 4 KB
    # default) keep exact per-packet granularity, so sniffer-event and
    # per-packet fairness semantics are unchanged where observable.
    MAX_PACKETS_PER_REQUEST = 256

    def __init__(self, link: Link, packet_bytes: int = DEFAULT_PACKET_BYTES,
                 default_weight: float = 1.0):
        super().__init__(link, packet_bytes=packet_bytes)
        self.default_weight = default_weight
        self._weights: Dict[str, float] = {}
        self._deficit: Dict[str, float] = {}

    def _effective_packet_bytes(self, nbytes: int) -> int:
        if nbytes > self.MAX_PACKETS_PER_REQUEST * self.packet_bytes:
            return -(-nbytes // self.MAX_PACKETS_PER_REQUEST)
        return self.packet_bytes

    def set_weight(self, requester: str, weight: float) -> None:
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        self._weights[requester] = weight

    def weight(self, requester: str) -> float:
        return self._weights.get(requester, self.default_weight)

    def submit(self, requester: str, nbytes: int, *, tag: str = "",
               weight: Optional[float] = None,
               on_done: Optional[Callable[[float], None]] = None) -> None:
        if weight is not None:
            self.set_weight(requester, weight)
        super().submit(requester, nbytes, tag=tag, on_done=on_done)

    def step(self) -> bool:
        if not self.pending():
            return False
        n = len(self._order)
        while True:
            name = self._order[self._rr % n]
            q = self._queues[name]
            if not q:
                self._deficit[name] = 0.0      # idle: forfeit deficit
                self._rr += 1
                continue
            req = q[0]
            pkt_len = req.packets[0]
            d = self._deficit.get(name, 0.0)
            if d < pkt_len:
                # grant this round's quantum and move on; weight > 0
                # guarantees the deficit eventually covers the packet.
                self._deficit[name] = d + self.weight(name) * self.packet_bytes
                self._rr += 1
                continue
            pkt = req.packets.popleft()
            self._deficit[name] = d - pkt
            t, _ = self.link.transfer(pkt, src=name, dst="link",
                                      tag=req.tag)
            req.bytes_done += pkt
            self.delivered[name] += pkt
            if not req.packets:
                q.popleft()
                req.t_done = t
                self.completions.append((name, t, req.bytes_total))
                if req.on_done is not None:
                    req.on_done(t)
            # NOTE: _rr not advanced — the requester keeps the link while
            # its deficit covers the next packet (its weighted burst).
            return True


def jains_index(shares: Dict[str, float]) -> float:
    """Jain's fairness index: 1.0 = perfectly fair."""
    vals = list(shares.values())
    if not vals:
        return 1.0
    s = sum(vals)
    s2 = sum(v * v for v in vals)
    return (s * s) / (len(vals) * s2) if s2 else 1.0


def weighted_jains_index(shares: Dict[str, float],
                         weights: Dict[str, float]) -> float:
    """Jain's index over weight-normalized shares: 1.0 means every party
    received bandwidth exactly proportional to its configured weight."""
    wtot = sum(weights.get(k, 1.0) for k in shares) or 1.0
    norm = {k: v / (weights.get(k, 1.0) / wtot)
            for k, v in shares.items()}
    return jains_index(norm)
